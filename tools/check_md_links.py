"""Markdown link checker: relative links in the repo docs must resolve.

Scans the given markdown files (default: README.md, docs/*.md,
benchmarks/README.md) for inline links/images `[text](target)` and checks
that every *relative* target exists on disk (anchors are stripped; http/
https/mailto targets are skipped — CI stays hermetic, no network). Exits
non-zero listing every broken link, so a doc referring to a moved file or
a renamed benchmark fails loudly instead of rotting.

  python tools/check_md_links.py [FILES...]
"""
from __future__ import annotations

import glob
import os
import re
import sys

# inline links/images; stops at the first ')' so "(see x)" prose is ignored
_LINK_RE = re.compile(r"!?\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
_SKIP_SCHEMES = ("http://", "https://", "mailto:", "#")

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

DEFAULT_FILES = ("README.md", "docs/*.md", "benchmarks/README.md")


def iter_links(md_path: str):
    text = open(md_path, encoding="utf-8").read()
    in_code = False
    for line in text.splitlines():
        if line.strip().startswith("```"):
            in_code = not in_code
            continue
        if in_code:
            continue
        for m in _LINK_RE.finditer(line):
            yield m.group(1)


def check(files: list[str]) -> list[str]:
    errors = []
    for md in files:
        for target in iter_links(md):
            if target.startswith(_SKIP_SCHEMES):
                continue
            path = target.split("#", 1)[0]
            if not path:
                continue
            resolved = os.path.normpath(
                os.path.join(os.path.dirname(md), path))
            if not os.path.exists(resolved):
                errors.append(f"{md}: broken link -> {target}")
    return errors


def main(argv=None) -> int:
    args = (argv if argv is not None else sys.argv[1:])
    if args:
        files = args
    else:
        files = [f for pat in DEFAULT_FILES
                 for f in sorted(glob.glob(os.path.join(REPO, pat)))]
    if not files:
        print("check_md_links: no markdown files found", file=sys.stderr)
        return 1
    errors = check(files)
    if errors:
        print("\n".join(errors), file=sys.stderr)
        return 1
    print(f"markdown links OK ({len(files)} files)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
