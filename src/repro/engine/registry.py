"""Layer-backend registry: the single place that knows every datapath.

A *backend* is one way to store and execute a projection/conv leaf at
serving time (dense MXU matmul, packed-weight binary matmul, XNOR-popcount
FC, XNOR-popcount conv, binarized-dense conv fallback). Each backend
registers a :class:`BackendSpec` describing

* ``eligible(ctx)``   — can this leaf run here, and if not, why not,
* ``pack(ctx, leaf, pack_ctx)`` — transform a master-weight leaf into the
  backend's serving representation (identity for dense),
* ``apply(leaf, x, **kw)`` — execute the layer on an input batch,
* ``cost(m, k, n, **kw)`` — HBM bytes + op count for an (M, K) x (K, N)
  application (conv is costed at the im2col GEMM level; ``plan_report``
  also offers ``shape=``/``with_scale=`` kwargs, but a bare (m, k, n)
  callable is accepted too),

plus the leaf class it produces, which is how ``apply_linear`` /
``apply_conv2d`` dispatch without isinstance chains: the registry maps
``(kind, type(leaf)) -> spec`` and falls back to the dense spec for plain
arrays (including binarized-dense conv kernels, which *are* plain arrays).

``repro.engine.plan.compile_plan`` walks a parameter tree, asks every
backend for eligibility, and assigns each path the highest-priority
eligible backend — adding a new datapath is one ``register_backend`` call.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

#: Eligibility result: (ok, reason). ``reason`` is "ok" when eligible,
#: otherwise a short JSON-stable explanation for the plan report.
EligibilityFn = Callable[["LeafContext"], tuple[bool, str]]


@dataclasses.dataclass(frozen=True)
class LeafContext:
    """Static facts about one parameter-tree leaf, as seen by eligibility
    predicates and pack transforms. Built by ``compile_plan`` (and rebuilt
    from a serialized plan row, so it must stay JSON-representable)."""

    path: str                 # '/'-joined tree path, e.g. "conv/3/kernel"
    index: int                # leaf position in tree order (PRNG folding)
    shape: tuple[int, ...]
    is_conv: bool             # 4-D conv-stack kernel (policy.is_conv_kernel)
    selected: bool            # weight policy selects this path
    xnor_selected: bool       # xnor (activation) policy also selects it
    mode: str                 # requested engine mode: det | stoch | xnor
    xnor_boundary: bool = False  # excluded because its input is real-valued

    @property
    def ndim(self) -> int:
        return len(self.shape)


@dataclasses.dataclass(frozen=True)
class PackContext:
    """Per-``pack`` call arguments shared by all leaves (PRNG key for
    stochastic binarization, scale storage)."""

    weight_mode: Any          # BinarizeMode for the weight values
    key: Any = None
    with_scale: bool = True


@dataclasses.dataclass(frozen=True)
class BackendSpec:
    name: str
    kinds: tuple[str, ...]    # apply seams served: ("linear",) / ("conv",)
    priority: int             # higher wins among eligible backends
    leaf_type: Optional[type]  # serving leaf class; None = plain array
    eligible: EligibilityFn
    pack: Callable[[LeafContext, Any, PackContext], Any]
    apply: Callable[..., Any]
    # (m, k, n) -> {"bytes": ..., "ops": ...}; may accept shape=/with_scale=
    # keywords (plan_report passes them when the signature allows)
    cost: Callable[..., dict]
    doc: str = ""
    # Master-weight dim tensor-parallel-sharded over the "model" mesh axis
    # (negative = from the end). Bitpacked backends use -1 (the N /
    # out-channel dim) so the int32 word dim is never split across devices
    # — a sharded word would split a 32-bit lane group. None = no fixed TP
    # dim; the plan compiler falls back to the Megatron path rules
    # (repro.distributed.sharding.leaf_pspec).
    tp_dim: Optional[int] = None
    # Master-weight *contraction* dim this backend can shard over "model"
    # for Megatron row-parallel projections (the leaves whose path rule
    # puts "model" on the input dim: w_o / wo / w_down / out_proj). The
    # packed word dim then splits as whole int32 words — a 32-bit lane
    # group still never crosses a device — and the matmul finishes with
    # one all-reduce of partial sums instead of an activation
    # gather/re-scatter. Only exact-accumulation backends should set this:
    # integer popcount partial sums all-reduce bit-exactly, while f32
    # partial sums could change summation order vs a single device.
    tp_contract_dim: Optional[int] = None


_REGISTRY: dict[str, BackendSpec] = {}
_LEAF_DISPATCH: dict[tuple[str, type], BackendSpec] = {}


def register_backend(spec: BackendSpec) -> BackendSpec:
    """Adds (or replaces) a backend. Returns the spec for chaining."""
    old = _REGISTRY.get(spec.name)
    if old is not None:  # drop the replaced spec's leaf-dispatch entries
        for key in [k for k, v in _LEAF_DISPATCH.items() if v is old]:
            del _LEAF_DISPATCH[key]
    _REGISTRY[spec.name] = spec
    if spec.leaf_type is not None:
        for kind in spec.kinds:
            _LEAF_DISPATCH[(kind, spec.leaf_type)] = spec
    return spec


def unregister_backend(name: str) -> None:
    """Removes a backend and its leaf-dispatch entries (no-op if absent)."""
    old = _REGISTRY.pop(name, None)
    if old is not None:
        for key in [k for k, v in _LEAF_DISPATCH.items() if v is old]:
            del _LEAF_DISPATCH[key]


def get_backend(name: str) -> BackendSpec:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown backend {name!r}; registered: {sorted(_REGISTRY)}"
        ) from None


def backend_names() -> list[str]:
    return [s.name for s in backends()]


def backends(kind: str | None = None) -> list[BackendSpec]:
    """All registered backends, highest priority first."""
    specs = [s for s in _REGISTRY.values()
             if kind is None or kind in s.kinds]
    return sorted(specs, key=lambda s: -s.priority)


def backend_for_leaf(leaf: Any, kind: str) -> BackendSpec:
    """Type-based dispatch used by ``apply_linear``/``apply_conv2d``: the
    leaf class selects its backend; anything unregistered is dense."""
    spec = _LEAF_DISPATCH.get((kind, type(leaf)))
    return spec if spec is not None else _REGISTRY["dense"]


def serving_leaf_types() -> tuple[type, ...]:
    """Every leaf class some registered backend produces — the node types
    mesh placement (``distributed.sharding.place_packed_params``) must
    treat atomically, built-ins and user registrations alike."""
    return tuple({s.leaf_type for s in _REGISTRY.values()
                  if s.leaf_type is not None})


def spec_for_serving_leaf(leaf: Any) -> Optional[BackendSpec]:
    """The BackendSpec whose ``leaf_type`` produced ``leaf`` (None for
    plain arrays / unregistered types), independent of kind."""
    for (kind, t), spec in _LEAF_DISPATCH.items():
        if t is type(leaf):
            return spec
    return None


def apply_linear(w: Any, x: Any) -> Any:
    """x @ w through whichever backend produced ``w`` (dense fallback)."""
    return backend_for_leaf(w, "linear").apply(w, x)


def apply_conv2d(w: Any, x: Any, *, stride=(1, 1), padding="SAME") -> Any:
    """conv2d(x, w) through whichever backend produced ``w``."""
    return backend_for_leaf(w, "conv").apply(w, x, stride=stride,
                                             padding=padding)
