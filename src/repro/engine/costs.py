"""Per-backend layer cost model: HBM bytes moved + op counts.

One source of truth for the bytes/ops arithmetic that the benchmarks
(``benchmarks/xnor_bench.py``, ``benchmarks/plan_bench.py``), the plan
report (``repro.engine.plan.plan_report``) and the roofline projections all
quote. Every cost is for one (M, K) x (K, N) GEMM application of a layer —
convolutions are costed at the im2col GEMM level, where K = kh*kw*C and
M = batch * OH * OW output positions.

Conventions (matching the serving kernels): activations stream at
``act_bytes`` per element (bf16 = 2), outputs are written at 4 bytes (f32
accumulator), packed tensors move 1 bit per element in int32 words, and
the optional per-channel BWN scale adds N * 4 bytes to the weight fetch.
"""
from __future__ import annotations

from repro.core import packing as wpack
from repro.core import roofline as R
from repro.xnor.conv.packing import patch_words


def dense_weight_bytes(shape: tuple[int, ...], act_bytes: int = 2) -> int:
    """bf16 storage of the full master/binarized-dense leaf."""
    n = 1
    for d in shape:
        n *= d
    return n * act_bytes


def packed_weight_bytes(shape: tuple[int, ...], *, conv: bool = False,
                        with_scale: bool = True, flat: bool = False) -> int:
    """int32 bitpacked storage (+ f32 scale) of a projection/conv leaf.

    Conv leaves default to the xnor per-tap word layout
    (kh*kw*ceil(C/32)); ``flat=True`` counts the packed_conv flat FC
    layout instead (ceil(kh*kw*C/32) — the two differ when C % 32 != 0)."""
    if conv:
        kh, kw, c, n = shape[-4:]
        if flat:
            k = kh * kw * c
            words = ((k + wpack.PACK - 1) // wpack.PACK) * n
        else:
            words = patch_words((kh, kw), c) * n
        lead = shape[:-4]
    else:
        k, n = shape[-2:]
        words = ((k + wpack.PACK - 1) // wpack.PACK) * n
        lead = shape[:-2]
    stack = 1
    for d in lead:
        stack *= d
    return stack * (words * 4 + (n * 4 if with_scale else 0))


def gemm_cost(backend: str, m: int, k: int, n: int, *,
              act_bytes: int = 2, with_scale: bool = True,
              shape: tuple[int, ...] | None = None) -> dict:
    """{"bytes": HBM bytes, "ops": MAC-equivalent ops} for one application.

    ``backend`` is a registry name; ``binarized_dense`` moves dense-width
    weights (its win is fidelity, not bytes), ``packed`` moves 1-bit
    weights but full-width activations, ``xnor``/``xnor_conv`` move 1-bit
    on both sides and replace the MXU dot with VPU popcount ops over 32x
    fewer words. Pass the conv leaf ``shape`` (kh, kw, C, N) for
    ``xnor_conv`` so words are counted in the engine's per-tap layout
    (kh*kw*ceil(C/32), matching ``packed_weight_bytes``) rather than the
    flat FC packing ceil(K/32) — they differ whenever C % 32 != 0.
    """
    out = m * n * 4
    act = m * k * act_bytes
    scale = n * 4 if with_scale else 0
    if backend in ("dense", "binarized_dense"):
        return {"bytes": k * n * act_bytes + act + out, "ops": 2 * m * k * n}
    if backend == "packed":
        return {"bytes": wpack.packed_nbytes((k, n)) + scale + act + out,
                "ops": 2 * m * k * n}
    if backend in ("xnor", "xnor_conv"):
        words = (k + wpack.PACK - 1) // wpack.PACK
        if backend == "xnor_conv" and shape is not None and len(shape) >= 4:
            kh, kw, c = shape[-4], shape[-3], shape[-2]
            words = patch_words((kh, kw), c)
        return {"bytes": words * n * 4 + scale + m * words * 4 + out,
                "ops": 2 * m * words * n}
    raise KeyError(f"no cost model for backend {backend!r}")


def roofline_seconds(backend: str, m: int, k: int, n: int, **kw) -> float:
    """max(bytes / HBM_BW, ops / peak) — the projected TPU time for one
    application; the binary paths' ops run at bf16-MXU-equivalent rate
    (VPU int32 popcount), matching ``benchmarks/xnor_bench.py``."""
    c = gemm_cost(backend, m, k, n, **kw)
    return max(c["bytes"] / R.HBM_BW, c["ops"] / R.PEAK_FLOPS_BF16)
