"""Execution-plan compiler: backend registry + per-layer dispatch plans.

The paper's nets are heterogeneous pipelines — real-valued first layers,
packed-weight binary-matmul layers, fully-binary XNOR layers — and the win
(FINN-style) comes from *compiling* a per-layer plan of which datapath each
layer gets, instead of hard-coding the boundary in pack/apply code. This
package makes the engine choice a first-class, inspectable artifact.

Architecture map::

    registry.py   BackendSpec + register_backend/get_backend/backends;
                  type-keyed apply dispatch (apply_linear / apply_conv2d)
                  used by models/layers — no isinstance chains anywhere.
    backends.py   The five built-ins, highest priority first:
                    xnor_conv        fully-binary im2col popcount conv
                    xnor             fully-binary FC (repro.xnor)
                    packed           bitpacked weights on the MXU engine
                    binarized_dense  Alg.-1 ±1 values stored densely (conv
                                     fallback — no packed conv lowering)
                    dense            full-width master weights
    plan.py       compile_plan(params, policy, mode, mesh=...) ->
                  ExecutionPlan: per-path backend + reason + full
                  eligibility map + sharding column (mesh placement of the
                  serving representation: binary backends TP-shard their
                  registered tp_dim — the out-channel dim — over "model";
                  dense leaves follow the Megatron path rules);
                  plan.pack(params) replaces the old pack_params monolith;
                  save()/load() JSON manifests; plan_report()/
                  format_plan_table() cost every layer under every
                  eligible backend.
    costs.py      Shared bytes/ops cost model (one source of truth for
                  benchmarks + roofline projections).

Registering a new backend (e.g. int4, stochastic-ensemble, fused BN-xnor)::

    from repro.engine import BackendSpec, register_backend
    register_backend(BackendSpec(
        name="int4", kinds=("linear",), priority=25, leaf_type=Int4Linear,
        eligible=lambda lc: (lc.selected and lc.ndim >= 2, "policy"),
        pack=pack_int4, apply=apply_int4, cost=cost_int4))

The plan compiler and the serving stack pick it up with no edits to
models/layers, serve/engine or launch/serve.

Plan manifest format (JSON, golden-checked in CI against
``benchmarks/golden_plans/*.json``; full schema in
``docs/PLAN_MANIFEST.md``)::

    {"version": 2, "mode": "xnor", "with_scale": true,
     "layers": [{"path": "conv/2/kernel", "index": 8,
                 "shape": [3, 3, 128, 256], "backend": "xnor_conv",
                 "reason": "selected",
                 "eligible": {"xnor_conv": "ok", "binarized_dense": "ok",
                              "dense": "ok"},
                 "sharding": [null, null, null, "model"]}, ...]}

``repro.distributed.sharding.place_packed_params(mesh, packed, plan)``
applies the sharding column to a packed tree;
``serve.ServeEngine(cfg, packed, mesh=mesh, plan=plan)`` does it for you
and serves tensor-parallel with bit-identical greedy streams.
"""
from repro.engine.backends import (BINARIZED_DENSE, DENSE, PACKED, XNOR,
                                   XNOR_CONV)
from repro.engine.plan import (ExecutionPlan, LayerAssignment, compile_plan,
                               format_plan_table, plan_report)
from repro.engine.registry import (BackendSpec, LeafContext, PackContext,
                                   backend_for_leaf, backend_names, backends,
                                   get_backend, register_backend,
                                   unregister_backend)

__all__ = [
    "BackendSpec", "LeafContext", "PackContext", "ExecutionPlan",
    "LayerAssignment", "compile_plan", "plan_report", "format_plan_table",
    "register_backend", "unregister_backend", "get_backend", "backends",
    "backend_names", "backend_for_leaf", "DENSE", "PACKED", "XNOR",
    "XNOR_CONV", "BINARIZED_DENSE",
]
