"""Built-in layer backends: dense, packed, xnor, xnor_conv, binarized_dense.

Each backend bundles the eligibility rule, pack transform, apply
implementation and cost model for one datapath and registers itself with
``repro.engine.registry``. The pack transforms are bit-for-bit the ones the
legacy ``serve.engine.pack_params`` monolith applied (same PRNG key folding
by leaf index, same scale axes), so a compiled plan packs a tree into
exactly the pytree the old code produced.

Priority order (highest wins among eligible):

  xnor_conv (40) > xnor (30) > packed (20) > packed_conv (15)
    > binarized_dense (10) > dense (0)

To add backend N+1, write these four functions and call
``register_backend`` — no edits to models/layers, serve/engine or the plan
compiler are needed.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import binarize as B
from repro.core.binarize import BinarizeMode
from repro.core.packing import PACK
from repro.engine import costs
from repro.engine.registry import (BackendSpec, LeafContext, PackContext,
                                   register_backend)
from repro.models.layers import (PackedConv, PackedLinear, XnorConv,
                                 XnorLinear)


# ---------------------------------------------------------------------------
# eligibility predicates
# ---------------------------------------------------------------------------

def _dense_eligible(lc: LeafContext) -> tuple[bool, str]:
    return True, "ok"


def _packable(lc: LeafContext) -> tuple[bool, str]:
    """Shared gate for the bitpacked-weight matmul backends."""
    if not lc.selected:
        return False, "policy-excluded"
    if lc.is_conv:
        return False, "conv kernel (no packed-weight MXU conv lowering)"
    if lc.ndim < 2:
        return False, f"ndim={lc.ndim} < 2 (not matmul-shaped)"
    if lc.shape[-2] % PACK != 0:
        return False, f"K={lc.shape[-2]} % {PACK} != 0"
    return True, "ok"


def _xnor_gate(lc: LeafContext) -> tuple[bool, str]:
    """Shared mode/activation-policy gate for the fully-binary backends."""
    if lc.mode != "xnor":
        return False, f"mode={lc.mode} != xnor"
    if not lc.xnor_selected:
        return False, ("xnor-policy-excluded (real-valued-input boundary)"
                       if lc.xnor_boundary else "xnor-policy-excluded")
    return True, "ok"


def _xnor_eligible(lc: LeafContext) -> tuple[bool, str]:
    ok, why = _packable(lc)
    if not ok:
        return ok, why
    return _xnor_gate(lc)


def _conv_selected(lc: LeafContext) -> tuple[bool, str]:
    if not lc.is_conv:
        return False, "not a conv-stack kernel"
    if not lc.selected:
        return False, "policy-excluded"
    return True, "ok"


def _xnor_conv_eligible(lc: LeafContext) -> tuple[bool, str]:
    ok, why = _conv_selected(lc)
    if not ok:
        return ok, why
    return _xnor_gate(lc)


def _packed_conv_eligible(lc: LeafContext) -> tuple[bool, str]:
    """Bitpacked conv weights, stoch mode only: in det/xnor mode the dense
    binarized_dense fallback costs the same bytes per single sample, but a
    K-replica stochastic ensemble (repro.stoch) needs 1-bit storage so K
    replicas stay ~K/16 of one bf16 kernel."""
    ok, why = _conv_selected(lc)
    if not ok:
        return ok, why
    if lc.mode != "stoch":
        return False, f"mode={lc.mode} != stoch (dense ±1 fallback is free)"
    return True, "ok"


# ---------------------------------------------------------------------------
# pack transforms (bit-identical to the legacy pack_params monolith)
# ---------------------------------------------------------------------------

def _pack_dense(lc: LeafContext, leaf, pc: PackContext):
    return leaf


def _missing_key_error(lc: LeafContext) -> ValueError:
    """Actionable 'no PRNG key' error naming the exact leaf that failed."""
    return ValueError(
        f"stochastic packing requires a PRNG key, but none was supplied "
        f"for leaf {lc.path!r} (leaf index {lc.index}): pass "
        f"key=jax.random.key(seed) to plan.pack(...) / pack_params(...), "
        f"or compile the plan with mode='det' for keyless deterministic "
        f"binarization")


def _binarize_values(lc: LeafContext, leaf, pc: PackContext):
    if pc.weight_mode is BinarizeMode.STOCHASTIC:
        if pc.key is None:
            raise _missing_key_error(lc)
        return B.stochastic_binarize(leaf, jax.random.fold_in(pc.key, lc.index))
    return B.deterministic_binarize(leaf)


def _pack_binarized_dense(lc: LeafContext, leaf, pc: PackContext):
    """Binarized values (±1 [* alpha]) kept in dense array form — the Alg.-1
    inference network for conv layers with no bitpacked lowering."""
    scale = None
    if pc.with_scale:
        scale = jnp.mean(jnp.abs(leaf.astype(jnp.float32)), axis=(0, 1, 2))
    wb = _binarize_values(lc, leaf, pc)
    if scale is not None:
        wb = (wb.astype(jnp.float32) * scale).astype(leaf.dtype)
    return wb


def _pack_linear(cls, lc: LeafContext, leaf, pc: PackContext):
    """Binarize + bitpack a (..., K, N) projection into ``cls``. Stacked
    leaves (L, K, N) pack per layer via vmap so ``lax.scan`` slices the
    result exactly like dense leaves."""
    from repro.kernels import ops as kops

    k_dim, n_dim = leaf.shape[-2], leaf.shape[-1]
    lead = leaf.shape[:-2]
    w2 = leaf.reshape((-1, k_dim, n_dim))
    if pc.weight_mode is BinarizeMode.STOCHASTIC:
        if pc.key is None:
            raise _missing_key_error(lc)
        ks = jax.random.split(jax.random.fold_in(pc.key, lc.index),
                              w2.shape[0])
        packed = jax.vmap(
            lambda w, kk: kops.binarize_and_pack(w, kk, stochastic=True)
        )(w2, ks)
    else:
        packed = jax.vmap(
            lambda w: kops.binarize_and_pack(w, stochastic=False))(w2)
    scale = None
    if pc.with_scale:
        scale = jnp.mean(jnp.abs(w2.astype(jnp.float32)), axis=1)  # (-1, N)
        scale = scale.reshape(lead + (n_dim,))
    packed = packed.reshape(lead + (k_dim // PACK, n_dim))
    return cls(packed, scale, k_dim)


def _pack_packed_conv(lc: LeafContext, leaf, pc: PackContext):
    """Binarize + bitpack a (kh, kw, C, N) conv kernel along the flattened
    kh*kw*C axis (flat FC word layout; ops.py pads the ragged last word
    with self-cancelling +1/-1 pairs, and apply slices back to the true K).
    Stoch-mode only, so the key is mandatory."""
    from repro.kernels import ops as kops

    if pc.key is None:
        raise _missing_key_error(lc)
    kh, kw, c_in, n_dim = leaf.shape
    scale = None
    if pc.with_scale:
        scale = jnp.mean(jnp.abs(leaf.astype(jnp.float32)), axis=(0, 1, 2))
    w2 = leaf.reshape((kh * kw * c_in, n_dim))
    packed = kops.binarize_and_pack(
        w2, jax.random.fold_in(pc.key, lc.index), stochastic=True)
    return PackedConv(packed, scale, (kh, kw), c_in)


def _pack_xnor_conv(lc: LeafContext, leaf, pc: PackContext):
    from repro.xnor.conv import pack_conv_kernel

    scale = None
    if pc.with_scale:
        scale = jnp.mean(jnp.abs(leaf.astype(jnp.float32)), axis=(0, 1, 2))
    kh, kw, c_in, _ = leaf.shape
    return XnorConv(pack_conv_kernel(leaf), scale, (kh, kw), c_in)


# ---------------------------------------------------------------------------
# apply implementations
# ---------------------------------------------------------------------------

def _apply_dense(w, x, *, stride=None, padding=None):
    if stride is None:
        return jnp.dot(x, w.astype(x.dtype))
    return jax.lax.conv_general_dilated(
        x, w.astype(x.dtype), window_strides=stride, padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


def _apply_packed(w: PackedLinear, x):
    from repro.kernels import ops

    out = ops.binary_matmul(x, w.packed, w.scale, out_dtype=jnp.float32)
    return out.astype(x.dtype)


def _apply_xnor(w: XnorLinear, x):
    from repro.xnor import ops as xops

    out = xops.xnor_matmul(x, w.packed, w.scale, k=w.k, out_dtype=jnp.float32)
    return out.astype(x.dtype)


def _apply_packed_conv(w: PackedConv, x, *, stride=(1, 1), padding="SAME"):
    from repro.core.packing import unpack_bits

    kh, kw = w.ksize
    n_dim = w.packed.shape[-1]
    wb = unpack_bits(w.packed, dtype=jnp.float32)[: w.k]  # drop ragged pad
    if w.scale is not None:
        wb = wb * w.scale.astype(jnp.float32)[None, :]
    wk = wb.reshape(kh, kw, w.c_in, n_dim)
    return _apply_dense(wk, x, stride=stride, padding=padding)


def _apply_xnor_conv(w: XnorConv, x, *, stride=(1, 1), padding="SAME"):
    from repro.xnor.conv import ops as cops

    out = cops.xnor_conv2d(x, w.packed, w.scale, ksize=w.ksize, c_in=w.c_in,
                           stride=stride, padding=padding,
                           out_dtype=jnp.float32)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# registration
# ---------------------------------------------------------------------------

DENSE = register_backend(BackendSpec(
    name="dense", kinds=("linear", "conv"), priority=0, leaf_type=None,
    eligible=_dense_eligible, pack=_pack_dense, apply=_apply_dense,
    cost=functools.partial(costs.gemm_cost, "dense"),
    doc="Full-width master weights on the MXU (matmul or lax.conv)."))

BINARIZED_DENSE = register_backend(BackendSpec(
    name="binarized_dense", kinds=("conv",), priority=10, leaf_type=None,
    eligible=_conv_selected, pack=_pack_binarized_dense, apply=_apply_dense,
    cost=functools.partial(costs.gemm_cost, "binarized_dense"),
    tp_dim=-1,
    doc="Conv fallback: Alg.-1 binarized values (±1 [* alpha]) stored "
        "densely; runs on the ordinary conv path."))

PACKED_CONV = register_backend(BackendSpec(
    name="packed_conv", kinds=("conv",), priority=15, leaf_type=PackedConv,
    eligible=_packed_conv_eligible, pack=_pack_packed_conv,
    apply=_apply_packed_conv,
    cost=functools.partial(costs.gemm_cost, "packed"),
    tp_dim=-1,
    doc="Stoch-mode conv: binary kernel bitpacked along flattened kh*kw*C "
        "(1-bit storage), unpacked to ±1 [* alpha] at apply time onto the "
        "ordinary conv path — makes K-replica ensembles (repro.stoch) "
        "affordable for conv nets."))

PACKED = register_backend(BackendSpec(
    name="packed", kinds=("linear",), priority=20, leaf_type=PackedLinear,
    eligible=_packable,
    pack=functools.partial(_pack_linear, PackedLinear), apply=_apply_packed,
    cost=functools.partial(costs.gemm_cost, "packed"),
    tp_dim=-1,
    doc="Bitpacked binary weights, full-width activations: the MXU "
        "binary-matmul engine (repro.kernels)."))

XNOR = register_backend(BackendSpec(
    name="xnor", kinds=("linear",), priority=30, leaf_type=XnorLinear,
    eligible=_xnor_eligible,
    pack=functools.partial(_pack_linear, XnorLinear), apply=_apply_xnor,
    cost=functools.partial(costs.gemm_cost, "xnor"),
    # Row-parallel contraction sharding is exact for xnor: the partial
    # popcount sums all-reduce in int32, so sharded streams stay
    # bit-identical to single-device. The f32-accumulating packed backend
    # deliberately does NOT set tp_contract_dim.
    tp_dim=-1, tp_contract_dim=-2,
    doc="Fully-binary FC: binary weights AND sign-packed activations, "
        "XNOR-popcount dot (repro.xnor)."))

XNOR_CONV = register_backend(BackendSpec(
    name="xnor_conv", kinds=("conv",), priority=40, leaf_type=XnorConv,
    eligible=_xnor_conv_eligible, pack=_pack_xnor_conv,
    apply=_apply_xnor_conv,
    cost=functools.partial(costs.gemm_cost, "xnor_conv"),
    tp_dim=-1,
    doc="Fully-binary conv: packed im2col patches + popcount GEMM "
        "(repro.xnor.conv)."))
