"""Execution-plan compiler: per-layer backend assignment as a first-class,
serializable artifact.

``compile_plan(params, policy, mode)`` walks the parameter tree once, asks
every registered backend (``repro.engine.registry``) whether it can serve
each leaf, and records — for *every* leaf — the assigned backend, the
reason, and the full eligibility map. The resulting :class:`ExecutionPlan`

* packs a parameter tree (``plan.pack(params, key=...)``) into exactly the
  pytree the legacy ``pack_params`` monolith produced,
* serializes to a JSON manifest (``save``/``load``) that is golden-checked
  in CI (``benchmarks/golden_plans``) so dispatch-boundary regressions fail
  loudly,
* supports per-layer overrides (``overrides={"conv/3": "binarized_dense"}``
  — keys match a leaf path exactly or as a '/'-prefix),
* records a per-row *sharding column* (mesh placement of the serving
  representation: packed word tensors TP-sharded on the out-channel dim
  over "model", dense leaves on the Megatron rules) that
  ``repro.distributed.sharding.place_packed_params`` applies at serve time,
* feeds ``plan_report`` which costs every layer under every eligible
  backend (one source of truth for benchmarks and the roofline numbers).

Silent fallthroughs are gone: a policy-selected leaf that no binary backend
can serve (K % 32 != 0, ndim < 2) is assigned ``dense`` with the blocking
reason recorded in its row, and ``compile_plan`` warns once per compile.
"""
from __future__ import annotations

import dataclasses
import json
import warnings
from typing import Mapping, Optional

import jax

from repro.core.binarize import BinarizeMode, _path_str
from repro.engine import backends as _backends  # noqa: F401  (registers)
from repro.engine import registry

PLAN_VERSION = 3

#: Manifest versions ``from_json`` accepts. v1 rows predate the sharding
#: column (loaded with ``sharding=None``; placement falls back to the
#: leaf-type rules in ``repro.distributed.sharding``). v2 manifests predate
#: the ensemble ``replica_axis`` field (loaded with ``replica_axis=None``,
#: i.e. replicated replicas).
_READABLE_VERSIONS = (1, 2, PLAN_VERSION)

@dataclasses.dataclass
class LayerAssignment:
    """One plan row: which backend serves the leaf at ``path`` and why."""

    path: str
    index: int                     # leaf position in tree order (PRNG fold)
    shape: tuple[int, ...]
    backend: str
    reason: str
    eligible: dict[str, str]       # backend -> "ok" | why-not
    # Mesh placement of the *master-shape* leaf: one entry per dim, each
    # None | axis-name | [axis-names]. Binary backends put "model" on the
    # out-channel dim (tp_dim); dense leaves follow the Megatron path
    # rules. None on a whole row = unannotated (a v1 manifest).
    sharding: Optional[list] = None

    @property
    def pspec(self):
        """The row's sharding column as a ``jax.sharding.PartitionSpec``
        over the master shape (None if the row is unannotated)."""
        if self.sharding is None:
            return None
        from repro.distributed.sharding import spec_from_json

        return spec_from_json(self.sharding)

    def to_json(self) -> dict:
        return {"path": self.path, "index": self.index,
                "shape": list(self.shape), "backend": self.backend,
                "reason": self.reason, "eligible": dict(self.eligible),
                "sharding": self.sharding}

    @classmethod
    def from_json(cls, d: dict) -> "LayerAssignment":
        return cls(path=d["path"], index=int(d["index"]),
                   shape=tuple(int(s) for s in d["shape"]),
                   backend=d["backend"], reason=d["reason"],
                   eligible=dict(d["eligible"]),
                   sharding=d.get("sharding"))


@dataclasses.dataclass
class ExecutionPlan:
    """Explicit per-path backend assignment for one parameter tree."""

    mode: str                      # det | stoch | xnor (engine mode)
    with_scale: bool
    layers: list[LayerAssignment]
    # Mesh axis the ensemble replica dim (repro.stoch) shards over — "data",
    # "model", or None for replicated replicas. Rides the manifest (v3+) so
    # a loaded plan reproduces the same ensemble placement.
    replica_axis: Optional[str] = None
    version: int = PLAN_VERSION

    # -- queries ----------------------------------------------------------
    def __getitem__(self, path: str) -> LayerAssignment:
        for a in self.layers:
            if a.path == path:
                return a
        raise KeyError(path)

    def assignments(self, backend: str | None = None) -> list[LayerAssignment]:
        return [a for a in self.layers
                if backend is None or a.backend == backend]

    def fallthroughs(self) -> list[LayerAssignment]:
        """Policy-selected leaves that no binary backend could serve."""
        return [a for a in self.layers if a.reason.startswith("cannot pack")]

    def stochastic_rows(self) -> list[LayerAssignment]:
        """Rows whose pack transform consumes the stochastic PRNG key —
        exactly the leaves ``repro.stoch.sample_replicas`` re-draws per
        replica. Empty unless the plan mode is "stoch" (det/xnor packs are
        keyless, so every replica would be identical)."""
        if self.mode != "stoch":
            return []
        return [a for a in self.layers if a.backend != "dense"]

    #: Leaf basenames that are elementwise parameters, not projections —
    #: stacked (L, D) norm scales/biases clear ndim >= 2 but are never
    #: matmul applications.
    _ELEMENTWISE = ("scale", "bias", "b", "beta", "gamma")

    def compute_rows(self) -> list[LayerAssignment]:
        """Rows that are matmul/conv applications (ndim >= 2) — the ones
        whose sharding column implies collectives; scales/biases/norms
        are excluded."""
        return [a for a in self.layers
                if len(a.shape) >= 2
                and a.path.rsplit("/", 1)[-1] not in self._ELEMENTWISE]

    def sharding_axes(self) -> set[str]:
        """Every mesh axis name the manifest's sharding columns (and the
        ensemble ``replica_axis``) reference."""
        axes: set[str] = set()
        for a in self.layers:
            for entry in a.sharding or ():
                if entry is None:
                    continue
                names = (entry if isinstance(entry, (list, tuple))
                         else [entry])
                axes.update(n for n in names if n is not None)
        if self.replica_axis is not None:
            axes.add(self.replica_axis)
        return axes

    def lint(self, *, mesh_axes=None, axis_sizes=None):
        """Static verification of this manifest —
        :func:`repro.analysis.lint_plan` (see docs/ANALYSIS.md for the
        rule catalogue). Returns a list of Findings; empty = clean."""
        from repro.analysis import lint_plan

        return lint_plan(self, mesh_axes=mesh_axes, axis_sizes=axis_sizes)

    # -- packing ----------------------------------------------------------
    def pack(self, params, key: Optional[jax.Array] = None):
        """Applies each row's backend ``pack`` transform to its leaf.

        The tree must match the plan leaf-for-leaf (path and shape); a
        mismatch raises instead of silently mis-dispatching."""
        leaves = jax.tree_util.tree_leaves_with_path(params)
        if len(leaves) != len(self.layers):
            raise ValueError(
                f"plan/params mismatch: plan has {len(self.layers)} leaves, "
                f"params has {len(leaves)}")
        weight_mode = (BinarizeMode.STOCHASTIC
                       if BinarizeMode.parse("det" if self.mode == "xnor"
                                             else self.mode)
                       is BinarizeMode.STOCHASTIC
                       else BinarizeMode.DETERMINISTIC)
        pc = registry.PackContext(weight_mode=weight_mode, key=key,
                                  with_scale=self.with_scale)
        out = []
        for a, (path, leaf) in zip(self.layers, leaves):
            s = _path_str(path)
            if s != a.path:
                raise ValueError(
                    f"plan/params mismatch at leaf {a.index}: plan has "
                    f"{a.path!r}, params has {s!r}")
            if tuple(getattr(leaf, "shape", ())) != a.shape:
                raise ValueError(
                    f"plan/params shape mismatch at {a.path!r}: plan has "
                    f"{a.shape}, params has {tuple(leaf.shape)}")
            lc = _leaf_context(a, self.mode)
            out.append(registry.get_backend(a.backend).pack(lc, leaf, pc))
        treedef = jax.tree_util.tree_structure(params)
        return jax.tree_util.tree_unflatten(treedef, out)

    # -- serialization ----------------------------------------------------
    def to_json(self) -> dict:
        return {"version": self.version, "mode": self.mode,
                "with_scale": self.with_scale,
                "replica_axis": self.replica_axis,
                "layers": [a.to_json() for a in self.layers]}

    @classmethod
    def from_json(cls, d: dict) -> "ExecutionPlan":
        if d.get("version") not in _READABLE_VERSIONS:
            raise ValueError(f"unsupported plan version {d.get('version')!r} "
                             f"(expected one of {_READABLE_VERSIONS})")
        return cls(mode=d["mode"], with_scale=bool(d["with_scale"]),
                   layers=[LayerAssignment.from_json(a) for a in d["layers"]],
                   replica_axis=d.get("replica_axis"),
                   version=int(d["version"]))

    def save(self, path: str) -> str:
        with open(path, "w") as f:
            json.dump(self.to_json(), f, indent=1, sort_keys=True)
            f.write("\n")
        return path

    @classmethod
    def load(cls, path: str) -> "ExecutionPlan":
        with open(path) as f:
            return cls.from_json(json.load(f))


def _leaf_context(a: LayerAssignment, mode: str) -> registry.LeafContext:
    """Rebuilds the pack-time context from a plan row. The built-in pack
    transforms only consume path/index/shape (plus the PackContext); the
    policy facts are re-derived from the recorded *eligibility map* — a
    backend reports "policy-excluded" iff the weight policy skipped the
    leaf, and the xnor-kind backend reports "ok" iff the activation policy
    selected it — so a loaded plan packs identically to a fresh compile."""
    is_conv = len(a.shape) == 4 and "xnor_conv" in a.eligible
    policy_probe = a.eligible.get("binarized_dense" if is_conv else "packed",
                                  "policy-excluded")
    xnor_probe = a.eligible.get("xnor_conv" if is_conv else "xnor", "")
    return registry.LeafContext(
        path=a.path, index=a.index, shape=a.shape, is_conv=is_conv,
        selected="policy-excluded" not in policy_probe,
        xnor_selected=xnor_probe == "ok",
        mode=mode)


# ---------------------------------------------------------------------------
# compilation
# ---------------------------------------------------------------------------

def _match_override(overrides: Mapping[str, str],
                    path: str) -> tuple[str, str] | None:
    """Longest-prefix override lookup: a key matches ``path`` exactly or as
    a leading '/'-separated prefix (``conv/3`` matches ``conv/3/kernel``).
    Returns (pattern, backend) or None."""
    best, best_len = None, -1
    for pat, backend in overrides.items():
        if (path == pat or path.startswith(pat + "/")) and len(pat) > best_len:
            best, best_len = (pat, backend), len(pat)
    return best


def _row_sharding(path: str, shape: tuple, backend: str, mesh) -> list:
    """The sharding column for one plan row: binary backends TP-shard their
    registered ``tp_dim`` (the N / out-channel dim — the packed int32 word
    dim is never split, so a 32-bit lane group never crosses a device
    boundary), except row-parallel projections of backends declaring a
    ``tp_contract_dim``, which shard the contraction/word dim instead
    (whole int32 words; one all-reduce of exact partial popcount sums —
    see ``repro.distributed.sharding.backend_leaf_spec``); dense leaves
    follow the Megatron path rules. With a concrete ``mesh``, axes the mesh
    cannot honour (missing name, non-divisible dim) are dropped to
    replicated."""
    from repro.distributed import sharding as SH

    ndim = len(shape)
    spec = SH.backend_leaf_spec(path, ndim, registry.get_backend(backend))
    if spec is None:
        spec = SH.leaf_pspec(path, ndim)
    if mesh is not None:
        spec = SH.sanitize_spec(mesh, spec, shape)
    return SH.spec_to_json(spec)


def compile_plan(params, policy, mode: str | BinarizeMode = "det", *,
                 xnor_policy=None, with_scale: bool = True,
                 overrides: Optional[Mapping[str, str]] = None,
                 mesh=None, replica_axis: Optional[str] = None,
                 warn: bool = True) -> ExecutionPlan:
    """Assigns every leaf of ``params`` the highest-priority eligible
    backend under ``policy``/``mode`` and returns the explicit plan.

    ``mode="xnor"`` enables the fully-binary backends for leaves also
    selected by ``xnor_policy`` (default ``core.policy.XNOR_POLICY``);
    weights still binarize deterministically (Eq. 1). ``overrides`` forces
    named paths (exact or prefix) onto a specific backend — the override
    must still be eligible, except ``dense`` which is always allowed.

    Every row also records a *sharding column*: the mesh placement of the
    layer's serving representation (binary backends TP-shard the
    out-channel dim over "model"; dense leaves follow the Megatron rules).
    The column is mesh-independent axis names by default; passing a
    concrete ``mesh`` (``jax.sharding.Mesh``) validates it — axes the mesh
    cannot honour are downgraded to replicated in the recorded plan.
    ``repro.distributed.sharding.place_packed_params(mesh, packed, plan)``
    applies the column to a packed tree.

    ``replica_axis`` names the mesh axis an ensemble replica dim
    (``repro.stoch.sample_replicas``) shards over — "data", "model", or
    None (replicated). It is recorded in the manifest (v3) and consumed by
    ``repro.stoch.place_replicas``; with a concrete ``mesh`` an unknown
    axis name raises immediately instead of at placement time.
    """
    mode_str = mode.value if isinstance(mode, BinarizeMode) else str(mode)
    if mode_str != "xnor":
        BinarizeMode.parse(mode_str)  # validate early
    if xnor_policy is None:
        from repro.core.policy import XNOR_POLICY as xnor_policy
    from repro.core.policy import is_conv_kernel, is_xnor_boundary

    rows: list[LayerAssignment] = []
    override_used = {pat: False for pat in (overrides or ())}
    xnor = mode_str == "xnor"
    for i, (path, leaf) in enumerate(
            jax.tree_util.tree_leaves_with_path(params)):
        s = _path_str(path)
        shape = tuple(getattr(leaf, "shape", ()))
        selected = policy.selects(s)
        lc = registry.LeafContext(
            path=s, index=i, shape=shape,
            is_conv=is_conv_kernel(s) and len(shape) == 4,
            selected=selected,
            xnor_selected=bool(xnor and xnor_policy.selects(s)),
            mode=mode_str, xnor_boundary=is_xnor_boundary(s))
        kind = "conv" if lc.is_conv else "linear"
        elig: dict[str, str] = {}
        chosen: str | None = None
        for spec in registry.backends(kind):
            ok, why = spec.eligible(lc)
            elig[spec.name] = "ok" if ok else why
            if ok and chosen is None:
                chosen = spec.name
        if chosen is None:       # unreachable: dense is always eligible
            chosen = "dense"
        reason = _reason(lc, chosen, elig)
        if reason == "policy-excluded":
            pat = getattr(policy, "excluded_by", lambda _: None)(s)
            if pat:
                reason = f"policy-excluded (pattern {pat!r})"
        if overrides:
            hit = _match_override(overrides, s)
            if hit is not None:
                pat, forced = hit
                spec = registry.get_backend(forced)  # raises on unknown name
                applicable = (kind in spec.kinds
                              and (forced == "dense"
                                   or elig.get(forced) == "ok"))
                if applicable:
                    override_used[pat] = True
                    chosen, reason = forced, f"override ({chosen} -> {forced})"
                elif pat == s:
                    # exact-path overrides validate strictly; a '/'-prefix
                    # match (a whole layer dict: kernel + bias + bn) only
                    # retargets the leaves the backend can actually serve
                    why = (elig.get(forced) if kind in spec.kinds else
                           f"backend serves {spec.kinds}, leaf is {kind}")
                    raise ValueError(
                        f"override {s!r} -> {forced!r}: ineligible ({why})")
        rows.append(LayerAssignment(
            path=s, index=i, shape=shape, backend=chosen, reason=reason,
            eligible=elig,
            sharding=_row_sharding(s, shape, chosen, mesh)))
    unused = [pat for pat, used in override_used.items() if not used]
    if unused:
        raise ValueError(
            f"overrides matched no applicable leaf: {unused} (paths are "
            f"'/'-joined, e.g. 'conv/3' or 'conv/3/kernel')")
    if (replica_axis is not None and mesh is not None
            and replica_axis not in mesh.axis_names):
        raise ValueError(
            f"replica_axis {replica_axis!r} is not a mesh axis "
            f"(mesh has {tuple(mesh.axis_names)})")
    plan = ExecutionPlan(mode=mode_str, with_scale=with_scale, layers=rows,
                         replica_axis=replica_axis)
    if warn:
        _warn_fallthroughs(plan)
    return plan


def _reason(lc: registry.LeafContext, chosen: str, elig: dict) -> str:
    """Human-stable explanation for the assignment — in particular, *why* a
    policy-selected leaf did not land on a better backend."""
    if not lc.selected:
        return "policy-excluded"
    if chosen == "dense":
        # Selected but nothing binary could serve it: surface the blocker
        # (the old code fell through here silently).
        blocker = elig.get("xnor_conv" if lc.is_conv else "packed", "")
        return f"cannot pack: {blocker}"
    if chosen == "binarized_dense":
        return ("no packed-weight conv lowering"
                if lc.mode != "xnor"
                else elig.get("xnor_conv", "xnor-policy-excluded"))
    if chosen == "packed" and lc.mode == "xnor":
        return elig.get("xnor", "xnor-policy-excluded")
    return "selected"


def _warn_fallthroughs(plan: ExecutionPlan) -> None:
    bad = plan.fallthroughs()
    if bad:
        details = "; ".join(f"{a.path}: {a.reason}" for a in bad[:8])
        more = "" if len(bad) <= 8 else f" (+{len(bad) - 8} more)"
        warnings.warn(
            f"compile_plan: {len(bad)} policy-selected leaves cannot use a "
            f"binary backend and will serve dense — {details}{more}",
            UserWarning, stacklevel=3)


# ---------------------------------------------------------------------------
# reporting
# ---------------------------------------------------------------------------

def _accepts_cost_kwargs(fn) -> bool:
    """Whether a backend's cost callable takes the optional ``shape``/
    ``with_scale`` keywords (inspected, not probed, so a TypeError raised
    *inside* the function is never misread as a signature mismatch)."""
    import inspect

    try:
        params = inspect.signature(fn).parameters.values()
    except (TypeError, ValueError):  # C callables etc.: assume kwargs-able
        return True
    return any(p.kind is inspect.Parameter.VAR_KEYWORD
               or p.name in ("shape", "with_scale") for p in params)

def plan_report(plan: ExecutionPlan, *, batch: int = 8,
                full: bool = False, axis_sizes=None) -> list[dict]:
    """Costs every plan row under its assigned backend *and* every eligible
    alternative. ``batch`` is M, the GEMM rows per application. Note that a
    conv layer's im2col GEMM has one row per *output position*, so with the
    default (rows = request batch) the per-row ``costs`` of conv layers are
    per output position, not per image — pass ``batch * OH * OW`` for
    spatially-resolved numbers. The static ``weight_bytes`` columns do not
    depend on ``batch``.

    Every row also carries a ``collectives`` entry — what the row's
    sharding column *implies* per application (all-gather for an
    out-channel/TP split, all-reduce for a contraction split; None for
    unsharded rows). Pass ``axis_sizes`` (e.g. ``{"model": 4}`` or
    ``dict(zip(mesh.axis_names, mesh.devices.shape))``) to resolve the
    participant count; rows whose sharded axes have size 1 report None.
    This column is the static *prediction* — the measured per-step counts
    come from ``repro.obs.audit_engine`` (``launch.serve
    --audit-collectives``), which reads the compiled HLO.

    Returns one dict per row; by default only "interesting" rows (anything
    not an untouched policy-excluded dense leaf) are included."""
    from repro.engine import costs as C
    from repro.obs.collectives import predict_row_collective

    rows = []
    for a in plan.layers:
        if (not full and a.backend == "dense"
                and a.reason.startswith("policy-excluded")):
            continue
        if len(a.shape) >= 2:
            if len(a.shape) == 4:
                kh, kw, c, n = a.shape
                k = kh * kw * c
            else:
                k, n = a.shape[-2], a.shape[-1]
        else:
            k = n = 0
        cost_by_backend = {}
        for name, status in a.eligible.items():
            if status == "ok" and k:
                fn = registry.get_backend(name).cost
                if _accepts_cost_kwargs(fn):
                    cost_by_backend[name] = fn(batch, k, n, shape=a.shape,
                                               with_scale=plan.with_scale)
                else:  # custom backend with a bare (m, k, n) fn
                    cost_by_backend[name] = fn(batch, k, n)
        conv = len(a.shape) == 4
        rows.append({
            "path": a.path, "backend": a.backend, "reason": a.reason,
            "shape": list(a.shape), "k": k, "n": n,
            "weight_bytes_dense": C.dense_weight_bytes(a.shape)
            if a.shape else 0,
            "weight_bytes": (
                C.packed_weight_bytes(a.shape, conv=conv,
                                      with_scale=plan.with_scale,
                                      flat=a.backend == "packed_conv")
                if a.backend in ("packed", "xnor", "xnor_conv",
                                 "packed_conv")
                else C.dense_weight_bytes(a.shape) if a.shape else 0),
            "costs": cost_by_backend,
            "collectives": predict_row_collective(
                a.sharding, a.shape, batch=batch, axis_sizes=axis_sizes),
        })
    return rows


def _fmt_collective(c: Optional[dict]) -> str:
    """Short cell for the plan table: 'all-gather@model 2.0KB/app'."""
    if not c:
        return "-"
    axes = "+".join(c["axes"])
    parts = f" x{c['parts']}" if c.get("parts") else ""
    return f"{c['kind']}@{axes}{parts} {c['bytes_per_app'] / 1e3:.1f}KB/app"


def format_plan_table(rows: list[dict]) -> str:
    """Aligned text table: path | backend | K x N | weight bytes (dense ->
    assigned) | collectives (the sharding column's predicted per-app
    collective) | reason."""
    hdr = ("path", "backend", "KxN", "w-bytes dense->plan", "collectives",
           "reason")
    table = [hdr]
    for r in rows:
        ratio = (r["weight_bytes_dense"] / r["weight_bytes"]
                 if r["weight_bytes"] else 1.0)
        table.append((
            r["path"], r["backend"],
            f"{r['k']}x{r['n']}" if r["k"] else "-",
            f"{r['weight_bytes_dense']:,} -> {r['weight_bytes']:,} "
            f"({ratio:.1f}x)",
            _fmt_collective(r.get("collectives")),
            r["reason"]))
    widths = [max(len(row[i]) for row in table) for i in range(len(hdr))]
    lines = ["  ".join(c.ljust(w) for c, w in zip(row, widths)).rstrip()
             for row in table]
    lines.insert(1, "  ".join("-" * w for w in widths))
    return "\n".join(lines)
