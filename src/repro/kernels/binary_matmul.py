"""Pallas TPU kernel: matmul against bitpacked binary weights.

The paper's FPGA kernels replace multiply-accumulate with sign-controlled
accumulation because binarized weights are {-1,+1}. The TPU adaptation keeps
the MXU (a matmul is free once operands are in VMEM) and instead attacks the
*memory hierarchy*: weights live in HBM bitpacked (32 weights / int32 word,
16x fewer bytes than bf16), are unpacked to ±1 *inside VMEM per block*, and
fed to the MXU as bf16. The weight-fetch term of the roofline drops ~16x,
which is the dominant term for decode/serving shapes.

Layout: activations  x        (M, K)        bf16/f32
        weights      w_packed (K // 32, N)  int32   (see core.packing)
        scale        optional (N,) f32      (per-output-channel, folds BN/BWN alpha)
        out                   (M, N)        f32 or x.dtype

Block shapes are MXU-aligned: bm, bn multiples of 128 (the systolic array
edge), bk a multiple of 256 so the packed block (bk//32, bn) keeps the int32
sublane dimension >= 8. The f32 accumulator lives in a VMEM scratch buffer
across the K grid dimension.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.compat import CompilerParams as _CompilerParams
from repro.core.packing import PACK


def _unpack_block(words: jax.Array, bk: int, dtype) -> jax.Array:
    """(bk//32, bn) int32 -> (bk, bn) ±1 in ``dtype`` (VMEM-local)."""
    w = words.astype(jnp.uint32)
    shifts = jnp.arange(PACK, dtype=jnp.uint32)[None, :, None]
    bits = (w[:, None, :] >> shifts) & jnp.uint32(1)
    pm1 = 2.0 * bits.astype(jnp.float32) - 1.0
    return pm1.reshape(bk, words.shape[-1]).astype(dtype)


def _bmm_kernel(x_ref, wp_ref, o_ref, acc_ref, *, nk: int, bk: int, compute_dtype):
    """Grid (i, j, k): accumulate x[i,k] @ unpack(wp[k,j]) into acc; flush at k end."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    w_block = _unpack_block(wp_ref[...], bk, compute_dtype)
    acc_ref[...] += jnp.dot(
        x_ref[...].astype(compute_dtype), w_block,
        preferred_element_type=jnp.float32,
    )

    @pl.when(k == nk - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def _bmm_scaled_kernel(x_ref, wp_ref, s_ref, o_ref, acc_ref, *, nk: int, bk: int,
                       compute_dtype):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    w_block = _unpack_block(wp_ref[...], bk, compute_dtype)
    acc_ref[...] += jnp.dot(
        x_ref[...].astype(compute_dtype), w_block,
        preferred_element_type=jnp.float32,
    )

    @pl.when(k == nk - 1)
    def _flush():
        o_ref[...] = (acc_ref[...] * s_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


def binary_matmul_pallas(
    x: jax.Array,
    w_packed: jax.Array,
    scale: jax.Array | None = None,
    *,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 512,
    compute_dtype=jnp.bfloat16,
    out_dtype=jnp.float32,
    interpret: bool = False,
) -> jax.Array:
    """Blocked Pallas binary matmul. Shapes must divide the block sizes
    (the jit wrapper in ``ops.py`` pads arbitrary shapes first)."""
    m, kdim = x.shape
    k32, n = w_packed.shape
    if k32 * PACK != kdim:
        raise ValueError(f"packed K mismatch: x K={kdim}, packed K={k32 * PACK}")
    if m % block_m or n % block_n or kdim % block_k:
        raise ValueError(
            f"shape ({m},{kdim})x({kdim},{n}) not divisible by blocks "
            f"({block_m},{block_k},{block_n}); use ops.binary_matmul")
    if block_k % PACK:
        raise ValueError("block_k must be a multiple of 32")

    nk = kdim // block_k
    grid = (m // block_m, n // block_n, nk)
    x_spec = pl.BlockSpec((block_m, block_k), lambda i, j, k: (i, k))
    w_spec = pl.BlockSpec((block_k // PACK, block_n), lambda i, j, k: (k, j))
    o_spec = pl.BlockSpec((block_m, block_n), lambda i, j, k: (i, j))
    scratch = [pltpu.VMEM((block_m, block_n), jnp.float32)]

    if scale is None:
        kern = functools.partial(
            _bmm_kernel, nk=nk, bk=block_k, compute_dtype=compute_dtype)
        in_specs = [x_spec, w_spec]
        args = (x, w_packed)
    else:
        kern = functools.partial(
            _bmm_scaled_kernel, nk=nk, bk=block_k, compute_dtype=compute_dtype)
        s_spec = pl.BlockSpec((1, block_n), lambda i, j, k: (0, j))
        in_specs = [x_spec, w_spec, s_spec]
        args = (x, w_packed, scale.reshape(1, n))

    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=in_specs,
        out_specs=o_spec,
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=scratch,
        interpret=interpret,
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
    )(*args)
