"""Pallas TPU kernels for the paper's compute hot-spots (binary matmul,
fused binarize+pack) with jnp oracles in ref.py and jit'd wrappers in ops.py."""
