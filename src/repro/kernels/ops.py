"""Public jit'd wrappers around the Pallas kernels.

Handle arbitrary shapes (pad to block multiples, slice back), batch leading
dims, pick interpret mode automatically on non-TPU backends, and fall back to
the jnp reference for shapes too small to block.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.compat import ceil_to as _ceil_to, on_tpu as _on_tpu
from repro.core.packing import PACK, pack_bits, pad_to_pack
from repro.kernels import ref
from repro.kernels.binary_matmul import binary_matmul_pallas
from repro.kernels.stoch_binarize import binarize_pack_pallas


# Global default for the use_pallas dispatch (dry-runs lower the jnp
# reference body off-TPU for clean HLO; real-TPU serving keeps the kernel).
_DEFAULT_USE_PALLAS = True


def set_use_pallas(value: bool) -> None:
    global _DEFAULT_USE_PALLAS
    _DEFAULT_USE_PALLAS = value


def binary_matmul(
    x: jax.Array,
    w_packed: jax.Array,
    scale: jax.Array | None = None,
    *,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 512,
    out_dtype=jnp.float32,
    use_pallas: bool | None = None,
    compute_dtype=None,
) -> jax.Array:
    """``x @ unpack(w_packed) [* scale]`` for x of shape (..., K).

    Uses the Pallas kernel (interpret mode off-TPU) with padding to block
    multiples; falls back to the jnp reference when padding overhead would
    exceed the problem size (tiny shapes). ``compute_dtype`` defaults to the
    input dtype for f32 activations (numerical parity with the dense path)
    and bf16 otherwise (the MXU-native choice)."""
    if use_pallas is None:
        use_pallas = _DEFAULT_USE_PALLAS
    if compute_dtype is None:
        compute_dtype = jnp.float32 if x.dtype == jnp.float32 else jnp.bfloat16
    return _binary_matmul(x, w_packed, scale, block_m=block_m,
                          block_n=block_n, block_k=block_k,
                          out_dtype=out_dtype, use_pallas=use_pallas,
                          compute_dtype=compute_dtype)


@functools.partial(
    jax.jit, static_argnames=("block_m", "block_n", "block_k", "out_dtype",
                              "use_pallas", "compute_dtype"))
def _binary_matmul(
    x: jax.Array,
    w_packed: jax.Array,
    scale: jax.Array | None = None,
    *,
    block_m: int,
    block_n: int,
    block_k: int,
    out_dtype,
    use_pallas: bool,
    compute_dtype=jnp.bfloat16,
) -> jax.Array:
    *lead, kdim = x.shape
    k32, n = w_packed.shape
    if k32 * PACK != kdim:
        raise ValueError(f"K mismatch: x has K={kdim}, packed has {k32 * PACK}")
    x2 = x.reshape(-1, kdim)
    m = x2.shape[0]
    # Tiny problems: blocking pads > 4x the work; use the reference.
    if not use_pallas or m * n * kdim < block_m * block_n * block_k:
        out = ref.binary_matmul_ref(x2, w_packed, scale, out_dtype=out_dtype,
                                    compute_dtype=compute_dtype)
        return out.reshape(*lead, n)

    bm = min(block_m, _ceil_to(m, 8))
    mp, np_, kp = _ceil_to(m, bm), _ceil_to(n, block_n), _ceil_to(kdim, block_k)
    xp = jnp.pad(x2, ((0, mp - m), (0, kp - kdim)))
    wp = jnp.pad(w_packed, ((0, (kp - kdim) // PACK), (0, np_ - n)))
    sp = None if scale is None else jnp.pad(scale, (0, np_ - n))
    out = binary_matmul_pallas(
        xp, wp, sp,
        block_m=bm, block_n=block_n, block_k=block_k,
        compute_dtype=compute_dtype,
        out_dtype=out_dtype, interpret=not _on_tpu(),
    )
    # Padded K rows contribute unpack(0-bits) = -1 weights times zero
    # activations = 0, so no correction is needed.
    return out[:m, :n].reshape(*lead, n)


@functools.partial(jax.jit, static_argnames=("stochastic", "block_k", "block_n"))
def binarize_and_pack(
    w: jax.Array,
    key: jax.Array | None = None,
    *,
    stochastic: bool = False,
    block_k: int = 256,
    block_n: int = 256,
) -> jax.Array:
    """Fused binarize (Eq. 1 or 2) + bitpack of a (K, N) master weight.

    Returns (ceil(K/32), N) int32. Off-TPU the stochastic path draws its
    uniform words with ``jax.random.bits`` (interpret mode cannot lower the
    TPU PRNG); on TPU the same operand path is used for determinism across
    backends — the in-kernel PRNG variant is available via
    ``stoch_binarize.binarize_pack_pallas(use_tpu_prng=True)``.
    """
    kdim, n = w.shape
    wp = pad_to_pack(w, axis=0)
    kp = _ceil_to(wp.shape[0], block_k)
    np_ = _ceil_to(n, block_n)
    if kp * np_ > 4 * max(kdim, 1) * max(n, 1):  # tiny: jnp reference
        if stochastic:
            if key is None:
                raise ValueError("stochastic binarization requires a key")
            bits = jax.random.bits(key, wp.shape, jnp.uint32)
            packed = ref.stoch_binarize_pack_ref(wp, bits)
        else:
            packed = ref.det_binarize_pack_ref(wp)
        return packed[:, :n]

    wpad = jnp.pad(wp, ((0, kp - wp.shape[0]), (0, np_ - n)))
    if stochastic:
        if key is None:
            raise ValueError("stochastic binarization requires a key")
        bits = jax.random.bits(key, wpad.shape, jnp.uint32)
        packed = binarize_pack_pallas(
            wpad, bits, stochastic=True, block_k=block_k, block_n=block_n,
            interpret=not _on_tpu())
    else:
        packed = binarize_pack_pallas(
            wpad, stochastic=False, block_k=block_k, block_n=block_n,
            interpret=not _on_tpu())
    return packed[: (kdim + PACK - 1) // PACK, :n]


def pack_master_weights(w: jax.Array) -> jax.Array:
    """Deterministic pack of an already-±1 tensor (serving path)."""
    return pack_bits(pad_to_pack(w, axis=0))
