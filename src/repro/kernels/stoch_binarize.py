"""Pallas TPU kernel: fused (stochastic|deterministic) binarize + bitpack.

FPGA stochastic BNNs use on-fabric LFSRs to draw the Bernoulli samples of
Eq. (2); the TPU analogue is the on-chip PRNG (``pltpu.prng_random_bits``).
The CPU Pallas interpreter has no lowering for the TPU PRNG primitives, so
the kernel is written to take the uniform random words as an *operand*
(``bits``): on a real TPU the caller can cheaply generate them with
``pltpu.prng_random_bits`` (the ``use_tpu_prng`` flag swaps the body), while
in interpret mode / tests they come from ``jax.random.bits``. The kernel body
— threshold against hard_sigmoid(w) in fixed point, pack 32 lanes into one
int32 word — is identical in both paths and is what tests validate.

Layout: w     (K, N) f32/bf16 master weights
        bits  (K, N) uint32 uniform random words (stochastic only)
        out   (K // 32, N) int32 packed sign bits (+1 -> 1)

The threshold is computed in uint32 fixed point: P(bit=1) = sigma(w) and
``bits < sigma(w) * 2^32`` has exactly that probability for uniform words.
The clip endpoints are handled exactly: p = 1 (w >= +1, a value master-weight
clipping produces) must yield bit 1 for *every* random word, but the f32
comparison alone cannot guarantee it — words >= 2^32 - 128 round up to
2^32.0f and tie with the threshold — so the kernels force the p >= 1 lane
explicitly. p = 0 (w <= -1) is exact as-is (u < 0 never holds).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.packing import PACK

_TWO32 = 4294967296.0  # 2 ** 32


def _pack_block(ones: jax.Array, bk: int) -> jax.Array:
    """(bk, bn) uint32 {0,1} -> (bk//32, bn) int32 packed words."""
    bn = ones.shape[-1]
    b = ones.reshape(bk // PACK, PACK, bn)
    shifts = jnp.arange(PACK, dtype=jnp.uint32)[None, :, None]
    words = jnp.sum(b << shifts, axis=1, dtype=jnp.uint32)
    return words.astype(jnp.int32)


def _stoch_kernel(w_ref, bits_ref, o_ref, *, bk: int):
    w = w_ref[...].astype(jnp.float32)
    p = jnp.clip((w + 1.0) * 0.5, 0.0, 1.0)            # Eq. (3)
    thresh = (p * _TWO32).astype(jnp.float32)
    u = bits_ref[...].astype(jnp.float32)               # uniform in [0, 2^32)
    # p >= 1 forced: u rounds to 2^32.0f for the top 128 words and would
    # tie with the threshold, turning a sure bit into a 3e-8 miss
    ones = ((u < thresh) | (p >= 1.0)).astype(jnp.uint32)  # P(one) = p (Eq. 2)
    o_ref[...] = _pack_block(ones, bk)


def _stoch_kernel_tpu_prng(seed_ref, w_ref, o_ref, *, bk: int):
    """Real-TPU variant: draws bits on chip. Not lowerable on CPU interpret."""
    pltpu.prng_seed(seed_ref[0], pl.program_id(0), pl.program_id(1))
    w = w_ref[...].astype(jnp.float32)
    p = jnp.clip((w + 1.0) * 0.5, 0.0, 1.0)
    thresh = (p * _TWO32).astype(jnp.float32)
    raw = pltpu.prng_random_bits(w.shape)
    u = raw.astype(jnp.uint32).astype(jnp.float32)
    ones = ((u < thresh) | (p >= 1.0)).astype(jnp.uint32)
    o_ref[...] = _pack_block(ones, bk)


def _det_kernel(w_ref, o_ref, *, bk: int):
    ones = (w_ref[...] > 0).astype(jnp.uint32)          # Eq. (1)
    o_ref[...] = _pack_block(ones, bk)


def binarize_pack_pallas(
    w: jax.Array,
    bits: jax.Array | None = None,
    *,
    stochastic: bool,
    block_k: int = 256,
    block_n: int = 256,
    seed: jax.Array | None = None,
    use_tpu_prng: bool = False,
    interpret: bool = False,
) -> jax.Array:
    """Fused binarize+pack. ``w`` is (K, N) with K % block_k == 0,
    N % block_n == 0, block_k % 32 == 0 (ops.py pads arbitrary shapes)."""
    kdim, n = w.shape
    if kdim % block_k or n % block_n or block_k % PACK:
        raise ValueError(f"bad blocks for shape {(kdim, n)}")
    grid = (kdim // block_k, n // block_n)
    w_spec = pl.BlockSpec((block_k, block_n), lambda i, j: (i, j))
    o_spec = pl.BlockSpec((block_k // PACK, block_n), lambda i, j: (i, j))
    out_shape = jax.ShapeDtypeStruct((kdim // PACK, n), jnp.int32)

    if not stochastic:
        return pl.pallas_call(
            functools.partial(_det_kernel, bk=block_k),
            grid=grid, in_specs=[w_spec], out_specs=o_spec,
            out_shape=out_shape, interpret=interpret,
        )(w)

    if use_tpu_prng:
        if seed is None:
            raise ValueError("use_tpu_prng requires a seed scalar")
        return pl.pallas_call(
            functools.partial(_stoch_kernel_tpu_prng, bk=block_k),
            grid=grid,
            in_specs=[pl.BlockSpec(memory_space=pltpu.SMEM), w_spec],
            out_specs=o_spec,
            out_shape=out_shape, interpret=interpret,
        )(seed.reshape(1).astype(jnp.int32), w)

    if bits is None:
        raise ValueError("stochastic=True without use_tpu_prng requires bits")
    bits_spec = pl.BlockSpec((block_k, block_n), lambda i, j: (i, j))
    return pl.pallas_call(
        functools.partial(_stoch_kernel, bk=block_k),
        grid=grid, in_specs=[w_spec, bits_spec], out_specs=o_spec,
        out_shape=out_shape, interpret=interpret,
    )(w, bits)
