"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth).

These are intentionally straight-line jnp implementations with no blocking,
used by tests (``assert_allclose`` sweeps over shapes/dtypes) and as the
portable fallback on backends without Pallas.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import packing

_TWO32 = 4294967296.0


def binary_matmul_ref(
    x: jax.Array,
    w_packed: jax.Array,
    scale: jax.Array | None = None,
    *,
    compute_dtype=jnp.bfloat16,
    out_dtype=jnp.float32,
) -> jax.Array:
    """out = x @ unpack(w_packed) [* scale]."""
    w = packing.unpack_bits(w_packed, dtype=compute_dtype)
    out = jnp.dot(x.astype(compute_dtype), w, preferred_element_type=jnp.float32)
    if scale is not None:
        out = out * scale.astype(jnp.float32)[None, :]
    return out.astype(out_dtype)


def det_binarize_pack_ref(w: jax.Array) -> jax.Array:
    """sign-binarize (Eq. 1) then bitpack."""
    pm1 = jnp.where(w > 0, 1.0, -1.0).astype(jnp.float32)
    return packing.pack_bits(pm1)


def stoch_binarize_pack_ref(w: jax.Array, bits: jax.Array) -> jax.Array:
    """Stochastic binarize (Eq. 2/3 with supplied uniform words) then bitpack.

    The p = 1 clip endpoint (w >= +1) is forced to bit 1: random words in
    the top 128 values round up to 2^32.0f and would tie with the f32
    threshold (matching the Pallas kernel's endpoint handling)."""
    p = jnp.clip((w.astype(jnp.float32) + 1.0) * 0.5, 0.0, 1.0)
    thresh = (p * _TWO32).astype(jnp.float32)
    ones = (bits.astype(jnp.float32) < thresh) | (p >= 1.0)
    pm1 = jnp.where(ones, 1.0, -1.0).astype(jnp.float32)
    return packing.pack_bits(pm1)
