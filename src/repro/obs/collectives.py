"""Static per-step collective audit of the jitted serving programs.

The ROADMAP's sharded-serving item names its success metric directly: "a
per-step collective count asserted in tests". This module produces that
number *statically* — no serving run needed — by walking the compiled,
SPMD-partitioned HLO of the engine's jitted ``decode_step`` /
``prefill_into`` with the existing ``repro.core.hlo_analysis`` parser:

* per collective kind (all-gather / reduce-scatter / all-reduce /
  all-to-all / collective-permute): the exact count and operand bytes
  executed per step, *trip-count weighted* (a collective inside the
  per-layer decode scan counts once per layer, which XLA's own
  ``cost_analysis`` gets wrong on CPU);
* resharding copies: top-level ``copy`` ops — where GSPMD materializes a
  placement change that needs no cross-device traffic, e.g. at the
  packed/dense boundary when an int32 word tensor's layout meets a dense
  activation.

Consumers: ``plan_report`` (a per-row predicted-collective column from the
plan's sharding metadata — what the plan *implies*), ``launch.serve
--audit-collectives`` (the measured table for the engine actually built),
``benchmarks/check_collectives.py`` (the CI golden gate: a code change that
silently adds a collective to ``decode_step`` fails the diff), and
``tests/test_obs_collectives.py`` (exact counts for the det/xnor sharded
golden plans on the forced 4-device CPU mesh).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

from repro.core import hlo_analysis as H

#: Activation-stream bytes/element for the predicted-collective column
#: (matches the ``engine/costs.py`` convention: bf16 activations).
ACT_BYTES = 2


@dataclasses.dataclass
class CollectiveAudit:
    """Per-execution collective profile of one compiled program."""

    entry: str                       # which jitted program ("decode_step")
    counts: Dict[str, int]           # kind -> count per execution
    bytes: Dict[str, float]          # kind -> operand bytes per execution
    reshard_copies: int = 0
    reshard_copy_bytes: float = 0.0

    @property
    def total_count(self) -> int:
        return sum(self.counts.values())

    @property
    def total_bytes(self) -> float:
        return sum(self.bytes.values())

    def to_json(self) -> dict:
        return {"entry": self.entry,
                "counts": {k: self.counts[k] for k in sorted(self.counts)},
                "bytes": {k: self.bytes[k] for k in sorted(self.bytes)},
                "reshard_copies": self.reshard_copies,
                "reshard_copy_bytes": self.reshard_copy_bytes}

    @classmethod
    def from_json(cls, d: dict) -> "CollectiveAudit":
        return cls(entry=d["entry"],
                   counts={k: int(v) for k, v in d["counts"].items()},
                   bytes={k: float(v) for k, v in d["bytes"].items()},
                   reshard_copies=int(d.get("reshard_copies", 0)),
                   reshard_copy_bytes=float(d.get("reshard_copy_bytes", 0.0)))

    def summary(self) -> str:
        if not self.counts:
            core = "no collectives"
        else:
            core = ", ".join(
                f"{k} x{self.counts[k]} ({self.bytes.get(k, 0) / 1e3:.1f}KB)"
                for k in sorted(self.counts))
        return (f"{self.entry}: {core}; reshard copies "
                f"{self.reshard_copies} "
                f"({self.reshard_copy_bytes / 1e3:.1f}KB)")


def audit_hlo(text: str, entry: str = "program",
              hlo_entry: Optional[str] = None) -> CollectiveAudit:
    """Audits optimized HLO text (``compiled.as_text()``): collective
    counts/bytes per kind plus top-level reshard copies, all trip-count
    weighted by ``hlo_analysis.analyze``."""
    cost = H.analyze(text, entry=hlo_entry)
    return CollectiveAudit(
        entry=entry,
        counts={k: int(v) for k, v in cost.collective_count.items()},
        bytes={k: float(v) for k, v in cost.collective_bytes_by_kind.items()},
        reshard_copies=cost.copy_count,
        reshard_copy_bytes=cost.copy_bytes)


# ---------------------------------------------------------------------------
# engine audit: lower the actual jitted entry points with their real
# (placed) arguments and read the per-step collectives off the compiled HLO
# ---------------------------------------------------------------------------

def lower_serving_hlo(engine, *, n_slots: int, prompt_len: int,
                      max_new_cap: int) -> Dict[str, str]:
    """Compiled (optimized, SPMD-partitioned) HLO text of the engine's
    jitted serving programs — ``decode_step``, ``prefill_into`` and (on
    the single-sample path) the fused chunked-prefill ``decode_prefill``
    step — lowered with the engine's *placed* parameter tree and a freshly
    placed :class:`DecodeState` under the engine's ambient mesh, so the
    HLO is exactly what serving executes. Works for both the plain and the
    K-replica ensemble path (whichever the engine serves)."""
    import jax.numpy as jnp

    state = engine.init_decode(n_slots, prompt_len, max_new_cap)
    tok = jnp.argmax(state.logits, axis=-1).reshape(n_slots, 1)
    tok = tok.astype(jnp.int32)
    prompt = jnp.zeros((1, prompt_len), jnp.int32)
    slot = jnp.int32(0)
    with engine._mesh_ctx():
        if engine._replicas is not None:
            rs = engine._replicas
            dec = engine._decode_ens.lower(
                rs.stacked, rs.base, state.cache, tok).compile()
            pre = engine._ens_prefill_into.lower(
                rs.stacked, rs.base, state.cache, state.logits,
                state.agreement, state.variance, prompt, slot,
                state.context_len).compile()
            return {"decode_step": dec.as_text(),
                    "prefill_into": pre.as_text()}
        dec = engine._decode.lower(
            engine.params, state.cache, tok).compile()
        pre = engine._prefill_into.lower(
            engine.params, state.cache, state.logits, prompt, slot,
            state.context_len).compile()
        # the fused step at a representative geometry: one full-width
        # prompt chunk interleaved into the all-slots decode
        chunk = jnp.zeros((1, prompt_len), jnp.int32)
        keep = jnp.zeros((n_slots,), bool)
        fused = engine._decode_prefill.lower(
            engine.params, state.cache, state.logits, tok, keep, chunk,
            slot, jnp.int32(0)).compile()
    return {"decode_step": dec.as_text(), "prefill_into": pre.as_text(),
            "decode_prefill": fused.as_text()}


def audit_engine(engine, *, n_slots: int, prompt_len: int,
                 max_new_cap: int) -> Dict[str, CollectiveAudit]:
    """Audits the serving engine's jitted programs for the given decode
    geometry: ``decode_step`` (one full step over all slots — the per-step
    collective count), ``prefill_into`` (one request splice) and, on the
    single-sample path, the fused ``decode_prefill`` chunked-prefill step.
    See :func:`lower_serving_hlo` for what is lowered."""
    texts = lower_serving_hlo(engine, n_slots=n_slots,
                              prompt_len=prompt_len,
                              max_new_cap=max_new_cap)
    return {name: audit_hlo(text, entry=name)
            for name, text in texts.items()}


def attribute_collectives(text: str) -> List[dict]:
    """Per-collective blame table for one compiled program: every
    collective op reachable from the entry, trip-count weighted, with the
    jaxpr source path XLA recorded in its metadata — which plan row /
    datapath boundary each all-gather or all-reduce belongs to. Each item:
    ``{kind, op, op_name, computation, trips, bytes_per_step}`` where
    ``bytes_per_step`` is operand bytes x trips (matching ``audit_hlo``'s
    accounting) and ``op_name`` is empty when XLA kept no metadata."""
    comps = H.parse_hlo(text)
    rows: List[dict] = []
    for visit in H.iter_ops(text):
        op = visit.op
        kind = next((k for k in H._COLLECTIVES
                     if op.opcode == k or op.opcode.startswith(k + "-")),
                    None)
        if kind is None:
            continue
        comp = comps[visit.computation]
        b = sum(H.shape_bytes(comp.ops[n].shape) for n in op.operands
                if n in comp.ops)
        if b == 0:
            b = H.shape_bytes(op.shape)
        rows.append({"kind": kind, "op": op.name,
                     "op_name": H.op_metadata_name(op),
                     "computation": visit.computation,
                     "trips": visit.mult,
                     "bytes_per_step": visit.mult * b})
    return rows


def format_audit(audits: Dict[str, CollectiveAudit]) -> str:
    """Aligned text table: entry | collective kind | count/step | bytes."""
    rows = [("entry", "collective", "count/step", "operand bytes")]
    for name in sorted(audits):
        a = audits[name]
        kinds = sorted(a.counts) or ["(none)"]
        for k in kinds:
            rows.append((name, k, str(a.counts.get(k, 0)),
                         f"{a.bytes.get(k, 0.0):,.0f}"))
        rows.append((name, "reshard-copy", str(a.reshard_copies),
                     f"{a.reshard_copy_bytes:,.0f}"))
    widths = [max(len(r[i]) for r in rows) for i in range(4)]
    lines = ["  ".join(c.ljust(w) for c, w in zip(r, widths)).rstrip()
             for r in rows]
    lines.insert(1, "  ".join("-" * w for w in widths))
    return "\n".join(lines)


# ---------------------------------------------------------------------------
# static per-row prediction for plan_report
# ---------------------------------------------------------------------------

def predict_row_collective(sharding: Optional[list], shape: tuple,
                           batch: int = 8,
                           axis_sizes: Optional[dict] = None
                           ) -> Optional[dict]:
    """What one plan row's sharding column *implies* per application:

    * non-batch mesh axes on the out-channel (last) dim — Megatron column
      parallelism: each device holds an N-shard of the output, so using the
      full activation downstream needs an **all-gather** of the output;
    * non-batch axes on the contraction (second-to-last) dim — row
      parallelism: each device holds partial sums, so the output needs an
      **all-reduce**.

    ``bytes_per_app`` is the collective's operand size for one application
    (``batch * N * ACT_BYTES``, the full output activation; wire bytes
    depend on the algorithm and device count and are reported separately
    by the measured audit). Returns None for unsharded / unannotated rows
    and rows whose only sharded dims are batch axes. Note GSPMD often
    *elides* the predicted collective — e.g. a column-parallel matmul
    feeding a row-parallel one fuses into one all-reduce — which is exactly
    why the measured ``audit_engine`` numbers, not this column, are the
    golden-gated artifact.
    """
    if not sharding or len(shape) < 2:
        return None
    batch_names = ("data", "pod")

    def model_axes(entry):
        names = entry if isinstance(entry, (list, tuple)) else [entry]
        return [a for a in names if a is not None and a not in batch_names]

    n = shape[-1]
    for dim, kind in ((len(shape) - 1, "all-gather"),
                      (len(shape) - 2, "all-reduce")):
        if dim < len(sharding):
            axes = model_axes(sharding[dim])
            if axes:
                parts = None
                if axis_sizes is not None:
                    parts = 1
                    for a in axes:
                        parts *= int(axis_sizes.get(a, 1))
                    if parts <= 1:
                        return None
                return {"kind": kind, "axes": axes, "parts": parts,
                        "bytes_per_app": batch * n * ACT_BYTES}
    return None
