"""Serving observability: tracing, metrics, and the static collective audit.

The sharded-serving hunt (ROADMAP: 86 tok/s sharded vs 316 single-device),
the pipeline-plan work and the autotuner all need *measured feedback*;
this package is the one place the serving stack reports itself.

Module map::

    trace.py        Tracer — low-overhead span API threaded through
                    ServeEngine.prefill_into / decode_step / stream_serve
                    and the SlotBatcher refill path; host vs device time
                    split via block_until_ready fencing (only while
                    tracing); Chrome trace-event JSON export viewable in
                    Perfetto; validate_trace / `python -m repro.obs.trace`
                    schema + span-coverage checker (CI runs it).
    metrics.py      MetricsRegistry — process-local counters / gauges /
                    histograms (tok/s, TTFT, per-step latency, queue
                    depth, slot occupancy, ensemble vote agreement and
                    abstains) with numpy-exact p50/p95/p99 summaries,
                    lossless JSON round-trip and Prometheus text export.
    collectives.py  audit_engine — walks the compiled SPMD HLO of the
                    jitted decode_step / prefill_into (via
                    core/hlo_analysis) and reports the exact per-step
                    count + operand bytes of every collective kind plus
                    resharding copies; predict_row_collective feeds the
                    plan_report "collectives" column; golden-gated in CI
                    (benchmarks/check_collectives.py).

Entry points: ``launch.serve --trace out.json --metrics-out m.json
--audit-collectives``; ``stream_serve(..., metrics=registry)``;
``ServeEngine(..., tracer=Tracer())``. See docs/OBSERVABILITY.md for the
span taxonomy, metric names/units, and how to read the audit.
"""
from repro.obs.collectives import (CollectiveAudit, audit_engine, audit_hlo,
                                   format_audit, predict_row_collective)
from repro.obs.metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                               record_request_metrics)
from repro.obs.trace import NULL_TRACER, Tracer, validate_trace

__all__ = [
    "CollectiveAudit", "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "NULL_TRACER", "Tracer", "audit_engine", "audit_hlo", "format_audit",
    "predict_row_collective", "record_request_metrics", "validate_trace",
]
