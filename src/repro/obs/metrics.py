"""Process-local serving metrics: counters, gauges, histograms.

One :class:`MetricsRegistry` per serving process collects the numbers the
ROADMAP's perf items need as *measured feedback* — tok/s, TTFT, per-step
latency, queue depth, slot occupancy, and (under K-replica ensemble
serving) abstain counts and vote agreement — and exports them two ways:

* ``to_json()`` — lossless (histograms keep their samples), round-trips
  through ``MetricsRegistry.from_json`` so benchmark records and CI
  artifacts can be re-aggregated offline;
* ``to_prometheus()`` — Prometheus text exposition (counters/gauges as-is,
  histograms as summaries with p50/p95/p99 quantile lines) for scraping.

Histogram percentiles use numpy's default linear interpolation, asserted
against ``np.quantile`` in tests. Histograms keep raw samples (serving
runs observe thousands of points, not millions); a bounded reservoir can
ride behind the same API if a workload ever needs it.

Metric name conventions (full table in docs/OBSERVABILITY.md): serving
metrics are prefixed ``serve_``, counters end in ``_total``, and units ride
the name (``_seconds``, ``_tokens``).
"""
from __future__ import annotations

import dataclasses
import json
from typing import Optional

import numpy as np


@dataclasses.dataclass
class Counter:
    """Monotonically increasing count (tokens emitted, steps run, ...)."""

    name: str
    help: str = ""
    value: float = 0.0

    def inc(self, v: float = 1.0) -> None:
        if v < 0:
            raise ValueError(f"counter {self.name} cannot decrease (inc {v})")
        self.value += v

    def to_json(self) -> dict:
        return {"type": "counter", "help": self.help, "value": self.value}


@dataclasses.dataclass
class Gauge:
    """Last-observed value (current queue depth, slot occupancy, ...)."""

    name: str
    help: str = ""
    value: float = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)

    def to_json(self) -> dict:
        return {"type": "gauge", "help": self.help, "value": self.value}


@dataclasses.dataclass
class Histogram:
    """Sample-keeping histogram with numpy-quantile percentiles."""

    name: str
    help: str = ""
    samples: list = dataclasses.field(default_factory=list)

    def observe(self, v: float) -> None:
        self.samples.append(float(v))

    @property
    def count(self) -> int:
        return len(self.samples)

    @property
    def sum(self) -> float:
        return float(np.sum(self.samples)) if self.samples else 0.0

    def percentile(self, q: float) -> Optional[float]:
        """q in [0, 100]; linear interpolation, matching np.quantile."""
        if not self.samples:
            return None
        return float(np.quantile(np.asarray(self.samples), q / 100.0))

    def summary(self) -> dict:
        if not self.samples:
            return {"count": 0}
        a = np.asarray(self.samples)
        return {"count": int(a.size), "sum": float(a.sum()),
                "min": float(a.min()), "max": float(a.max()),
                "mean": float(a.mean()),
                "p50": float(np.quantile(a, 0.50)),
                "p95": float(np.quantile(a, 0.95)),
                "p99": float(np.quantile(a, 0.99))}

    def to_json(self) -> dict:
        return {"type": "histogram", "help": self.help,
                "summary": self.summary(), "samples": list(self.samples)}


class MetricsRegistry:
    """Name-keyed registry; ``counter``/``gauge``/``histogram`` get-or-
    create (re-registering a name as a different type raises)."""

    def __init__(self):
        self._metrics: dict = {}

    def _get(self, cls, name: str, help: str):
        m = self._metrics.get(name)
        if m is None:
            m = cls(name=name, help=help)
            self._metrics[name] = m
        elif not isinstance(m, cls):
            raise TypeError(f"metric {name!r} already registered as "
                            f"{type(m).__name__}, not {cls.__name__}")
        return m

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get(Counter, name, help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get(Gauge, name, help)

    def histogram(self, name: str, help: str = "") -> Histogram:
        return self._get(Histogram, name, help)

    def __contains__(self, name: str) -> bool:
        return name in self._metrics

    def __getitem__(self, name: str):
        return self._metrics[name]

    def names(self) -> list[str]:
        return sorted(self._metrics)

    # -- export ------------------------------------------------------------
    def to_json(self) -> dict:
        return {name: self._metrics[name].to_json()
                for name in sorted(self._metrics)}

    @classmethod
    def from_json(cls, d: dict) -> "MetricsRegistry":
        """Inverse of ``to_json`` (histogram samples restored verbatim)."""
        reg = cls()
        for name, m in d.items():
            kind = m.get("type")
            if kind == "counter":
                reg.counter(name, m.get("help", "")).value = float(m["value"])
            elif kind == "gauge":
                reg.gauge(name, m.get("help", "")).set(m["value"])
            elif kind == "histogram":
                h = reg.histogram(name, m.get("help", ""))
                h.samples.extend(float(s) for s in m.get("samples", ()))
            else:
                raise ValueError(f"unknown metric type {kind!r} for {name!r}")
        return reg

    def save(self, path: str) -> str:
        with open(path, "w") as f:
            json.dump(self.to_json(), f, indent=1, sort_keys=True)
            f.write("\n")
        return path

    def to_prometheus(self) -> str:
        """Prometheus text exposition; histograms as summaries (quantile
        labels) since the registry keeps samples, not fixed buckets."""
        lines = []
        for name in sorted(self._metrics):
            m = self._metrics[name]
            if m.help:
                lines.append(f"# HELP {name} {m.help}")
            if isinstance(m, Counter):
                lines.append(f"# TYPE {name} counter")
                lines.append(f"{name} {m.value:g}")
            elif isinstance(m, Gauge):
                lines.append(f"# TYPE {name} gauge")
                lines.append(f"{name} {m.value:g}")
            else:
                lines.append(f"# TYPE {name} summary")
                for q in (0.5, 0.95, 0.99):
                    v = m.percentile(q * 100)
                    if v is not None:
                        lines.append(f'{name}{{quantile="{q}"}} {v:g}')
                lines.append(f"{name}_sum {m.sum:g}")
                lines.append(f"{name}_count {m.count}")
        return "\n".join(lines) + "\n"

    def format_table(self) -> str:
        """Human-readable one-line-per-metric summary for CLI output."""
        out = []
        for name in sorted(self._metrics):
            m = self._metrics[name]
            if isinstance(m, Histogram):
                s = m.summary()
                if s["count"]:
                    out.append(
                        f"{name}: n={s['count']} mean={s['mean']:.4g} "
                        f"p50={s['p50']:.4g} p95={s['p95']:.4g} "
                        f"p99={s['p99']:.4g}")
                else:
                    out.append(f"{name}: n=0")
            else:
                out.append(f"{name}: {m.value:g}")
        return "\n".join(out)


def record_request_metrics(registry: MetricsRegistry, batcher) -> None:
    """Fold a ``SlotBatcher``'s completed-request ledger into the registry:
    TTFT / end-to-end latency histograms, token and completion counters,
    and — when the ensemble columns are populated — per-token vote
    agreement and the abstain counter. Called by ``stream_serve`` at loop
    exit; callers aggregating several runs can call it per batcher."""
    ttft = registry.histogram("serve_ttft_seconds",
                              "submit-to-first-token seconds (queue incl.)")
    lat = registry.histogram("serve_request_latency_seconds",
                             "submit-to-last-token seconds (queue incl.)")
    done = registry.counter("serve_requests_completed_total",
                            "requests fully served")
    toks = registry.counter("serve_tokens_total", "tokens recorded")
    trunc = registry.counter("serve_prompts_truncated_total",
                             "prompts truncated to the slot width")
    for r in batcher.completed:
        if r.ttft is not None:
            ttft.observe(r.ttft)
        if r.latency is not None:
            lat.observe(r.latency)
        done.inc()
        toks.inc(len(r.generated))
        if r.truncated:
            trunc.inc()
        if r.agreement:
            agr = registry.histogram(
                "serve_vote_agreement",
                "per-token ensemble replica vote agreement (0-1)")
            for a in r.agreement:
                agr.observe(a)
        if r.abstained:
            registry.counter("serve_abstain_total",
                             "requests flagged below the abstain "
                             "threshold").inc()
