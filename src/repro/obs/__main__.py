"""``python -m repro.obs trace.json [--min-coverage 0.95]`` — validate a
Chrome trace emitted by ``launch.serve --trace`` (schema + span coverage).
Same CLI as ``python -m repro.obs.trace`` without runpy's re-import
warning (the package __init__ already imports the submodule)."""
from repro.obs.trace import main

if __name__ == "__main__":
    main()
