"""Low-overhead step-level tracing for the serving stack.

The sharded-serving slowdown (ROADMAP: 86 tok/s sharded vs 316 single-
device) cannot be hunted without seeing *where* each decode step spends its
time: host-side bookkeeping (refill, sampling, the batcher ledger), jitted
dispatch, and device compute. A :class:`Tracer` records wall-clock spans
through the ``ServeEngine`` entry points and the ``stream_serve`` loop and
exports them as Chrome trace-event JSON — open the file at
https://ui.perfetto.dev (or ``chrome://tracing``) and the serving timeline
reads like a flame chart.

Design constraints, in order:

* **Off means off.** ``tracer.span(...)`` on a disabled tracer returns one
  shared no-op context manager — no allocation, no clock read, no event.
  The serving hot loop pays a single attribute check per span site, and
  ``jax.block_until_ready`` fencing *only* happens while tracing (the
  normal async-dispatch pipeline is never serialized by a dormant tracer).
* **Host vs device split.** jax dispatch returns before the device
  finishes; a wall-clock span around a jitted call measures only dispatch.
  When tracing, the engine brackets each jitted call with a ``dispatch``
  span (call returns) and a ``device`` span (``tracer.fence`` =
  ``block_until_ready``), so the trace separates Python overhead from
  compute. Fencing serializes the pipeline, which can itself shift the
  numbers — the trace is for *attribution*, the untraced benchmark for
  *throughput*.
* **Valid Chrome trace events.** Every span is a complete event
  (``"ph": "X"``) with ``ts``/``dur`` in microseconds since the tracer's
  epoch, ``pid``/``tid``, and a ``depth`` arg (the span-stack depth at
  entry) that makes coverage accounting trivial; :func:`validate_trace`
  checks the schema, timestamp monotonicity, and span coverage, and is
  runnable as ``python -m repro.obs.trace out.json`` (CI does).

Span taxonomy (see docs/OBSERVABILITY.md): ``stream_serve`` (root) >
``init_decode`` / ``step`` > ``refill`` / ``prefill_into`` / ``sample`` /
``record`` / ``decode_step`` > ``dispatch`` / ``device``.
"""
from __future__ import annotations

import json
import os
import threading
import time
from typing import Optional


class _NullSpan:
    """Shared no-op context manager: the disabled-tracer fast path."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL_SPAN = _NullSpan()


class _Span:
    """One live span: records a complete ("X") event on exit."""

    __slots__ = ("tracer", "name", "args", "t0", "depth")

    def __init__(self, tracer: "Tracer", name: str, args: dict):
        self.tracer = tracer
        self.name = name
        self.args = args

    def __enter__(self):
        tr = self.tracer
        stack = tr._stack()
        self.depth = len(stack)
        stack.append(self)
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        t1 = time.perf_counter()
        tr = self.tracer
        tr._stack().pop()
        args = dict(self.args)
        args["depth"] = self.depth
        tr.events.append({
            "name": self.name, "ph": "X", "cat": "serve",
            "ts": (self.t0 - tr._t0) * 1e6,
            "dur": (t1 - self.t0) * 1e6,
            "pid": tr.pid, "tid": tr._tid(), "args": args,
        })
        return False


class Tracer:
    """Span recorder with Chrome trace-event export.

    ``enabled=False`` builds a dormant tracer: every ``span``/``instant``/
    ``fence`` call is a no-op (``span`` returns a shared null context
    manager — asserted in tests). ``fence=False`` keeps spans but never
    blocks on device values (dispatch-only timing)."""

    def __init__(self, enabled: bool = True, fence: bool = True,
                 pid: Optional[int] = None):
        self.enabled = enabled
        self.fence_enabled = fence
        self.events: list[dict] = []
        self.pid = os.getpid() if pid is None else pid
        self._t0 = time.perf_counter()
        self._tids: dict[int, int] = {}
        self._stacks: dict[int, list] = {}

    # -- recording ---------------------------------------------------------
    def span(self, name: str, **args):
        """Context manager timing one serving phase; ``args`` land in the
        event's ``args`` dict (small JSON-able values only)."""
        if not self.enabled:
            return _NULL_SPAN
        return _Span(self, name, args)

    def instant(self, name: str, **args) -> None:
        """Zero-duration marker (request submitted, slot refilled, ...)."""
        if not self.enabled:
            return
        self.events.append({
            "name": name, "ph": "i", "s": "t", "cat": "serve",
            "ts": (time.perf_counter() - self._t0) * 1e6,
            "pid": self.pid, "tid": self._tid(), "args": args,
        })

    def fence(self, value):
        """``jax.block_until_ready(value)`` — but only while tracing, so a
        dormant tracer never serializes the async dispatch pipeline."""
        if self.enabled and self.fence_enabled:
            import jax

            jax.block_until_ready(value)
        return value

    # -- bookkeeping -------------------------------------------------------
    def _tid(self) -> int:
        ident = threading.get_ident()
        if ident not in self._tids:
            self._tids[ident] = len(self._tids) + 1
        return self._tids[ident]

    def _stack(self) -> list:
        ident = threading.get_ident()
        if ident not in self._stacks:
            self._stacks[ident] = []
        return self._stacks[ident]

    # -- export ------------------------------------------------------------
    def to_json(self) -> dict:
        """Chrome trace-event JSON object (events sorted by timestamp)."""
        events = sorted(self.events, key=lambda e: e["ts"])
        meta = [{
            "name": "process_name", "ph": "M", "pid": self.pid, "tid": 0,
            "args": {"name": "repro.serve"},
        }]
        return {"traceEvents": meta + events, "displayTimeUnit": "ms"}

    def save(self, path: str) -> str:
        with open(path, "w") as f:
            json.dump(self.to_json(), f)
            f.write("\n")
        return path


#: Module-level disabled tracer: the default everywhere tracing is optional.
NULL_TRACER = Tracer(enabled=False)


# ---------------------------------------------------------------------------
# validation
# ---------------------------------------------------------------------------

_REQUIRED_X = ("name", "ph", "ts", "dur", "pid", "tid")


def validate_trace(trace: dict | str) -> dict:
    """Validates a Chrome trace-event JSON object (or a path to one).

    Checks: the ``traceEvents`` envelope; required fields per complete
    ("X") event (``name``/``ph``/``ts``/``dur``/``pid``/``tid``);
    non-negative durations; timestamps monotonically non-decreasing in file
    order (the export sorts). Also computes *span coverage*: the fraction
    of the root span's duration covered by its depth-1 children — the
    acceptance bar for serving traces is >= 0.95 (everything the loop does
    should be inside a named phase).

    Returns ``{"events": n, "spans": n, "coverage": float|None,
    "root": name|None}``; raises ``ValueError`` on any schema violation.
    """
    if isinstance(trace, str):
        with open(trace) as f:
            trace = json.load(f)
    if not isinstance(trace, dict) or "traceEvents" not in trace:
        raise ValueError("not a Chrome trace: missing 'traceEvents'")
    events = trace["traceEvents"]
    spans = [e for e in events if e.get("ph") == "X"]
    last_ts = None
    for e in events:
        if e.get("ph") == "M":
            continue
        for k in ("name", "ph", "ts", "pid", "tid"):
            if k not in e:
                raise ValueError(f"event missing {k!r}: {e}")
        if last_ts is not None and e["ts"] < last_ts:
            raise ValueError(
                f"timestamps not monotonic: {e['ts']} after {last_ts}")
        last_ts = e["ts"]
    for e in spans:
        for k in _REQUIRED_X:
            if k not in e:
                raise ValueError(f"complete event missing {k!r}: {e}")
        if e["dur"] < 0:
            raise ValueError(f"negative duration: {e}")
    coverage = root_name = None
    roots = [e for e in spans if e.get("args", {}).get("depth") == 0]
    if roots:
        root = max(roots, key=lambda e: e["dur"])
        root_name = root["name"]
        inside = [e for e in spans
                  if e.get("args", {}).get("depth") == 1
                  and e["tid"] == root["tid"]
                  and root["ts"] <= e["ts"]
                  and e["ts"] + e["dur"] <= root["ts"] + root["dur"] + 1.0]
        covered = sum(e["dur"] for e in inside)
        coverage = min(1.0, covered / root["dur"]) if root["dur"] > 0 else 1.0
    return {"events": len(events), "spans": len(spans),
            "coverage": coverage, "root": root_name}


def main() -> None:
    """CLI: ``python -m repro.obs.trace trace.json [--min-coverage 0.95]``
    — exits non-zero on schema violations or insufficient span coverage."""
    import argparse

    ap = argparse.ArgumentParser(description=validate_trace.__doc__)
    ap.add_argument("trace", help="Chrome trace-event JSON file")
    ap.add_argument("--min-coverage", type=float, default=None,
                    help="fail unless depth-1 spans cover at least this "
                         "fraction of the root span")
    args = ap.parse_args()
    info = validate_trace(args.trace)
    cov = ("n/a" if info["coverage"] is None
           else f"{info['coverage'] * 100:.1f}%")
    print(f"{args.trace}: valid — {info['events']} events, "
          f"{info['spans']} spans, root={info['root']!r}, coverage={cov}")
    if args.min_coverage is not None:
        if info["coverage"] is None or info["coverage"] < args.min_coverage:
            raise SystemExit(
                f"span coverage {cov} below required "
                f"{args.min_coverage * 100:.0f}%")


if __name__ == "__main__":
    main()
