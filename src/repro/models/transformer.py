"""Decoder stacks for all assigned LM-family architectures.

Three templates cover the pool:
  * ``uniform``  — every layer attention + FFN (dense or MoE):
                   starcoder2, qwen2.5, danube, deepseek, moonshot, grok,
                   musicgen, internvl2 backbones;
  * ``ssm``      — every layer a Mamba2 mixer: mamba2-130m;
  * ``hybrid``   — scan over periods of ``attn_period`` layers with one
                   attention layer per period and MoE on alternating layers:
                   jamba-1.5-large.

Layers are stacked on a leading axis and iterated with ``lax.scan`` so the
HLO stays O(1) in depth (fast multi-pod compiles, clean roofline attribution).
Forward passes are binarization-agnostic (see models/layers.py).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.models import attention as A
from repro.models import mlp as M
from repro.models import moe as MOE
from repro.models import ssm as S
from repro.models.layers import embed_lookup, lm_init, rms_norm


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _stacked(init_one, key, n: int):
    return jax.vmap(init_one)(jax.random.split(key, n))


def init_lm(cfg, key) -> dict:
    keys = jax.random.split(key, 8)
    params: dict[str, Any] = {
        "embed": {"embedding": lm_init(keys[0], (cfg.vocab_size, cfg.d_model),
                                       fan_in=cfg.d_model)},
        "final_norm": {"scale": jnp.zeros((cfg.d_model,))},
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = {"kernel": lm_init(keys[1], (cfg.d_model, cfg.vocab_size))}

    if cfg.family == "ssm":
        params["layers"] = {
            "ssm": _stacked(lambda k: S.init_ssm(k, cfg, lm_init), keys[2], cfg.n_layers),
            "ln1": {"scale": jnp.zeros((cfg.n_layers, cfg.d_model))},
        }
        return params

    if cfg.is_hybrid:
        per = cfg.attn_period
        n_per = cfg.n_layers // per
        n_mamba = per - 1
        n_moe = sum(cfg.moe_layer(i) for i in range(per))
        n_dense = per - n_moe
        params["layers"] = {
            "attn": _stacked(lambda k: A.init_attn(k, cfg, lm_init), keys[2], n_per),
            "mamba": jax.vmap(lambda ks: _stacked(
                lambda k: S.init_ssm(k, cfg, lm_init), ks, n_mamba))(
                jax.random.split(keys[3], n_per)),
            "mlp": jax.vmap(lambda ks: _stacked(
                lambda k: M.init_mlp(k, cfg, lm_init), ks, n_dense))(
                jax.random.split(keys[4], n_per)),
            "moe": jax.vmap(lambda ks: _stacked(
                lambda k: MOE.init_moe(k, cfg, lm_init), ks, n_moe))(
                jax.random.split(keys[5], n_per)),
            "ln1": {"scale": jnp.zeros((n_per, per, cfg.d_model))},
            "ln2": {"scale": jnp.zeros((n_per, per, cfg.d_model))},
        }
        return params

    # uniform
    layer_p = {
        "attn": _stacked(lambda k: A.init_attn(k, cfg, lm_init), keys[2], cfg.n_layers),
        "ln1": {"scale": jnp.zeros((cfg.n_layers, cfg.d_model))},
        "ln2": {"scale": jnp.zeros((cfg.n_layers, cfg.d_model))},
    }
    if cfg.n_experts and cfg.moe_every == 1:
        layer_p["moe"] = _stacked(lambda k: MOE.init_moe(k, cfg, lm_init),
                                  keys[3], cfg.n_layers)
    else:
        layer_p["mlp"] = _stacked(lambda k: M.init_mlp(k, cfg, lm_init),
                                  keys[3], cfg.n_layers)
    params["layers"] = layer_p
    return params


# ---------------------------------------------------------------------------
# forward (training / scoring): tokens or embeds -> logits
# ---------------------------------------------------------------------------

def _maybe_remat(fn, cfg):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        return jax.checkpoint(fn, policy=jax.checkpoint_policies.dots_saveable)
    return jax.checkpoint(fn)


# A/B measured in EXPERIMENTS.md §Perf iteration 4: nested per-sublayer
# checkpoints ADDED 18% recompute FLOPs and 10 GB peak on jamba train
# (XLA's buffer assignment does not exploit the finer structure under the
# outer scan remat), so outer-body remat only is the default.
SUB_REMAT = False


def _sub_remat(fn, cfg):
    """Per-SUBLAYER remat nested inside the outer scan-body remat: the
    backward recomputes one sublayer at a time, bounding the live set to one
    sublayer's internals + the (sequence-parallel, small) residuals.
    Measured against outer-only remat in EXPERIMENTS.md §Perf iteration 4."""
    if cfg.remat == "none" or not SUB_REMAT:
        return fn
    return jax.checkpoint(fn)


def _embed_in(cfg, params, tokens_or_embeds, sh):
    if tokens_or_embeds.dtype in (jnp.int32, jnp.int64):
        x = embed_lookup(params["embed"]["embedding"], tokens_or_embeds,
                         cfg.activation_dtype)
    else:
        x = tokens_or_embeds.astype(cfg.activation_dtype)  # stubbed frontend
    return sh.act(x, "btd") if sh is not None else x


def _head_out(cfg, params, x, sh):
    x = rms_norm(x, params["final_norm"]["scale"])
    if cfg.tie_embeddings:
        w = params["embed"]["embedding"].astype(x.dtype).T
    else:
        w = params["lm_head"]["kernel"].astype(x.dtype)
    logits = jnp.dot(x, w)
    return sh.act(logits, "btv") if sh is not None else logits


def _decode_head_out(cfg, params, x, sh):
    """Decode head: col-parallel logits matmul + ONE deferred gather.

    The "btv" constraint inside :func:`_head_out` keeps the dot's output
    vocab-sharded (weight-stationary — pinning it replicated makes GSPMD
    all-gather the whole tied-embedding table instead of the logits), and
    the "bv" constraint here is the single small (B, V) gather the whole
    decode step defers to."""
    logits = _head_out(cfg, params, x, sh)[:, -1]
    return sh.act(logits, "bv") if sh is not None else logits


def forward(cfg, params, tokens_or_embeds, sh=None):
    """Full-sequence forward -> (logits, aux)."""
    x = _embed_in(cfg, params, tokens_or_embeds, sh)
    positions = jnp.arange(x.shape[1], dtype=jnp.int32)

    if cfg.family == "ssm":
        ssm_fn = _sub_remat(lambda p, h: S.ssm_forward(cfg, p, h, sh), cfg)

        def body(carry, lp):
            x = carry
            h = rms_norm(x, lp["ln1"]["scale"])
            x = x + ssm_fn(lp["ssm"], h)
            return sh.act(x, "btd") if sh is not None else x, ()

        x, _ = jax.lax.scan(_maybe_remat(body, cfg), x, params["layers"])
        return _head_out(cfg, params, x, sh), {"lb_loss": jnp.float32(0)}

    if cfg.is_hybrid:
        x, aux = _hybrid_scan(cfg, params, x, positions, sh)
        return _head_out(cfg, params, x, sh), aux

    attn_fn = _sub_remat(
        lambda p, h: A.attention(cfg, p, h, positions, sh), cfg)
    mlp_fn = _sub_remat(lambda p, h: M.mlp(cfg, p, h, sh), cfg)
    moe_fn = _sub_remat(lambda p, h: MOE.moe_ffn(cfg, p, h, sh), cfg)

    def body(carry, lp):
        x, lb = carry
        h = rms_norm(x, lp["ln1"]["scale"])
        x = x + attn_fn(lp["attn"], h)
        h = rms_norm(x, lp["ln2"]["scale"])
        if "moe" in lp:
            y, aux = moe_fn(lp["moe"], h)
            lb = lb + aux["lb_loss"]
        else:
            y = mlp_fn(lp["mlp"], h)
        x = x + y
        return ((sh.act(x, "btd") if sh is not None else x), lb), ()

    (x, lb), _ = jax.lax.scan(_maybe_remat(body, cfg),
                              (x, jnp.float32(0)), params["layers"])
    return _head_out(cfg, params, x, sh), {"lb_loss": lb}


def _hybrid_scan(cfg, params, x, positions, sh):
    per = cfg.attn_period
    attn_at = per // 2
    attn_fn = _sub_remat(
        lambda p, h: A.attention(cfg, p, h, positions, sh), cfg)
    ssm_fn = _sub_remat(lambda p, h: S.ssm_forward(cfg, p, h, sh), cfg)
    mlp_fn = _sub_remat(lambda p, h: M.mlp(cfg, p, h, sh), cfg)
    moe_fn = _sub_remat(lambda p, h: MOE.moe_ffn(cfg, p, h, sh), cfg)

    def body(carry, lp):
        x, lb = carry
        mi = di = oi = 0
        for j in range(per):
            h = rms_norm(x, lp["ln1"]["scale"][j])
            if j == attn_at:
                x = x + attn_fn(lp["attn"], h)
            else:
                mamba_j = jax.tree.map(lambda a, i=mi: a[i], lp["mamba"])
                x = x + ssm_fn(mamba_j, h)
                mi += 1
            h = rms_norm(x, lp["ln2"]["scale"][j])
            if cfg.moe_layer(j):
                moe_j = jax.tree.map(lambda a, i=oi: a[i], lp["moe"])
                y, aux = moe_fn(moe_j, h)
                lb = lb + aux["lb_loss"]
                oi += 1
            else:
                mlp_j = jax.tree.map(lambda a, i=di: a[i], lp["mlp"])
                y = mlp_fn(mlp_j, h)
                di += 1
            x = x + y
            if sh is not None:
                x = sh.act(x, "btd")
        return (x, lb), ()

    (x, lb), _ = jax.lax.scan(_maybe_remat(body, cfg),
                              (x, jnp.float32(0)), params["layers"])
    return x, {"lb_loss": lb}


# ---------------------------------------------------------------------------
# decode cache
# ---------------------------------------------------------------------------

def init_cache(cfg, batch: int, context_len: int, dtype=None) -> dict:
    """Zeroed decode cache for a context of ``context_len`` tokens."""
    dtype = dtype or cfg.activation_dtype
    s_kv = A.cache_length(cfg, context_len)
    cache: dict[str, Any] = {"pos": jnp.zeros((batch,), jnp.int32)}
    conv_dim = cfg.d_inner + 2 * cfg.ssm_state
    if cfg.family == "ssm":
        cache["ssm"] = jnp.zeros(
            (cfg.n_layers, batch, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state),
            jnp.float32)
        cache["conv"] = jnp.zeros(
            (cfg.n_layers, batch, cfg.ssm_conv_width - 1, conv_dim), dtype)
        return cache
    if cfg.is_hybrid:
        n_per = cfg.n_layers // cfg.attn_period
        nm = cfg.attn_period - 1
        cache["k"] = jnp.zeros((n_per, batch, s_kv, cfg.n_kv_heads, cfg.head_dim), dtype)
        cache["v"] = jnp.zeros_like(cache["k"])
        cache["ssm"] = jnp.zeros(
            (n_per, nm, batch, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state),
            jnp.float32)
        cache["conv"] = jnp.zeros(
            (n_per, nm, batch, cfg.ssm_conv_width - 1, conv_dim), dtype)
        return cache
    cache["k"] = jnp.zeros(
        (cfg.n_layers, batch, s_kv, cfg.n_kv_heads, cfg.head_dim), dtype)
    cache["v"] = jnp.zeros_like(cache["k"])
    return cache


def cache_slot_axes(cfg) -> dict[str, int]:
    """Slot (batch) axis of every decode-cache entry for this family.

    The decode cache is a long-lived, slot-addressed structure under
    continuous batching: each request owns one index along these axes for
    its lifetime, and ``cache_insert`` splices a freshly prefilled request
    in without touching the other slots."""
    if cfg.family == "ssm":
        return {"pos": 0, "ssm": 1, "conv": 1}
    if cfg.is_hybrid:
        return {"pos": 0, "k": 1, "v": 1, "ssm": 2, "conv": 2}
    return {"pos": 0, "k": 1, "v": 1}


def cache_pspecs(cfg, dp_axes=("data",)) -> dict:
    """PartitionSpec per decode-cache entry: slots (the continuous-batching
    batch dim) shard over the data axes; every other axis — in particular
    the KV sequence — is *replicated* over "model" (matching the serving
    ``cache_kv`` / ``ssm_state`` kinds of a ``decode=True``
    ``repro.distributed.sharding.ShardCtx``). Replicating the sequence axis
    trades per-device cache bytes for copy-free updates: the per-step
    ``.at[slot, pos].set`` write and ``cache_insert`` splice are then
    device-local scatters into a donated buffer, where the earlier
    seq-over-"model" flash-decoding layout cost ~10 collectives + reshard
    copies per decode step (measured in ``benchmarks/golden_plans/
    collectives.json`` before/after — see docs/ARCHITECTURE.md §Decode-step
    collective budget). Keyed like :func:`cache_slot_axes`; used by
    ``ServeEngine.init_decode`` to place the persistent
    :class:`~repro.serve.engine.DecodeState` on a mesh. ``dp_axes`` may be
    empty (a pure tensor-parallel mesh with no data axis): the whole cache
    then replicates. Specs shorter than an entry's rank replicate the
    trailing dims."""
    from jax.sharding import PartitionSpec as P

    dp = (tuple(dp_axes) if len(dp_axes) > 1
          else dp_axes[0] if dp_axes else None)
    if cfg.family == "ssm":
        return {"pos": P(dp),
                "ssm": P(None, dp),        # (L, B, H, hp, N)
                "conv": P(None, dp)}       # (L, B, w-1, conv_dim)
    if cfg.is_hybrid:
        return {"pos": P(dp),
                "k": P(None, dp),          # (n_per, B, S, kv, hd)
                "v": P(None, dp),
                "ssm": P(None, None, dp),  # (n_per, nm, B, H, ...)
                "conv": P(None, None, dp)}
    return {"pos": P(dp),
            "k": P(None, dp),              # (L, B, S, kv, hd)
            "v": P(None, dp)}


def cache_insert(cfg, cache: dict, one: dict, slot) -> dict:
    """Insert a batch-1 cache ``one`` into ``cache`` at slot index ``slot``.

    ``one`` must come from a prefill with the same ``max_len`` (so the
    context axes already agree); ``slot`` may be a traced int32 scalar —
    all shapes are static, so a jitted caller never re-specializes on the
    slot index. Returns the updated cache (other slots untouched)."""
    axes = cache_slot_axes(cfg)
    if set(axes) != set(cache):
        raise ValueError(
            f"cache_slot_axes is out of sync with the cache layout: axes "
            f"cover {sorted(axes)}, cache has {sorted(cache)} — an entry "
            f"left out would silently keep the slot's previous occupant")
    out = dict(cache)
    for name, axis in axes.items():
        upd = one[name].astype(cache[name].dtype)
        if upd.shape[axis] != 1:
            raise ValueError(
                f"cache_insert expects a batch-1 cache; {name!r} has "
                f"{upd.shape[axis]} slots on axis {axis}")
        out[name] = jax.lax.dynamic_update_slice_in_dim(
            cache[name], upd, slot, axis=axis)
    return out


def cache_extract(cfg, cache: dict, slot) -> dict:
    """Batch-1 snapshot of one slot's cache rows (inverse of
    :func:`cache_insert`). ``slot`` may be traced; shapes are static."""
    axes = cache_slot_axes(cfg)
    return {name: jax.lax.dynamic_slice_in_dim(cache[name], slot, 1, axis=ax)
            for name, ax in axes.items()}


def cache_keep(cfg, old: dict, new: dict, keep) -> dict:
    """Per-slot merge of two caches: slots where ``keep`` (bool (n_slots,))
    is True retain ``old``'s rows, the rest take ``new``.

    This is what makes a partially-prefilled slot survive the fused
    decode+prefill step: a plain ``decode_step`` advances every slot's
    state, so the fused step re-selects the old rows for mid-prefill slots
    before the chunk runs. Only state a pending chunk cannot rewrite is
    re-selected — the position counters (pinning ``pos`` stops the
    per-step climb, confining the foreign decode's K/V write to the one
    index the slot's next chunk overwrites before anything reads it; the
    chunk masks by its host-tracked offset and sets ``pos`` absolutely)
    and the recurrent ``ssm``/``conv`` states (a multiplicative update, so
    a foreign decode corrupts them irreversibly). Append-style K/V
    buffers pass through untouched: a full-cache ``jnp.where`` would keep
    both copies alive and force XLA to materialize the whole cache every
    fused step, costing more than the prefill chunk itself. Selection is
    elementwise (bit-exact, GSPMD-local)."""
    axes = cache_slot_axes(cfg)
    out = dict(new)
    for name, axis in axes.items():
        if name not in ("pos", "ssm", "conv"):
            continue
        shape = [1] * old[name].ndim
        shape[axis] = old[name].shape[axis]
        out[name] = jnp.where(keep.reshape(shape), old[name], new[name])
    return out


def _set_pos(pos, slot, value):
    upd = jnp.reshape(value, (1,)).astype(pos.dtype)
    return jax.lax.dynamic_update_slice_in_dim(pos, upd, slot, axis=0)


def prefill_chunk(cfg, params, cache: dict, tokens, slot, offset, sh=None):
    """Advance ONE slot's prefill by a chunk of C prompt tokens.

    tokens: (1, C) int32 with C static; ``slot`` / ``offset`` are traced
    int32 scalars, ``offset`` the number of prompt tokens already in the
    slot. The partially-prefilled slot is a first-class cache state for
    every family: attention reads the slot's pre-write rows and masks
    exactly what a whole-prompt prefill would see (ring-aware for sliding
    windows), ssm/hybrid thread the slot's recurrent + conv states through
    the chunk. Returns (last-token logits (1, V), new_cache) with
    ``cache["pos"][slot]`` advanced to ``offset + C``."""
    x = _embed_in(cfg, params, tokens, sh)
    c = x.shape[1]
    new_pos = _set_pos(cache["pos"], slot, offset + c)

    if cfg.family == "ssm":
        st0 = jax.lax.dynamic_slice_in_dim(cache["ssm"], slot, 1, axis=1)
        cv0 = jax.lax.dynamic_slice_in_dim(cache["conv"], slot, 1, axis=1)
        # offset == 0 is a FRESH prefill: the slot's resident state belongs
        # to its previous occupant and must read as start-of-sequence zeros
        # (attention needs no gate — masking zeroes stale lanes exactly)
        st0 = jnp.where(offset > 0, st0, jnp.zeros_like(st0))
        cv0 = jnp.where(offset > 0, cv0, jnp.zeros_like(cv0))

        def body(x, xs):
            lp, st, cv = xs
            h = rms_norm(x, lp["ln1"]["scale"])
            y, st, cv = S.ssm_forward(cfg, lp["ssm"], h, sh, chunk=c,
                                      return_state=True,
                                      initial_state=st, conv_state=cv)
            return x + y, (st, cv)

        x, (sts, cvs) = jax.lax.scan(
            body, x, (params["layers"], st0, cv0))
        new_cache = dict(
            cache,
            ssm=jax.lax.dynamic_update_slice_in_dim(
                cache["ssm"], sts.astype(cache["ssm"].dtype), slot, axis=1),
            conv=jax.lax.dynamic_update_slice_in_dim(
                cache["conv"], cvs.astype(cache["conv"].dtype), slot, axis=1),
            pos=new_pos)
        return _decode_head_out(cfg, params, x[:, -1:], sh), new_cache

    if cfg.is_hybrid:
        return _hybrid_prefill_chunk(cfg, params, cache, x, slot, offset,
                                     new_pos, sh)

    def body(x, xs):
        lp, kc, vc = xs
        h = rms_norm(x, lp["ln1"]["scale"])
        y, kc, vc = A.chunk_attention(cfg, lp["attn"], h, kc, vc,
                                      slot, offset, sh)
        x = x + y
        h = rms_norm(x, lp["ln2"]["scale"])
        if "moe" in lp:
            y, _ = MOE.moe_ffn(cfg, lp["moe"], h, sh)
        else:
            y = M.mlp(cfg, lp["mlp"], h, sh)
        return x + y, (kc, vc)

    x, (new_k, new_v) = jax.lax.scan(
        body, x, (params["layers"], cache["k"], cache["v"]))
    new_cache = dict(cache, k=new_k, v=new_v, pos=new_pos)
    return _decode_head_out(cfg, params, x[:, -1:], sh), new_cache


def _hybrid_prefill_chunk(cfg, params, cache, x, slot, offset, new_pos, sh):
    per = cfg.attn_period
    attn_at = per // 2
    c = x.shape[1]

    def body(x, xs):
        lp, kc, vc, stc, cvc = xs
        st_s = jax.lax.dynamic_slice_in_dim(stc, slot, 1, axis=1)
        cv_s = jax.lax.dynamic_slice_in_dim(cvc, slot, 1, axis=1)
        # fresh prefill (offset == 0): stale occupant state reads as zeros
        st_s = jnp.where(offset > 0, st_s, jnp.zeros_like(st_s))
        cv_s = jnp.where(offset > 0, cv_s, jnp.zeros_like(cv_s))
        mi = di = oi = 0
        new_st, new_cv = [], []
        for j in range(per):
            h = rms_norm(x, lp["ln1"]["scale"][j])
            if j == attn_at:
                y, kc, vc = A.chunk_attention(cfg, lp["attn"], h, kc, vc,
                                              slot, offset, sh)
            else:
                mamba_j = jax.tree.map(lambda a, i=mi: a[i], lp["mamba"])
                y, st, cv = S.ssm_forward(cfg, mamba_j, h, sh, chunk=c,
                                          return_state=True,
                                          initial_state=st_s[mi],
                                          conv_state=cv_s[mi])
                new_st.append(st)
                new_cv.append(cv)
                mi += 1
            x = x + y
            h = rms_norm(x, lp["ln2"]["scale"][j])
            if cfg.moe_layer(j):
                moe_j = jax.tree.map(lambda a, i=oi: a[i], lp["moe"])
                y, _ = MOE.moe_ffn(cfg, moe_j, h, sh)
                oi += 1
            else:
                mlp_j = jax.tree.map(lambda a, i=di: a[i], lp["mlp"])
                y = M.mlp(cfg, mlp_j, h, sh)
                di += 1
            x = x + y
        stc = jax.lax.dynamic_update_slice_in_dim(
            stc, jnp.stack(new_st).astype(stc.dtype), slot, axis=1)
        cvc = jax.lax.dynamic_update_slice_in_dim(
            cvc, jnp.stack(new_cv).astype(cvc.dtype), slot, axis=1)
        return x, (kc, vc, stc, cvc)

    x, (nk, nv, nst, ncv) = jax.lax.scan(
        body, x, (params["layers"], cache["k"], cache["v"],
                  cache["ssm"], cache["conv"]))
    new_cache = dict(cache, k=nk, v=nv, ssm=nst, conv=ncv, pos=new_pos)
    return _decode_head_out(cfg, params, x[:, -1:], sh), new_cache


def decode_step(cfg, params, cache: dict, tokens_or_embeds, sh=None):
    """One decode step for the whole batch -> (logits, new_cache).

    tokens: (B, 1) int32 (or (B, 1, D) stub embeddings)."""
    x = _embed_in(cfg, params, tokens_or_embeds, sh)
    pos = cache["pos"]

    if cfg.family == "ssm":
        def body(x, xs):
            lp, st, cv = xs
            h = rms_norm(x, lp["ln1"]["scale"])
            y, st, cv = S.ssm_decode_step(cfg, lp["ssm"], h, st, cv)
            return x + y, (st, cv)

        x, (new_ssm, new_conv) = jax.lax.scan(
            body, x, (params["layers"], cache["ssm"], cache["conv"]))
        new_cache = dict(cache, ssm=new_ssm, conv=new_conv, pos=pos + 1)
        return _decode_head_out(cfg, params, x, sh), new_cache

    if cfg.is_hybrid:
        return _hybrid_decode(cfg, params, cache, x, sh)

    def body(x, xs):
        lp, kc, vc = xs
        h = rms_norm(x, lp["ln1"]["scale"])
        y, kc, vc = A.decode_attention(cfg, lp["attn"], h, kc, vc, pos, sh)
        x = x + y
        h = rms_norm(x, lp["ln2"]["scale"])
        if "moe" in lp:
            y, _ = MOE.moe_ffn(cfg, lp["moe"], h, sh)
        else:
            y = M.mlp(cfg, lp["mlp"], h, sh)
        return x + y, (kc, vc)

    x, (new_k, new_v) = jax.lax.scan(
        body, x, (params["layers"], cache["k"], cache["v"]))
    new_cache = dict(cache, k=new_k, v=new_v, pos=pos + 1)
    return _decode_head_out(cfg, params, x, sh), new_cache


def _hybrid_decode(cfg, params, cache, x, sh):
    per = cfg.attn_period
    attn_at = per // 2
    pos = cache["pos"]

    def body(x, xs):
        lp, kc, vc, stc, cvc = xs
        mi = di = oi = 0
        new_st, new_cv = [], []
        for j in range(per):
            h = rms_norm(x, lp["ln1"]["scale"][j])
            if j == attn_at:
                y, kc, vc = A.decode_attention(cfg, lp["attn"], h, kc, vc, pos, sh)
            else:
                mamba_j = jax.tree.map(lambda a, i=mi: a[i], lp["mamba"])
                y, st, cv = S.ssm_decode_step(cfg, mamba_j, h, stc[mi], cvc[mi])
                new_st.append(st)
                new_cv.append(cv)
                mi += 1
            x = x + y
            h = rms_norm(x, lp["ln2"]["scale"][j])
            if cfg.moe_layer(j):
                moe_j = jax.tree.map(lambda a, i=oi: a[i], lp["moe"])
                y, _ = MOE.moe_ffn(cfg, moe_j, h, sh)
                oi += 1
            else:
                mlp_j = jax.tree.map(lambda a, i=di: a[i], lp["mlp"])
                y = M.mlp(cfg, mlp_j, h, sh)
                di += 1
            x = x + y
        return x, (kc, vc, jnp.stack(new_st), jnp.stack(new_cv))

    x, (nk, nv, nst, ncv) = jax.lax.scan(
        body, x, (params["layers"], cache["k"], cache["v"],
                  cache["ssm"], cache["conv"]))
    new_cache = dict(cache, k=nk, v=nv, ssm=nst, conv=ncv, pos=pos + 1)
    return _decode_head_out(cfg, params, x, sh), new_cache


# ---------------------------------------------------------------------------
# prefill: full context -> (last-token logits, populated cache)
# ---------------------------------------------------------------------------

def _to_cache_layout(cfg, k: jax.Array, s: int, s_kv: int) -> jax.Array:
    """(B, S, KV, hd) prefill keys -> ring/linear cache of length s_kv.

    Invariant shared with ``decode_attention``: token at absolute position
    ``p`` lives at slot ``p % s_kv`` (ring) for sliding-window archs, slot
    ``p`` (linear) otherwise."""
    if cfg.sliding_window and s > s_kv:
        k = k[:, -s_kv:]
        return jnp.roll(k, shift=(s - s_kv) % s_kv, axis=1)
    if s < s_kv:
        return jnp.pad(k, ((0, 0), (0, s_kv - s)) + ((0, 0),) * (k.ndim - 2))
    return k


def prefill(cfg, params, tokens_or_embeds, sh=None, max_len: int | None = None):
    """Prefill ``s`` context tokens; cache is sized for ``max_len`` total
    positions (default ``s + 1`` so at least one decode step fits)."""
    x = _embed_in(cfg, params, tokens_or_embeds, sh)
    bsz, s = x.shape[0], x.shape[1]
    positions = jnp.arange(s, dtype=jnp.int32)
    s_kv = A.cache_length(cfg, max_len if max_len is not None else s + 1)

    if cfg.family == "ssm":
        def body(x, lp):
            h = rms_norm(x, lp["ln1"]["scale"])
            y, st, cv = S.ssm_forward(cfg, lp["ssm"], h, sh, return_state=True)
            return x + y, (st, cv)

        x, (sts, cvs) = jax.lax.scan(body, x, params["layers"])
        cache = {"ssm": sts, "conv": cvs,
                 "pos": jnp.full((bsz,), s, jnp.int32)}
        return _head_out(cfg, params, x, sh)[:, -1], cache

    if cfg.is_hybrid:
        return _hybrid_prefill(cfg, params, x, positions, sh, max_len)

    def body(x, lp):
        h = rms_norm(x, lp["ln1"]["scale"])
        y, k, v = A.attention_with_cache_write(cfg, lp["attn"], h, positions, sh)
        x = x + y
        h = rms_norm(x, lp["ln2"]["scale"])
        if "moe" in lp:
            y, _ = MOE.moe_ffn(cfg, lp["moe"], h, sh)
        else:
            y = M.mlp(cfg, lp["mlp"], h, sh)
        return x + y, (_to_cache_layout(cfg, k.astype(cfg.activation_dtype), s, s_kv),
                       _to_cache_layout(cfg, v.astype(cfg.activation_dtype), s, s_kv))

    x, (ks, vs) = jax.lax.scan(body, x, params["layers"])
    if sh is not None:
        ks, vs = sh.act(ks, "cache_kv"), sh.act(vs, "cache_kv")
    cache = {"k": ks, "v": vs, "pos": jnp.full((bsz,), s, jnp.int32)}
    return _head_out(cfg, params, x, sh)[:, -1], cache


def _hybrid_prefill(cfg, params, x, positions, sh, max_len: int | None = None):
    per = cfg.attn_period
    attn_at = per // 2
    bsz, s = x.shape[0], x.shape[1]
    s_kv = A.cache_length(cfg, max_len if max_len is not None else s + 1)

    def body(x, lp):
        mi = di = oi = 0
        sts, cvs = [], []
        kout = vout = None
        for j in range(per):
            h = rms_norm(x, lp["ln1"]["scale"][j])
            if j == attn_at:
                y, k, v = A.attention_with_cache_write(cfg, lp["attn"], h, positions, sh)
                kout = _to_cache_layout(cfg, k.astype(cfg.activation_dtype), s, s_kv)
                vout = _to_cache_layout(cfg, v.astype(cfg.activation_dtype), s, s_kv)
            else:
                mamba_j = jax.tree.map(lambda a, i=mi: a[i], lp["mamba"])
                y, st, cv = S.ssm_forward(cfg, mamba_j, h, sh, return_state=True)
                sts.append(st)
                cvs.append(cv)
                mi += 1
            x = x + y
            h = rms_norm(x, lp["ln2"]["scale"][j])
            if cfg.moe_layer(j):
                moe_j = jax.tree.map(lambda a, i=oi: a[i], lp["moe"])
                y, _ = MOE.moe_ffn(cfg, moe_j, h, sh)
                oi += 1
            else:
                mlp_j = jax.tree.map(lambda a, i=di: a[i], lp["mlp"])
                y = M.mlp(cfg, mlp_j, h, sh)
                di += 1
            x = x + y
        return x, (kout, vout, jnp.stack(sts), jnp.stack(cvs))

    x, (ks, vs, sts, cvs) = jax.lax.scan(body, x, params["layers"])
    cache = {"k": ks, "v": vs, "ssm": sts, "conv": cvs,
             "pos": jnp.full((bsz,), s, jnp.int32)}
    return _head_out(cfg, params, x, sh)[:, -1], cache
