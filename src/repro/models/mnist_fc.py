"""The paper's permutation-invariant fully-connected MNIST network.

Architecture per the paper's §III-A (and the BinaryConnect lineage it cites):
784 -> hidden -> hidden -> hidden -> 10, batch-norm after every layer output,
softmax + cross-entropy, He initialization, SGD momentum with the Eq.-(4)
adaptive learning-rate decay (implemented in ``repro.optim``).

``apply`` is binarization-agnostic; Alg. 1 binarizes the kernels upstream in
``train_step``.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.core.binarize import binarize
from repro.models.layers import apply_linear, batch_norm, he_normal

DEFAULT_HIDDEN = (2048, 2048, 2048)
N_CLASSES = 10
IN_DIM = 784


def init(key, hidden=DEFAULT_HIDDEN, in_dim: int = IN_DIM,
         n_classes: int = N_CLASSES) -> dict:
    dims = (in_dim,) + tuple(hidden) + (n_classes,)
    params: dict[str, Any] = {"layers": []}
    state: dict[str, Any] = {"layers": []}
    keys = jax.random.split(key, len(dims) - 1)
    for i, (a, b) in enumerate(zip(dims[:-1], dims[1:])):
        params["layers"].append({
            "kernel": he_normal(keys[i], (a, b)),
            "bias": jnp.zeros((b,)),
            "bn_scale": jnp.ones((b,)),
            "bn_bias": jnp.zeros((b,)),
        })
        state["layers"].append({
            "mean": jnp.zeros((b,)),
            "var": jnp.ones((b,)),
        })
    return {"params": params, "state": state}


def apply(params: dict, state: dict, x: jax.Array, *, training: bool,
          binary_act: bool = False):
    """x: (B, 784) -> (logits (B, 10), new_state).

    With ``binary_act=True`` the hidden non-linearity is the Eq.-(1) sign
    (straight-through gradient) instead of ReLU: every hidden activation is
    ±1, so hidden layers packed as ``XnorLinear`` compute exact XNOR-popcount
    dot products (the fully-binary path; the first layer still sees the
    real-valued input, matching the paper)."""
    new_state = {"layers": []}
    h = x
    n = len(params["layers"])
    for i, (lp, ls) in enumerate(zip(params["layers"], state["layers"])):
        h = apply_linear(lp["kernel"], h, lp["bias"])
        h, m, v = batch_norm(h, lp["bn_scale"], lp["bn_bias"],
                             ls["mean"], ls["var"], training=training)
        new_state["layers"].append({"mean": m, "var": v})
        if i < n - 1:
            h = binarize(h, "det") if binary_act else jax.nn.relu(h)
    return h, new_state
