"""Feed-forward blocks: SwiGLU (llama-family) and 2-matmul GELU (starcoder,
musicgen)."""
from __future__ import annotations

import jax

from repro.models.layers import apply_linear


def init_mlp(key, cfg, init_fn, d_ff=None) -> dict:
    d_ff = d_ff or cfg.d_ff
    if cfg.mlp_type == "glu":
        k1, k2, k3 = jax.random.split(key, 3)
        return {
            "w_gate": init_fn(k1, (cfg.d_model, d_ff)),
            "w_up": init_fn(k2, (cfg.d_model, d_ff)),
            "w_down": init_fn(k3, (d_ff, cfg.d_model)),
        }
    k1, k2 = jax.random.split(key)
    return {
        "wi": init_fn(k1, (cfg.d_model, d_ff)),
        "wo": init_fn(k2, (d_ff, cfg.d_model)),
    }


def mlp(cfg, params: dict, x: jax.Array, sh=None) -> jax.Array:
    # activation constraints ride through the dispatch seam (sh/kind on
    # apply_linear), so packed / xnor serving leaves get the same TP layout
    # as the dense path
    if "w_gate" in params:
        g = apply_linear(params["w_gate"], x, sh=sh, kind="btf")
        u = apply_linear(params["w_up"], x, sh=sh, kind="btf")
        h = jax.nn.silu(g) * u
        return apply_linear(params["w_down"], h, sh=sh, kind="btd")
    h = apply_linear(params["wi"], x, sh=sh, kind="btf")
    h = jax.nn.gelu(h)
    return apply_linear(params["wo"], h, sh=sh, kind="btd")
