"""Modality frontend stubs for the [audio] / [vlm] assigned architectures.

Per the assignment, these archs specify the transformer BACKBONE only: the
EnCodec tokenizer (musicgen) and the InternViT patch tower (internvl2) are
STUBS whose role is to define the *shape contract* — ``input_specs()``
provides precomputed frame/patch embeddings of shape (batch, seq, d_model).
The functions here generate deterministic synthetic embeddings matching that
contract for smoke tests and examples.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def frame_embeddings(key, batch: int, seq: int, d_model: int,
                     dtype=jnp.bfloat16) -> jax.Array:
    """Stub EnCodec frame embeddings (musicgen)."""
    return (jax.random.normal(key, (batch, seq, d_model), jnp.float32)
            * 0.02).astype(dtype)


def patch_embeddings(key, batch: int, seq: int, d_model: int,
                     dtype=jnp.bfloat16) -> jax.Array:
    """Stub InternViT patch embeddings (internvl2)."""
    return (jax.random.normal(key, (batch, seq, d_model), jnp.float32)
            * 0.02).astype(dtype)


STUBS = {"frames": frame_embeddings, "patch": patch_embeddings}
