"""Mixture-of-Experts FFN with top-k routing and capacity-based dispatch.

Dispatch is sort-based (argsort by expert id -> position-in-expert ->
scatter into an (E, capacity, D) buffer), which keeps every shape static for
pjit while doing only *active* FLOPs (E * C * D * F with E*C ~= tokens * k).
Expert weight tensors are stacked (E, ...) so experts shard over the
``model`` mesh axis (EP) when E % axis == 0, and the buffer's capacity dim
shards over ``data`` — GSPMD inserts the token all-to-all at the dispatch
boundary exactly like a hand-written EP exchange.

Router stays full-precision (BNN convention); expert projections are
binarized by the default policy.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import PackedLinear


def _expert_matmul(w, xe, dtype):
    """Batched-over-experts matmul: (E, C, a) x (E, a, b) -> (E, C, b),
    where ``w`` is dense or a bitpacked PackedLinear (packed serving)."""
    if isinstance(w, PackedLinear):
        from repro.kernels import ops

        if w.scale is None:
            out = jax.vmap(lambda a, p: ops.binary_matmul(a, p))(xe, w.packed)
        else:
            out = jax.vmap(lambda a, p, s: ops.binary_matmul(a, p, s))(
                xe, w.packed, w.scale)
        return out.astype(dtype)
    return jnp.einsum("eca,eab->ecb", xe, w.astype(dtype))


def init_moe(key, cfg, init_fn) -> dict:
    e, d, f = cfg.n_experts, cfg.d_model, cfg.d_ff
    keys = jax.random.split(key, 4)
    p = {"router": init_fn(keys[0], (d, e), fan_in=d)}
    if cfg.mlp_type == "glu":
        p["w_gate"] = init_fn(keys[1], (e, d, f), fan_in=d)
        p["w_up"] = init_fn(keys[2], (e, d, f), fan_in=d)
        p["w_down"] = init_fn(keys[3], (e, f, d), fan_in=f)
    else:
        p["wi"] = init_fn(keys[1], (e, d, f), fan_in=d)
        p["wo"] = init_fn(keys[2], (e, f, d), fan_in=f)
    return p


def capacity(cfg, n_tokens: int) -> int:
    c = int(n_tokens * cfg.experts_per_token * cfg.capacity_factor
            / max(cfg.n_experts, 1))
    return max(8, -(-c // 8) * 8)  # round up to 8


def moe_ffn(cfg, params: dict, x: jax.Array, sh=None):
    """x: (B, S, D) -> (y, aux). aux carries the load-balancing loss."""
    b, s, d = x.shape
    t = b * s
    k = cfg.experts_per_token
    e = cfg.n_experts
    cap = capacity(cfg, t)
    xt = x.reshape(t, d)

    # --- routing (fp32 for numerics; router excluded from binarization) ---
    logits = jnp.dot(xt.astype(jnp.float32), params["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                      # (T, E)
    topk_p, topk_e = jax.lax.top_k(probs, k)                     # (T, k)
    topk_p = topk_p / jnp.clip(topk_p.sum(-1, keepdims=True), 1e-9)

    # load-balance loss (Switch): E * sum_e f_e * p_e
    me = jnp.mean(probs, axis=0)
    ce = jnp.zeros((e,), jnp.float32).at[topk_e.reshape(-1)].add(1.0) / (t * k)
    lb_loss = e * jnp.sum(me * ce)

    # --- dispatch: sort assignments by expert ---
    e_flat = topk_e.reshape(-1)                                  # (T*k,)
    w_flat = topk_p.reshape(-1).astype(x.dtype)
    tok_flat = jnp.repeat(jnp.arange(t, dtype=jnp.int32), k)

    order = jnp.argsort(e_flat, stable=True)
    se, st, sw = e_flat[order], tok_flat[order], w_flat[order]
    if sh is not None:  # keep assignment vectors data-sharded (EP exchange
        se, st, sw = (sh.act(v, "a") for v in (se, st, sw))  # happens at the
        # (E, cap) buffer boundary, not by replicating 6M-row gathers)
    counts = jnp.zeros((e,), jnp.int32).at[e_flat].add(1)
    starts = jnp.concatenate([jnp.zeros((1,), jnp.int32), jnp.cumsum(counts)[:-1]])
    pos = jnp.arange(t * k, dtype=jnp.int32) - starts[se]
    keep = pos < cap
    pos_c = jnp.where(keep, pos, cap)                            # overflow slot

    # (E, cap+1, D) buffer; the +1 row swallows dropped tokens
    buf = jnp.zeros((e, cap + 1, d), x.dtype).at[se, pos_c].set(xt[st])
    xe = buf[:, :cap]
    if sh is not None:
        xe = sh.act(xe, "ecd")

    # --- expert FFN (batched over E; dense or bitpacked weights) ---
    if "w_gate" in params:
        g = _expert_matmul(params["w_gate"], xe, x.dtype)
        u = _expert_matmul(params["w_up"], xe, x.dtype)
        if sh is not None:
            g, u = sh.act(g, "ecf"), sh.act(u, "ecf")
        h = jax.nn.silu(g) * u
        ye = _expert_matmul(params["w_down"], h, x.dtype)
    else:
        h = _expert_matmul(params["wi"], xe, x.dtype)
        if sh is not None:
            h = sh.act(h, "ecf")
        h = jax.nn.gelu(h)
        ye = _expert_matmul(params["wo"], h, x.dtype)
    if sh is not None:
        ye = sh.act(ye, "ecd")

    # --- combine ---
    y_assign = ye[se, jnp.minimum(pos_c, cap - 1)]               # (T*k, D)
    if sh is not None:
        y_assign = sh.act(y_assign, "ad")
    y_assign = y_assign * (sw * keep.astype(x.dtype))[:, None]
    y = jnp.zeros((t, d), x.dtype).at[st].add(y_assign)
    if sh is not None:
        # shard the scatter-add target on BOTH dims: GSPMD then emits a
        # reduce-scatter instead of a full-buffer all-reduce for the combine
        y = sh.act(y, "ad")
    return y.reshape(b, s, d), {"lb_loss": lb_loss,
                                "dropped_frac": 1.0 - jnp.mean(keep)}
