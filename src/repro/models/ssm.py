"""Mamba2 (SSD — state-space duality) mixer, chunked-scan training form and
O(1)-state decode form.

Follows the minimal SSD formulation of Dao & Gu (arXiv:2405.21060): the
sequence is split into chunks; within a chunk the quadratic dual form runs
(an attention-like einsum masked by the decay kernel), and a ``lax.scan``
passes the (H, P, N) state across chunks. n_groups = 1 (B/C shared across
heads). A depthwise conv precedes the SSM over the [x, B, C] channels.

Binarization applies to in_proj / out_proj only; A_log, dt_bias, D, conv and
the gated RMSNorm stay full precision (see DESIGN.md §Arch-applicability).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import apply_linear, rms_norm


def init_ssm(key, cfg, init_fn) -> dict:
    d, di, n, h = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    conv_dim = di + 2 * n
    keys = jax.random.split(key, 4)
    return {
        "in_proj": init_fn(keys[0], (d, 2 * di + 2 * n + h), fan_in=d),
        "out_proj": init_fn(keys[1], (di, d), fan_in=di),
        "conv": 0.1 * jax.random.normal(keys[2], (cfg.ssm_conv_width, conv_dim)),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, h)),
        "dt_bias": jnp.zeros((h,)),
        "D": jnp.ones((h,)),
        "norm_scale": jnp.zeros((di,)),
    }


def _split_proj(cfg, zxbcdt):
    di, n, h = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    idx = [di, 2 * di, 2 * di + n, 2 * di + 2 * n]
    z, x, b_mat, c_mat, dt = jnp.split(zxbcdt, idx, axis=-1)
    return z, x, b_mat, c_mat, dt


def _causal_conv(xbc: jax.Array, w: jax.Array, history=None) -> jax.Array:
    """Depthwise causal conv, xbc: (B, S, C), w: (W, C).

    ``history`` is an optional (B, W-1, C) window of the raw pre-conv
    channels preceding ``xbc`` (a decode ``conv_state``); ``None`` means
    start-of-sequence, which pads with zeros — bitwise identical to a
    zero history window."""
    width = w.shape[0]
    if history is None:
        pad = jnp.pad(xbc, ((0, 0), (width - 1, 0), (0, 0)))
    else:
        pad = jnp.concatenate([history.astype(xbc.dtype), xbc], axis=1)
    out = jnp.zeros_like(xbc, dtype=jnp.float32)
    for i in range(width):
        out = out + pad[:, i:i + xbc.shape[1]].astype(jnp.float32) * w[i].astype(jnp.float32)
    return jax.nn.silu(out).astype(xbc.dtype)


def ssd_chunked(x, dt, a, b_mat, c_mat, chunk: int, sh=None,
                init_state=None):
    """Chunked SSD scan.

    x: (B, S, H, P); dt: (B, S, H) post-softplus; a: (H,) negative decay;
    b_mat/c_mat: (B, S, N). ``init_state`` is an optional (B, H, P, N)
    carry-in state (mid-prefill continuation); ``None`` starts from zeros,
    which is bitwise identical to passing explicit zeros. Returns
    y: (B, S, H, P) and final state (B, H, P, N)."""
    bsz, s, h, p = x.shape
    n = b_mat.shape[-1]
    nc = s // chunk
    assert nc * chunk == s, f"seq {s} not divisible by chunk {chunk}"

    xc = x.reshape(bsz, nc, chunk, h, p)
    dtc = dt.reshape(bsz, nc, chunk, h)
    bc = b_mat.reshape(bsz, nc, chunk, n)
    cc = c_mat.reshape(bsz, nc, chunk, n)

    da = dtc * a[None, None, None, :]                  # (B, nc, Q, H) log-decay
    cum = jnp.cumsum(da, axis=2)                       # inclusive cumsum

    # --- intra-chunk (dual/quadratic form) ---
    # L[i, j] = exp(cum_i - cum_j) for i >= j else 0       (B, nc, H, Q, Q)
    li = cum[..., :, None, :] - cum[..., None, :, :]       # (B,nc,Q,Q,H)
    mask = jnp.tril(jnp.ones((chunk, chunk), bool))
    # mask the *exponent*: exp of masked (i<j) entries would overflow and
    # poison the backward pass through jnp.where (grad-of-where trap)
    li = jnp.where(mask[None, None, :, :, None], li, -1e30)
    decay = jnp.exp(li)
    if sh is not None:  # (B, nc, Q, Q, H): heads over "model" — the SSD
        decay = sh.act(decay, "bcqqh")  # dual-form blocks dominate memory
    cb = jnp.einsum("bcin,bcjn->bcij", cc, bc)             # (B,nc,Q,Q)
    xdt = xc * dtc[..., None]                              # dt-weighted input
    y_intra = jnp.einsum("bcij,bcijh,bcjhp->bcihp",
                         cb.astype(jnp.float32), decay, xdt.astype(jnp.float32))

    # --- chunk states ---
    seg_end = cum[:, :, -1:, :]                            # (B,nc,1,H)
    state_w = jnp.exp(seg_end - cum)                       # decay to chunk end
    states = jnp.einsum("bcjn,bcjh,bcjhp->bchpn",
                        bc.astype(jnp.float32), state_w.astype(jnp.float32),
                        xdt.astype(jnp.float32))           # (B,nc,H,P,N)
    if sh is not None:
        states = sh.act(states, "bchpn")

    # --- inter-chunk recurrence ---
    chunk_decay = jnp.exp(seg_end[:, :, 0, :])             # (B,nc,H)

    def step(h_prev, inp):
        st, dec = inp                                      # (B,H,P,N), (B,H)
        h_new = h_prev * dec[..., None, None] + st
        return h_new, h_prev

    if init_state is None:
        init = jnp.zeros((bsz, h, p, n), jnp.float32)
    else:
        init = init_state.astype(jnp.float32)
    final_state, h_before = jax.lax.scan(
        step,
        init,
        (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)),
    )
    h_before = jnp.moveaxis(h_before, 0, 1)                # (B,nc,H,P,N)

    # --- inter-chunk output: y_i += C_i . h_chunkstart * exp(cum_i) ---
    y_inter = jnp.einsum("bcin,bcih,bchpn->bcihp",
                         cc.astype(jnp.float32), jnp.exp(cum).astype(jnp.float32),
                         h_before)
    y = (y_intra + y_inter).reshape(bsz, s, h, p)
    return y.astype(x.dtype), final_state


def ssm_forward(cfg, params: dict, x: jax.Array, sh=None,
                chunk: int = 128, return_state: bool = False,
                initial_state=None, conv_state=None):
    """Full-sequence Mamba2 mixer. x: (B, S, D) -> (B, S, D).

    ``initial_state`` (B, H, P, N) and ``conv_state`` (B, W-1, conv_dim)
    continue a partially-consumed sequence (chunked prefill): the SSD scan
    starts from ``initial_state`` and the causal conv sees ``conv_state``
    as its left context. Both default to start-of-sequence (zeros), which
    is bitwise identical to omitting them."""
    bsz, s, d = x.shape
    di, n, h = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    p = cfg.ssm_head_dim

    zxbcdt = apply_linear(params["in_proj"], x, sh=sh, kind="btn")
    z, xi, b_mat, c_mat, dt = _split_proj(cfg, zxbcdt)

    xbc_raw = jnp.concatenate([xi, b_mat, c_mat], axis=-1)
    if conv_state is None:
        conv_tail = xbc_raw[:, s - (cfg.ssm_conv_width - 1):]  # pre-conv window
    else:
        # tail of the history-extended window: always W-1 long, even for
        # chunks shorter than the conv width
        window = jnp.concatenate(
            [conv_state.astype(xbc_raw.dtype), xbc_raw], axis=1)
        conv_tail = window[:, window.shape[1] - (cfg.ssm_conv_width - 1):]
    xbc = _causal_conv(xbc_raw, params["conv"], history=conv_state)
    xi, b_mat, c_mat = jnp.split(xbc, [di, di + n], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + params["dt_bias"].astype(jnp.float32))
    a = -jnp.exp(params["A_log"].astype(jnp.float32))
    xh = xi.reshape(bsz, s, h, p)
    if sh is not None:
        xh = sh.act(xh, "bthd")   # ssm heads over "model" (padded if uneven)
        dt = sh.act(dt, "bsh")

    chunk = min(chunk, s)
    pad = (-s) % chunk
    if pad:
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b_mat = jnp.pad(b_mat, ((0, 0), (0, pad), (0, 0)))
        c_mat = jnp.pad(c_mat, ((0, 0), (0, pad), (0, 0)))
    y, state = ssd_chunked(xh, dt, a, b_mat, c_mat, chunk, sh,
                           init_state=initial_state)
    if pad:
        y = y[:, :s]

    y = y + xh[:, :s] * params["D"].astype(x.dtype)[None, None, :, None]
    y = y.reshape(bsz, s, di)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)  # gate
    y = rms_norm(y, params["norm_scale"])
    out = apply_linear(params["out_proj"], y)
    if return_state:
        return out, state, conv_tail
    return out


def ssm_decode_step(cfg, params: dict, x: jax.Array, ssm_state: jax.Array,
                    conv_state: jax.Array):
    """One-token decode. x: (B, 1, D); ssm_state: (B, H, P, N);
    conv_state: (B, W-1, conv_dim). Returns (out, ssm_state, conv_state)."""
    bsz = x.shape[0]
    di, n, h = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    p = cfg.ssm_head_dim

    zxbcdt = apply_linear(params["in_proj"], x)[:, 0]      # (B, ...)
    z, xi, b_mat, c_mat, dt = _split_proj(cfg, zxbcdt)

    xbc_new = jnp.concatenate([xi, b_mat, c_mat], axis=-1)  # (B, conv_dim)
    window = jnp.concatenate([conv_state, xbc_new[:, None]], axis=1)  # (B, W, C)
    conv_w = params["conv"].astype(jnp.float32)
    xbc = jnp.einsum("bwc,wc->bc", window.astype(jnp.float32), conv_w)
    xbc = jax.nn.silu(xbc).astype(x.dtype)
    new_conv_state = window[:, 1:]
    xi, b_mat, c_mat = jnp.split(xbc, [di, di + n], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + params["dt_bias"].astype(jnp.float32))   # (B, H)
    a = -jnp.exp(params["A_log"].astype(jnp.float32))               # (H,)
    da = jnp.exp(dt * a[None, :])                                   # (B, H)
    xh = xi.reshape(bsz, h, p).astype(jnp.float32)
    dbx = jnp.einsum("bh,bhp,bn->bhpn", dt, xh, b_mat.astype(jnp.float32))
    new_state = ssm_state * da[..., None, None] + dbx
    y = jnp.einsum("bn,bhpn->bhp", c_mat.astype(jnp.float32), new_state)
    y = y + xh * params["D"].astype(jnp.float32)[None, :, None]
    y = y.reshape(bsz, 1, di).astype(x.dtype)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)[:, None, :]
    y = rms_norm(y, params["norm_scale"])
    return apply_linear(params["out_proj"], y), new_state, new_conv_state


def ssd_reference(x, dt, a, b_mat, c_mat):
    """O(S^2)-free naive per-step recurrence oracle for tests."""
    bsz, s, h, p = x.shape
    n = b_mat.shape[-1]

    def step(state, inp):
        xt, dtt, bt, ct = inp
        da = jnp.exp(dtt * a)                                # (B,H)
        dbx = jnp.einsum("bh,bhp,bn->bhpn", dtt, xt, bt)
        state = state * da[..., None, None] + dbx
        y = jnp.einsum("bn,bhpn->bhp", ct, state)
        return state, y

    init = jnp.zeros((bsz, h, p, n), jnp.float32)
    xs = (jnp.moveaxis(x, 1, 0).astype(jnp.float32),
          jnp.moveaxis(dt, 1, 0).astype(jnp.float32),
          jnp.moveaxis(b_mat, 1, 0).astype(jnp.float32),
          jnp.moveaxis(c_mat, 1, 0).astype(jnp.float32))
    state, ys = jax.lax.scan(step, init, xs)
    return jnp.moveaxis(ys, 0, 1), state
