"""Base layers: linear application (dense or bitpacked-binary), norms,
embeddings, rotary position embeddings, initializers.

Models are *binarization-agnostic*: ``train_step`` binarizes the master
parameter tree (Alg. 1) before calling the forward pass, and the serving path
may substitute :class:`PackedLinear` leaves (bitpacked binary weights +
optional per-channel scale), :class:`XnorLinear` / :class:`XnorConv` leaves
(binary weights *and* binary activations, XNOR-popcount compute), or any
other serving leaf registered with ``repro.engine``. ``apply_linear`` and
``apply_conv2d`` dispatch through the backend registry on the leaf type, so
the same model code serves every datapath — which backend each layer gets is
decided (and recorded) by ``repro.engine.compile_plan``.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class PackedLinear:
    """Bitpacked binary weight: ``unpack(packed) * scale`` of shape (K, N)."""

    packed: jax.Array               # (K // 32, N) int32
    scale: jax.Array | None         # (N,) f32 or None
    k: int                          # static original K

    def tree_flatten(self):
        return (self.packed, self.scale), (self.k,)

    @classmethod
    def tree_unflatten(cls, aux, children):
        packed, scale = children
        return cls(packed, scale, aux[0])

    @property
    def shape(self):
        return (self.k, self.packed.shape[-1])

    @property
    def master_shape(self):
        """True master-weight shape incl. leading stack dims (L/E, K, N) —
        the dense-baseline shape for byte accounting, independent of any
        pad words the packed layout carries."""
        return tuple(self.packed.shape[:-2]) + (self.k, self.packed.shape[-1])

    @property
    def ndim(self):
        return 2


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class XnorLinear:
    """Fully-binary linear: weights bitpacked like :class:`PackedLinear`, and
    *activations* sign-binarized + bitpacked on the fly, so the dot product is
    integer XNOR-popcount (``repro.xnor``) — no MXU, no full-width activation
    traffic."""

    packed: jax.Array               # (K // 32, N) int32
    scale: jax.Array | None         # (N,) f32 or None
    k: int                          # static original K

    def tree_flatten(self):
        return (self.packed, self.scale), (self.k,)

    @classmethod
    def tree_unflatten(cls, aux, children):
        packed, scale = children
        return cls(packed, scale, aux[0])

    @property
    def shape(self):
        return (self.k, self.packed.shape[-1])

    @property
    def master_shape(self):
        """True master-weight shape incl. leading stack dims (see
        :class:`PackedLinear`). The packed array may legally hold more
        words than ceil(K/32) (self-cancelling pad layouts); this never
        reflects them."""
        return tuple(self.packed.shape[:-2]) + (self.k, self.packed.shape[-1])

    @property
    def ndim(self):
        return 2


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class XnorConv:
    """Fully-binary 2-D convolution leaf: the (kh, kw, C, N) kernel is
    bitpacked along the flattened kh*kw*C contraction axis (per-tap word
    layout, ``repro.xnor.conv``), and at apply time the input activation is
    sign-binarized + bitpacked into im2col patches on the fly, so the conv
    is an integer XNOR-popcount GEMM — no MXU, 1-bit activation traffic."""

    packed: jax.Array               # (kh*kw*ceil(c_in/32), N) int32
    scale: jax.Array | None         # (N,) f32 or None
    ksize: tuple[int, int]          # static (kh, kw)
    c_in: int                       # static input channels

    def tree_flatten(self):
        return (self.packed, self.scale), (self.ksize, self.c_in)

    @classmethod
    def tree_unflatten(cls, aux, children):
        packed, scale = children
        return cls(packed, scale, aux[0], aux[1])

    @property
    def k(self):
        """True contraction length kh*kw*c_in."""
        return self.ksize[0] * self.ksize[1] * self.c_in

    @property
    def shape(self):
        return (*self.ksize, self.c_in, self.packed.shape[-1])

    @property
    def master_shape(self):
        """True (kh, kw, C, N) master shape. The packed words cover
        kh*kw*ceil(C/32)*32 >= kh*kw*C positions (per-tap channel padding);
        dense-baseline accounting must use the true C recorded here."""
        return self.shape

    @property
    def ndim(self):
        return 4


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class PackedConv:
    """Bitpacked *binary-weight* 2-D convolution leaf with real-valued
    activations: the (kh, kw, C, N) kernel is binarized and bitpacked along
    the flattened kh*kw*C contraction axis (flat FC word layout,
    ceil(kh*kw*C/32) words per output channel), and at apply time the words
    unpack back to ±1 [* alpha] and run through the ordinary dense conv —
    ``binarized_dense`` numerics at 1-bit weight storage. This is what makes
    K-replica stochastic ensembles (``repro.stoch``) affordable for conv
    nets: K packed conv replicas cost ~K/16 of one bf16 kernel."""

    packed: jax.Array               # (ceil(kh*kw*c_in/32), N) int32
    scale: jax.Array | None         # (N,) f32 or None
    ksize: tuple[int, int]          # static (kh, kw)
    c_in: int                       # static input channels

    def tree_flatten(self):
        return (self.packed, self.scale), (self.ksize, self.c_in)

    @classmethod
    def tree_unflatten(cls, aux, children):
        packed, scale = children
        return cls(packed, scale, aux[0], aux[1])

    @property
    def k(self):
        """True contraction length kh*kw*c_in."""
        return self.ksize[0] * self.ksize[1] * self.c_in

    @property
    def shape(self):
        return (*self.ksize, self.c_in, self.packed.shape[-1])

    @property
    def master_shape(self):
        """True (kh, kw, C, N) master shape; the flat packed layout may pad
        the last word (ceil), dense-baseline accounting uses the true K."""
        return self.shape

    @property
    def ndim(self):
        return 4


def apply_linear(w, x: jax.Array, bias: jax.Array | None = None, *,
                 sh=None, kind: str | None = None) -> jax.Array:
    """x @ w (+ bias). The leaf type of ``w`` selects its backend through
    the ``repro.engine`` registry (dense array, PackedLinear, XnorLinear, or
    any user-registered serving leaf) — no isinstance chain here.

    ``sh``/``kind`` thread the activation-sharding context
    (``repro.distributed.sharding.ShardCtx``) through the dispatch seam:
    the constraint lands on the backend's *output* regardless of which
    datapath served the layer, so packed / xnor leaves inherit exactly the
    TP layout the dense path would produce. No-op when ``sh`` is None (or
    built with ``mesh=None``)."""
    from repro.engine import registry

    out = registry.apply_linear(w, x)
    if bias is not None:
        out = out + bias.astype(out.dtype)
    if sh is not None and kind is not None:
        out = sh.act(out, kind)
    return out


def apply_conv2d(w, x: jax.Array, bias: jax.Array | None = None, *,
                 stride=(1, 1), padding="SAME", sh=None,
                 kind: str | None = None) -> jax.Array:
    """conv2d(x, w) (+ bias) in NHWC/HWIO. The leaf type of ``w`` selects
    its backend through the ``repro.engine`` registry (dense / binarized-
    dense kernels, XnorConv, or any user-registered serving leaf).
    ``sh``/``kind`` constrain the output like :func:`apply_linear`."""
    from repro.engine import registry

    out = registry.apply_conv2d(w, x, stride=stride, padding=padding)
    if bias is not None:
        out = out + bias.astype(out.dtype)
    if sh is not None and kind is not None:
        out = sh.act(out, kind)
    return out


# ---------------------------------------------------------------------------
# initializers
# ---------------------------------------------------------------------------

def he_normal(key, shape, dtype=jnp.float32, fan_in=None):
    """He initialization (the paper's choice for FC/VGG nets)."""
    fan_in = fan_in if fan_in is not None else shape[0]
    std = (2.0 / max(fan_in, 1)) ** 0.5
    return std * jax.random.normal(key, shape, dtype)


def lm_init(key, shape, dtype=jnp.float32, fan_in=None):
    """Scaled-normal init for transformer projections."""
    fan_in = fan_in if fan_in is not None else shape[-2] if len(shape) >= 2 else shape[0]
    std = fan_in ** -0.5
    return std * jax.random.normal(key, shape, dtype)


def embed_init(key, shape, dtype=jnp.float32):
    return jax.random.normal(key, shape, dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * (1.0 + scale.astype(jnp.float32))).astype(dt)


def layer_norm(x, scale, bias, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    x = (x - mu) * jax.lax.rsqrt(var + eps)
    return (x * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


def batch_norm(x, scale, bias, mean, var, *, training: bool,
               momentum: float = 0.9, eps: float = 1e-5, axes=(0,)):
    """BatchNorm with running stats (the paper normalizes every layer output).

    Returns (y, new_mean, new_var); in eval mode the stats pass through."""
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    if training:
        mu = jnp.mean(x32, axis=axes)
        va = jnp.var(x32, axis=axes)
        new_mean = momentum * mean + (1.0 - momentum) * mu
        new_var = momentum * var + (1.0 - momentum) * va
    else:
        mu, va = mean, var
        new_mean, new_var = mean, var
    shape = [1] * x.ndim
    shape[-1] = x.shape[-1]
    y = (x32 - mu.reshape(shape)) * jax.lax.rsqrt(va.reshape(shape) + eps)
    y = y * scale.reshape(shape) + bias.reshape(shape)
    return y.astype(dt), new_mean, new_var


# ---------------------------------------------------------------------------
# rotary embeddings
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, S, H, hd); positions: (B, S) or (S,)."""
    hd = x.shape[-1]
    freqs = rope_frequencies(hd, theta)                      # (hd/2,)
    if positions.ndim == 1:
        positions = positions[None, :]
    angles = positions[..., None].astype(jnp.float32) * freqs  # (B, S, hd/2)
    cos = jnp.cos(angles)[..., None, :]                      # (B, S, 1, hd/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# embedding
# ---------------------------------------------------------------------------

def embed_lookup(embedding: jax.Array, tokens: jax.Array, dtype) -> jax.Array:
    return jnp.take(embedding, tokens, axis=0).astype(dtype)
