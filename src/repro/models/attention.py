"""Grouped-query attention with RoPE, sliding windows, and KV caching.

Covers the assigned families: GQA (all LM archs), MHA (musicgen kv==heads),
sliding-window (h2o-danube-3), QKV bias (qwen2.5), plus the decode path used
by ``serve_step`` (single new token against a cached context; under a
serving ``ShardCtx`` the cache is sharded batch-over-data only — sequence
replicated over "model" — so the per-step cache write, softmax and PV
reduction all run device-local, and the block's cross-device traffic is one
all-gather after the col-parallel qkv matmul plus one all-reduce for the
row-parallel output projection).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models.layers import apply_linear, apply_rope

NEG_INF = -1e30


class AttnParams(NamedTuple):
    w_qkv: jax.Array                 # (D, (H + 2*KV) * hd)
    w_o: jax.Array                   # (H * hd, D)
    b_qkv: Optional[jax.Array] = None


def init_attn(key, cfg, init_fn) -> dict:
    k1, k2 = jax.random.split(key)
    p = {
        "w_qkv": init_fn(k1, (cfg.d_model, cfg.q_dim + 2 * cfg.kv_dim)),
        "w_o": init_fn(k2, (cfg.q_dim, cfg.d_model)),
    }
    if cfg.qkv_bias:
        p["b_qkv"] = jnp.zeros((cfg.q_dim + 2 * cfg.kv_dim,), jnp.float32)
    return p


def _split_qkv(cfg, qkv):
    q, k, v = jnp.split(qkv, [cfg.q_dim, cfg.q_dim + cfg.kv_dim], axis=-1)
    b, s = q.shape[:2]
    q = q.reshape(b, s, cfg.n_heads, cfg.head_dim)
    k = k.reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
    v = v.reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
    return q, k, v


def _repeat_kv(x: jax.Array, groups: int) -> jax.Array:
    if groups == 1:
        return x
    b, s, kv, hd = x.shape
    return jnp.repeat(x, groups, axis=2)


def causal_mask(s_q: int, s_k: int, window: Optional[int], q_offset: int = 0):
    """(s_q, s_k) boolean mask; True = attend. Supports sliding window."""
    qi = jnp.arange(s_q)[:, None] + q_offset
    ki = jnp.arange(s_k)[None, :]
    m = ki <= qi
    if window is not None:
        m &= (qi - ki) < window
    return m


# Sequences at or above this length use the chunked online-softmax (flash)
# path, which keeps attention memory O(S * chunk) instead of O(S^2).
FLASH_THRESHOLD = 4096
FLASH_CHUNK = 1024


def flash_attention(q, k, v, *, window: Optional[int] = None,
                    chunk_q: int = FLASH_CHUNK, chunk_k: int = FLASH_CHUNK):
    """Causal chunked attention with online softmax (pure jnp).

    q/k/v: (B, S, H, hd), k/v already GQA-expanded. Memory per step is one
    (B, H, cq, ck) block; masked blocks are computed-and-discarded (the
    waste is < 1% of a full model's FLOPs at 32k — see DESIGN/§Perf)."""
    bsz, s, h, hd = q.shape
    nq, nk = s // chunk_q, s // chunk_k
    scale = hd ** -0.5
    qc = jnp.moveaxis(q.reshape(bsz, nq, chunk_q, h, hd), 1, 0)
    kc = jnp.moveaxis(k.reshape(bsz, nk, chunk_k, h, hd), 1, 0)
    vc = jnp.moveaxis(v.reshape(bsz, nk, chunk_k, h, hd), 1, 0)
    qi = jnp.arange(chunk_q)
    kj = jnp.arange(chunk_k)

    def q_block(_, iq):
        i, qb = iq                                  # qb: (B, cq, H, hd)

        def k_block(carry, jk):
            m, l, acc = carry
            j, kb, vb = jk
            logits = jnp.einsum("bqhd,bkhd->bhqk", qb, kb)
            logits = logits.astype(jnp.float32) * scale
            qpos = i * chunk_q + qi[:, None]
            kpos = j * chunk_k + kj[None, :]
            msk = kpos <= qpos
            if window is not None:
                msk &= (qpos - kpos) < window
            logits = jnp.where(msk[None, None], logits, NEG_INF)
            m_new = jnp.maximum(m, logits.max(-1))
            alpha = jnp.exp(m - m_new)
            p = jnp.exp(logits - m_new[..., None])
            l_new = l * alpha + p.sum(-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bhqk,bkhd->bhqd", p.astype(qb.dtype), vb).astype(jnp.float32)
            return (m_new, l_new, acc_new), ()

        m0 = jnp.full((bsz, h, chunk_q), NEG_INF, jnp.float32)
        l0 = jnp.zeros((bsz, h, chunk_q), jnp.float32)
        a0 = jnp.zeros((bsz, h, chunk_q, hd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            k_block, (m0, l0, a0), (jnp.arange(nk), kc, vc))
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return (), jnp.moveaxis(out, 1, 2).astype(qb.dtype)  # (B, cq, H, hd)

    _, ob = jax.lax.scan(q_block, (), (jnp.arange(nq), qc))
    return jnp.moveaxis(ob, 0, 1).reshape(bsz, s, h, hd)


def _sdpa(cfg, q, k, v, s: int):
    """Dispatch: dense attention below FLASH_THRESHOLD, flash above."""
    if s >= FLASH_THRESHOLD and s % FLASH_CHUNK == 0:
        return flash_attention(q, k, v, window=cfg.sliding_window)
    scale = cfg.head_dim ** -0.5
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * scale
    mask = causal_mask(s, s, cfg.sliding_window)
    logits = jnp.where(mask[None, None], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, v)


def attention(cfg, params: dict, x: jax.Array, positions: jax.Array,
              sh=None) -> jax.Array:
    """Full (training / prefill) self-attention. x: (B, S, D)."""
    qkv = apply_linear(params["w_qkv"], x, params.get("b_qkv"),
                       sh=sh, kind="btq")
    q, k, v = _split_qkv(cfg, qkv)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    groups = cfg.n_heads // cfg.n_kv_heads
    k = _repeat_kv(k, groups)
    v = _repeat_kv(v, groups)
    if sh is not None:  # heads over "model" (padded when H % axis != 0)
        q, k, v = (sh.act(t, "bthd") for t in (q, k, v))

    out = _sdpa(cfg, q, k, v, x.shape[1])
    out = out.reshape(x.shape[0], x.shape[1], cfg.q_dim)
    if sh is not None:
        out = sh.act(out, "btq")
    return apply_linear(params["w_o"], out, sh=sh, kind="btd")


def attention_with_cache_write(cfg, params, x, positions, sh=None):
    """Prefill: same as :func:`attention` but also returns (k, v) to cache.

    Returned k/v are pre-GQA-expansion (B, S, KV, hd), post-RoPE."""
    qkv = apply_linear(params["w_qkv"], x, params.get("b_qkv"),
                       sh=sh, kind="btq")
    q, k, v = _split_qkv(cfg, qkv)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    groups = cfg.n_heads // cfg.n_kv_heads
    ke = _repeat_kv(k, groups)
    ve = _repeat_kv(v, groups)
    out = _sdpa(cfg, q, ke, ve, x.shape[1])
    out = out.reshape(x.shape[0], x.shape[1], cfg.q_dim)
    return apply_linear(params["w_o"], out, sh=sh, kind="btd"), k, v


def decode_attention(cfg, params, x, k_cache, v_cache, pos, sh=None):
    """One-token decode. x: (B, 1, D); caches: (B, S_cache, KV, hd);
    pos: (B,) int32 current write position (tokens seen so far).

    For sliding-window archs the cache length is the window and writes wrap
    (ring buffer); masking is by *token age*, which is wrap-invariant.
    Returns (out, k_cache, v_cache)."""
    b, _, _ = x.shape
    s_cache = k_cache.shape[1]
    # "qkv": under a decode ShardCtx this is the block's ONE gather — the
    # col-parallel qkv matmul's output replicates here, so the split /
    # RoPE / cache write / softmax / PV einsum below are all device-local
    qkv = apply_linear(params["w_qkv"], x, params.get("b_qkv"),
                       sh=sh, kind="qkv")
    q, k, v = _split_qkv(cfg, qkv)
    q = apply_rope(q, pos[:, None], cfg.rope_theta)
    k = apply_rope(k, pos[:, None], cfg.rope_theta)

    write_idx = pos % s_cache if cfg.sliding_window else jnp.minimum(pos, s_cache - 1)
    # One-hot select instead of a batched scatter: GSPMD cannot partition a
    # scatter whose index vector spans a sharded batch dim (it replicated
    # the updates with a collective-permute + all-gather pair per cache,
    # per layer, per step), while this jnp.where is elementwise — fully
    # local under the slot-sharded serving cache layout. Selection is
    # bit-exact (no arithmetic on cache values).
    write_hot = (jnp.arange(s_cache)[None, :] == write_idx[:, None]
                 )[:, :, None, None]                       # (B, S, 1, 1)
    k_cache = jnp.where(write_hot, k[:, :1].astype(k_cache.dtype), k_cache)
    v_cache = jnp.where(write_hot, v[:, :1].astype(v_cache.dtype), v_cache)

    # Grouped attention WITHOUT materializing the GQA-expanded cache
    # (a repeat would cost groups x the cache bytes — §Perf iteration 2):
    # q: (B, KV, G, hd) against cache (B, S, KV, hd).
    groups = cfg.n_heads // cfg.n_kv_heads
    qg = q[:, 0].reshape(b, cfg.n_kv_heads, groups, cfg.head_dim)
    scale = cfg.head_dim ** -0.5
    logits = jnp.einsum("bngd,bsnd->bngs", qg,
                        k_cache.astype(x.dtype)).astype(jnp.float32) * scale

    slots = jnp.arange(s_cache)[None, :]                       # (1, S)
    if cfg.sliding_window:
        # slot holds token (pos - age); valid if age < min(window, pos+1)
        age = (write_idx[:, None] - slots) % s_cache
        valid = age < jnp.minimum(jnp.int32(cfg.sliding_window), pos[:, None] + 1)
    else:
        valid = slots <= pos[:, None]
    logits = jnp.where(valid[:, None, None, :], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(x.dtype)
    out = jnp.einsum("bngs,bsnd->bngd", probs, v_cache.astype(x.dtype))
    out = out.reshape(b, 1, cfg.q_dim)
    return apply_linear(params["w_o"], out, sh=sh, kind="btd"), k_cache, v_cache


def chunk_attention(cfg, params, x, k_cache, v_cache, slot, offset, sh=None):
    """Chunked-prefill attention: C prompt tokens of ONE slot against the
    slot-addressed cache. x: (1, C, D); caches: (n_slots, S_cache, KV, hd);
    slot / offset are traced int32 scalars, ``offset`` = tokens already
    prefilled into the slot.

    The chunk's queries attend over [pre-write cache rows ++ the chunk's
    own K/V] with one softmax, so a partially-prefilled slot sees exactly
    the tokens a whole-prompt prefill would: cache lanes are masked to the
    real pre-offset tokens (by token age for ring caches), chunk lanes are
    causal within the chunk (+ window). The chunk's K/V are written to the
    slot's ring/linear positions only AFTER attention — writing first
    would evict ring tokens still inside earlier in-chunk queries'
    windows. Ring caches therefore require C <= S_cache (the engine clamps
    the chunk size). Returns (out, k_cache, v_cache)."""
    _, c, _ = x.shape
    s_cache = k_cache.shape[1]
    qkv = apply_linear(params["w_qkv"], x, params.get("b_qkv"),
                       sh=sh, kind="qkv")
    q, k, v = _split_qkv(cfg, qkv)                       # (1, C, H/KV, hd)
    positions = offset + jnp.arange(c, dtype=jnp.int32)  # absolute positions
    q = apply_rope(q, positions[None, :], cfg.rope_theta)
    k = apply_rope(k, positions[None, :], cfg.rope_theta)

    # the slot's pre-write cache rows (1, S_cache, KV, hd)
    k_ctx = jax.lax.dynamic_slice_in_dim(k_cache, slot, 1, axis=0)
    v_ctx = jax.lax.dynamic_slice_in_dim(v_cache, slot, 1, axis=0)

    # Grouped attention without GQA-expanding the cache (same trick as
    # decode_attention): q -> (1, C, KV, G, hd) against (1, S+C, KV, hd).
    groups = cfg.n_heads // cfg.n_kv_heads
    qg = q.reshape(1, c, cfg.n_kv_heads, groups, cfg.head_dim)
    scale = cfg.head_dim ** -0.5
    k_all = jnp.concatenate([k_ctx.astype(x.dtype), k.astype(x.dtype)], axis=1)
    v_all = jnp.concatenate([v_ctx.astype(x.dtype), v.astype(x.dtype)], axis=1)
    logits = jnp.einsum("bcngd,bsnd->bngcs", qg,
                        k_all).astype(jnp.float32) * scale

    qi = jnp.arange(c, dtype=jnp.int32)
    si = jnp.arange(s_cache, dtype=jnp.int32)
    p_q = offset + qi                                    # (C,)
    if cfg.sliding_window:
        # ring slot s holds token t_s = (offset-1) - ((offset-1-s) % S);
        # negative t_s means the slot was never written for this prefix
        t_s = (offset - 1) - ((offset - 1 - si) % s_cache)
        ctx_valid = ((t_s[None, :] >= 0)
                     & (p_q[:, None] - t_s[None, :] < cfg.sliding_window))
    else:
        ctx_valid = jnp.broadcast_to(si[None, :] < offset, (c, s_cache))
    chunk_valid = qi[None, :] <= qi[:, None]
    if cfg.sliding_window:
        chunk_valid &= (qi[:, None] - qi[None, :]) < cfg.sliding_window
    valid = jnp.concatenate([ctx_valid, chunk_valid], axis=1)  # (C, S+C)
    logits = jnp.where(valid[None, None, None], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(x.dtype)
    out = jnp.einsum("bngcs,bsnd->bcngd", probs, v_all)
    out = out.reshape(1, c, cfg.q_dim)

    # post-attention write of the chunk's K/V into the slot's rows
    kc = k.astype(k_cache.dtype)
    vc = v.astype(v_cache.dtype)
    if cfg.sliding_window:
        # ring: chunk token j lands at slot (offset + j) % S_cache; with
        # C <= S_cache every chunk token gets a distinct slot, and slots
        # not addressed by the chunk keep their previous occupant
        i_for_s = (si - offset) % s_cache
        sel = (i_for_s < c)[None, :, None, None]
        gather = jnp.minimum(i_for_s, c - 1)
        k_row = jnp.where(sel, jnp.take(kc, gather, axis=1), k_ctx)
        v_row = jnp.where(sel, jnp.take(vc, gather, axis=1), v_ctx)
    else:
        k_row = jax.lax.dynamic_update_slice(k_ctx, kc, (0, offset, 0, 0))
        v_row = jax.lax.dynamic_update_slice(v_ctx, vc, (0, offset, 0, 0))
    k_cache = jax.lax.dynamic_update_slice_in_dim(k_cache, k_row, slot, axis=0)
    v_cache = jax.lax.dynamic_update_slice_in_dim(v_cache, v_row, slot, axis=0)
    return apply_linear(params["w_o"], out, sh=sh, kind="btd"), k_cache, v_cache


def cache_length(cfg, seq_len: int) -> int:
    """Static KV-cache length for an arch at a given context length."""
    if cfg.sliding_window:
        return min(cfg.sliding_window, seq_len)
    return seq_len
