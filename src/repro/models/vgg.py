"""VGG-16 for CIFAR-10 (the paper's CNN benchmark, §III-A).

Standard VGG-16 configuration (Simonyan & Zisserman) adapted to 32x32
CIFAR inputs: 13 conv layers in 5 blocks with 2x2 maxpool after each block,
batch-norm after every layer (the paper normalizes every layer output), and
a compact FC head (512 -> 512 -> 10), as is conventional for CIFAR-scale
VGG. Convolutions route through ``apply_conv2d`` (NHWC/HWIO), so a conv
leaf may be a dense kernel (``lax.conv_general_dilated``, binarized by
Alg. 1 upstream during training) or an :class:`~repro.models.layers.XnorConv`
node (serving: binary weights *and* activations, XNOR-popcount im2col conv
via ``repro.xnor.conv``). Two boundaries keep the raw-pixel side
real-valued, per the BNN convention the paper follows: *weight*
binarization (Alg. 1, training and packing) excludes the first conv and
the final classifier (launch.train.make_paper_policy), and *activation*
binarization (XNOR serving) additionally keeps all of conv block 1 off the
binary-activation path (``core.policy.XNOR_POLICY``) — conv/1 then serves
densely-stored binarized weights on real-valued activations.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.core.binarize import binarize
from repro.models.layers import (apply_conv2d, apply_linear, batch_norm,
                                 he_normal)

# VGG-16: numbers are output channels, "M" is maxpool.
VGG16_CFG = (64, 64, "M", 128, 128, "M", 256, 256, 256, "M",
             512, 512, 512, "M", 512, 512, 512, "M")
N_CLASSES = 10


def init(key, width_mult: float = 1.0, in_channels: int = 3,
         n_classes: int = N_CLASSES, fc_dim: int = 512) -> dict:
    params: dict[str, Any] = {"conv": [], "fc": []}
    state: dict[str, Any] = {"conv": [], "fc": []}
    keys = iter(jax.random.split(key, 32))
    c_in = in_channels
    for v in VGG16_CFG:
        if v == "M":
            continue
        c_out = max(8, int(v * width_mult))
        fan_in = 3 * 3 * c_in
        params["conv"].append({
            "kernel": he_normal(next(keys), (3, 3, c_in, c_out), fan_in=fan_in),
            "bias": jnp.zeros((c_out,)),
            "bn_scale": jnp.ones((c_out,)),
            "bn_bias": jnp.zeros((c_out,)),
        })
        state["conv"].append({"mean": jnp.zeros((c_out,)), "var": jnp.ones((c_out,))})
        c_in = c_out
    fc_d = max(8, int(fc_dim * width_mult))
    dims = (c_in, fc_d, fc_d, n_classes)  # 1x1 spatial after 5 pools on 32x32
    for a, b in zip(dims[:-1], dims[1:]):
        params["fc"].append({
            "kernel": he_normal(next(keys), (a, b)),
            "bias": jnp.zeros((b,)),
            "bn_scale": jnp.ones((b,)),
            "bn_bias": jnp.zeros((b,)),
        })
        state["fc"].append({"mean": jnp.zeros((b,)), "var": jnp.ones((b,))})
    return {"params": params, "state": state}


def _maxpool2x2(x: jax.Array) -> jax.Array:
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID")


def _conv(x: jax.Array, kernel, bias: jax.Array) -> jax.Array:
    return apply_conv2d(kernel, x, bias, stride=(1, 1), padding="SAME")


def apply(params: dict, state: dict, x: jax.Array, *, training: bool,
          binary_act: bool = False):
    """x: (B, 32, 32, 3) NHWC -> (logits (B, 10), new_state).

    With ``binary_act=True`` the non-linearity is the Eq.-(1) sign
    (straight-through gradient) instead of ReLU exactly on the activations
    that *feed* binary-path layers: conv outputs 1..11 (the inputs of
    ``XnorConv`` blocks 2-5) and the head's hidden layers (``XnorLinear``).
    Both real-valued boundaries keep ReLU — conv/0 -> conv/1 (block 1 stays
    off the binary-activation path) and conv/12 -> fc/0 (the head input
    consumes real-valued conv features) — matching
    ``core.policy.XNOR_POLICY``."""
    new_state: dict[str, Any] = {"conv": [], "fc": []}
    ci, n_conv = 0, len(params["conv"])
    for v in VGG16_CFG:
        if v == "M":
            x = _maxpool2x2(x)
            continue
        lp, ls = params["conv"][ci], state["conv"][ci]
        x = _conv(x, lp["kernel"], lp["bias"])
        x, m, va = batch_norm(x, lp["bn_scale"], lp["bn_bias"],
                              ls["mean"], ls["var"], training=training,
                              axes=(0, 1, 2))
        new_state["conv"].append({"mean": m, "var": va})
        sign_act = binary_act and 1 <= ci < n_conv - 1
        x = binarize(x, "det") if sign_act else jax.nn.relu(x)
        ci += 1
    x = x.reshape(x.shape[0], -1)
    n = len(params["fc"])
    for i, (lp, ls) in enumerate(zip(params["fc"], state["fc"])):
        x = apply_linear(lp["kernel"], x, lp["bias"])
        x, m, va = batch_norm(x, lp["bn_scale"], lp["bn_bias"],
                              ls["mean"], ls["var"], training=training)
        new_state["fc"].append({"mean": m, "var": va})
        if i < n - 1:
            x = binarize(x, "det") if binary_act else jax.nn.relu(x)
    return x, new_state
