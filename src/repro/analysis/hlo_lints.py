"""Compiled-graph lints over the engine's jitted serving programs.

Plan lints (:mod:`repro.analysis.plan_lints`) check what the manifest
*says*; these check what XLA actually *compiled* — the optimized,
SPMD-partitioned HLO of ``decode_step`` / ``prefill_into`` as lowered by
:func:`repro.obs.collectives.lower_serving_hlo`:

``hlo.f32_upcast``
    Large low-precision -> f32 ``convert`` ops inside the datapath
    (trip-count weighted, byte-thresholded): a bf16/f16 weight or
    activation tensor silently widened to f32 — the binary datapath's
    whole advantage is *not* paying f32 bandwidth. Small converts
    (scales, counters) are below the threshold by construction.

``hlo.cache_not_donated``
    The decode program declares no ``input_output_alias`` — the KV cache
    is copied instead of donated, doubling decode HBM traffic. The
    engine's ``_decode`` jits with ``donate_argnums=(1,)``; this catches
    the aliasing being lost (a dtype/placement mismatch silently disables
    donation).

``hlo.host_transfer``
    Host traffic ops (infeed / outfeed / send / recv) reachable from the
    entry, trip-weighted: a host round-trip inside the decode loop
    serializes every step on PCIe latency.

``hlo.collective_budget``
    Per-kind collective counts exceed a committed budget (e.g. the
    ``benchmarks/golden_plans/collectives.json`` golden). The finding
    carries the per-op blame table from
    :func:`repro.obs.collectives.attribute_collectives`, so the *new*
    collective is named by jaxpr path — per-boundary blame, not one
    global diff.
"""
from __future__ import annotations

from typing import Any, List, Mapping, Optional

from repro.analysis.findings import ERROR, WARNING, Finding
from repro.core import hlo_analysis as H
from repro.obs.collectives import attribute_collectives, audit_hlo

#: Ignore converts below this many operand bytes (trip-weighted): scale
#: vectors, loop counters, and index math legitimately widen.
F32_UPCAST_MIN_BYTES = 65536

#: Low-precision source dtypes whose widening to f32 the lint flags.
_NARROW = ("bf16", "f16")

_HOST_OPS = ("infeed", "outfeed", "send", "recv", "send-done", "recv-done")


def _operand_dtype(op: H.HloOp, comp: H.HloComputation) -> str:
    if op.operands:
        src = comp.ops.get(op.operands[0])
        if src is not None:
            dtype, _ = H._shape_dims(src.shape)
            return dtype
    return ""


def lint_f32_upcast(text: str, entry: str = "program", *,
                    min_bytes: int = F32_UPCAST_MIN_BYTES) -> List[Finding]:
    """hlo.f32_upcast — large narrow-float -> f32 converts."""
    comps = H.parse_hlo(text)
    offenders: List[dict] = []
    total = 0.0
    for visit in H.iter_ops(text):
        op = visit.op
        if op.opcode != "convert":
            continue
        dtype, _ = H._shape_dims(op.shape)
        if dtype != "f32":
            continue
        src_dtype = _operand_dtype(op, comps[visit.computation])
        if src_dtype not in _NARROW:
            continue
        b = visit.mult * H.shape_bytes(op.shape)
        if b < min_bytes:
            continue
        total += b
        offenders.append({"op": op.name, "from": src_dtype,
                          "op_name": H.op_metadata_name(op),
                          "bytes_per_step": b})
    if not offenders:
        return []
    offenders.sort(key=lambda r: -r["bytes_per_step"])
    top = offenders[0]
    return [Finding(
        rule="hlo.f32_upcast", severity=WARNING, where=entry,
        message=(f"{len(offenders)} {'/'.join(_NARROW)}->f32 convert(s) "
                 f"of >= {min_bytes} bytes inside {entry} "
                 f"({total:,.0f} bytes/step; largest: {top['op']} "
                 f"{top['bytes_per_step']:,.0f}B at "
                 f"{top['op_name'] or '<no metadata>'})"),
        hint=("keep the binary datapath in its storage dtype; if the "
              "widening is a deliberate accumulation, waive this rule or "
              "raise min_bytes"),
        data={"offenders": offenders[:8], "total_bytes_per_step": total})]


def lint_cache_donation(text: str, entry: str = "decode_step"
                        ) -> List[Finding]:
    """hlo.cache_not_donated — decode program without input/output
    aliasing (the KV cache is copied every step)."""
    aliases = H.input_output_aliases(text)
    if aliases:
        return []
    return [Finding(
        rule="hlo.cache_not_donated", severity=ERROR, where=entry,
        message=(f"{entry} compiled with no input_output_alias — the KV "
                 f"cache is copied, not donated, doubling decode HBM "
                 f"traffic"),
        hint=("jit with donate_argnums covering the cache and keep the "
              "passed-in state's dtype/sharding identical to the output "
              "(a mismatch silently disables donation)"),
        data={})]


def lint_host_transfer(text: str, entry: str = "program") -> List[Finding]:
    """hlo.host_transfer — host traffic ops reachable from the entry."""
    hits: List[dict] = []
    for visit in H.iter_ops(text):
        if visit.op.opcode in _HOST_OPS:
            hits.append({"op": visit.op.name, "opcode": visit.op.opcode,
                         "trips": visit.mult,
                         "op_name": H.op_metadata_name(visit.op)})
    if not hits:
        return []
    return [Finding(
        rule="hlo.host_transfer", severity=ERROR, where=entry,
        message=(f"{len(hits)} host-transfer op(s) inside {entry} "
                 f"({', '.join(sorted({h['opcode'] for h in hits}))}) — "
                 f"every decode step would block on host round-trips"),
        hint=("keep the decode loop on device: no io_callback/debug "
              "prints/host polling inside jitted serving entries"),
        data={"ops": hits[:8]})]


def lint_collective_budget(text: str, entry: str,
                           budget: Mapping[str, int]) -> List[Finding]:
    """hlo.collective_budget — measured per-kind counts vs a committed
    budget, with per-op jaxpr-path blame for the overage."""
    audit = audit_hlo(text, entry=entry)
    over = {k: (int(audit.counts.get(k, 0)), int(budget.get(k, 0)))
            for k in set(audit.counts) | set(budget)
            if int(audit.counts.get(k, 0)) > int(budget.get(k, 0))}
    if not over:
        return []
    blame = attribute_collectives(text)
    blamed = sorted((r for r in blame if r["kind"] in over),
                    key=lambda r: -r["bytes_per_step"])
    detail = "; ".join(f"{k}: {got} > budget {want}"
                       for k, (got, want) in sorted(over.items()))
    names = [r["op_name"] or r["op"] for r in blamed[:4]]
    return [Finding(
        rule="hlo.collective_budget", severity=ERROR, where=entry,
        message=(f"{entry} exceeds its collective budget ({detail}); "
                 f"over-budget kinds come from: {', '.join(names)}"),
        hint=("review the blame table in data.blame — if the new "
              "collective is intentional, regenerate the golden "
              "(python -m benchmarks.check_collectives --write)"),
        data={"over": {k: {"measured": g, "budget": w}
                       for k, (g, w) in over.items()},
              "blame": blamed[:16]})]


def lint_hlo(text: str, entry: str = "program", *,
             budget: Optional[Mapping[str, int]] = None,
             require_donation: bool = False,
             min_upcast_bytes: int = F32_UPCAST_MIN_BYTES) -> List[Finding]:
    """All compiled-graph lints over one program's HLO text."""
    findings: List[Finding] = []
    findings += lint_f32_upcast(text, entry, min_bytes=min_upcast_bytes)
    if require_donation:
        findings += lint_cache_donation(text, entry)
    findings += lint_host_transfer(text, entry)
    if budget is not None:
        findings += lint_collective_budget(text, entry, budget)
    return findings


def lint_engine(engine: Any, *, n_slots: int, prompt_len: int,
                max_new_cap: int,
                budgets: Optional[Mapping[str, Mapping[str, int]]] = None
                ) -> List[Finding]:
    """Lower the engine's serving programs and lint them all: donation is
    required of ``decode_step`` and the fused ``decode_prefill`` (the
    engine donates its cache + logits to both); ``budgets`` maps entry
    name -> per-kind collective budget."""
    from repro.obs.collectives import lower_serving_hlo

    texts = lower_serving_hlo(engine, n_slots=n_slots,
                              prompt_len=prompt_len,
                              max_new_cap=max_new_cap)
    findings: List[Finding] = []
    for name, text in texts.items():
        findings += lint_hlo(
            text, entry=name,
            budget=(budgets or {}).get(name),
            require_donation=(name in ("decode_step", "decode_prefill")))
    return findings
