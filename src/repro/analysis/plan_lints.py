"""Static lints over :class:`repro.engine.plan.ExecutionPlan` manifests.

The FPGA pipelines this repo reproduces verify their folding/rate
invariants *before* synthesis; our analogue is linting the execution plan
before ``pack``/jit ever runs. These rules check the invariants the
sharded serving path depends on, straight off the manifest (any readable
version, v1-v3 — v1 rows simply have no sharding column to lint):

``plan.dense_fallthrough``
    A policy-selected leaf silently serving dense because no binary
    backend could take it (``K % 32 != 0``, ndim < 2). ``compile_plan``
    warns; in CI a warning scrolls away — this makes it a gate.

``plan.word_lane_split``
    A sharding-column placement that would split a packed int32 word
    lane: non-batch mesh axes on a contraction/word dim of a packed
    backend that declares no ``tp_contract_dim`` (f32 accumulation order
    would change across devices), a conv kernel's folded kh/kw/C dims
    sharded at all, or a word split that does not divide into whole
    int32 words.

``plan.unknown_axis``
    A sharding entry (or the plan's ``replica_axis``) naming a mesh axis
    the target mesh does not have — placement would silently drop it.

``plan.replica_axis_collision``
    The ensemble ``replica_axis`` reused inside a stochastic row's own
    sharding column: ``repro.stoch.place_replicas`` would put the same
    mesh axis on two tensor dims.

``plan.boundary_reshard``
    A packed/dense boundary where the upstream row's output sharding
    cannot flow into the downstream row — GSPMD materializes a reshard
    (gather or copy) there. Informational: the measured audit
    (``repro.obs.audit_engine``) is the golden-gated artifact.

All rules return :class:`repro.analysis.findings.Finding` lists; none
import jax — a manifest on disk lints without a device backend.
"""
from __future__ import annotations

from typing import (Any, Dict, Iterable, List, Optional, Sequence, Set,
                    Tuple)

from repro.analysis.findings import ERROR, INFO, Finding
from repro.engine import registry
from repro.engine.plan import ExecutionPlan, LayerAssignment

#: Packed word width (bits per int32 lane group) — the invariant the
#: word-lane lint protects. Mirrors ``repro.core.binarize`` packing.
WORD = 32

#: Mesh axes that carry batch (data) parallelism; sharding a weight dim
#: over them is FSDP-style and never implies a word-lane split concern
#: for the lint below (the packed word dim is only ever model-sharded).
BATCH_AXES = ("data", "pod")

#: Default axis vocabulary for linting mesh-independent manifests (the
#: checked-in goldens): every axis name the repo's placement rules emit.
DEFAULT_MESH_AXES = ("data", "model", "pod")


def _axes_at(sharding: Optional[list], dim: int) -> List[str]:
    """Axis names a sharding column places on ``dim`` (flattened)."""
    if sharding is None or dim >= len(sharding):
        return []
    entry = sharding[dim]
    if entry is None:
        return []
    names = entry if isinstance(entry, (list, tuple)) else [entry]
    return [a for a in names if a is not None]


def _all_axes(sharding: Optional[list]) -> Set[str]:
    out: Set[str] = set()
    for d in range(len(sharding or [])):
        out.update(_axes_at(sharding, d))
    return out


def _backend_spec(name: str) -> Optional[registry.BackendSpec]:
    try:
        return registry.get_backend(name)
    except KeyError:  # plan from a build with extra custom backends
        return None


def _is_packed(spec: Optional[registry.BackendSpec]) -> bool:
    """Whether a backend stores packed int32 word tensors (dense and
    binarized_dense keep plain arrays — no word lanes to protect)."""
    return spec is not None and spec.leaf_type is not None


def _parts(axes: Sequence[str],
           axis_sizes: Optional[Dict[str, int]]) -> Optional[int]:
    if axis_sizes is None:
        return None
    n = 1
    for a in axes:
        n *= int(axis_sizes.get(a, 1))
    return n


# ---------------------------------------------------------------------------
# rules
# ---------------------------------------------------------------------------

def lint_dense_fallthrough(plan: ExecutionPlan) -> List[Finding]:
    """plan.dense_fallthrough — policy-selected leaves serving dense."""
    out = []
    for a in plan.fallthroughs():
        out.append(Finding(
            rule="plan.dense_fallthrough", severity=ERROR, where=a.path,
            message=(f"policy-selected leaf {a.path!r} {a.shape} serves "
                     f"dense ({a.reason})"),
            hint=("pad/resize the layer to K % 32 == 0, exclude the path "
                  "from the weight policy, or force an explicit backend "
                  "via overrides={...} and waive this rule"),
            data={"shape": list(a.shape), "reason": a.reason}))
    return out


def _lint_row_lanes(a: LayerAssignment,
                    spec: registry.BackendSpec,
                    axis_sizes: Optional[Dict[str, int]]) -> List[Finding]:
    ndim = len(a.shape)
    is_conv = "conv" in spec.kinds and ndim == 4
    out: List[Finding] = []
    for dim in range(ndim - 1):          # the out dim (tp_dim) is safe
        axes = [x for x in _axes_at(a.sharding, dim)
                if x not in BATCH_AXES]
        if not axes:
            continue
        if is_conv:
            # (kh, kw, C, N): dims 0..2 all fold into the packed word dim
            out.append(Finding(
                rule="plan.word_lane_split", severity=ERROR, where=a.path,
                message=(f"conv kernel dim {dim} of {a.shape} is sharded "
                         f"over {axes} but kh*kw*C folds into packed int32 "
                         f"words — a lane group would cross devices"),
                hint=("shard conv kernels only on the out-channel dim "
                      "(the backend's tp_dim)"),
                data={"dim": dim, "axes": axes, "backend": a.backend}))
            continue
        if dim != ndim - 2:
            continue                     # stacked-leaf leading dims: fine
        k = a.shape[dim]
        if spec.tp_contract_dim is None:
            out.append(Finding(
                rule="plan.word_lane_split", severity=ERROR, where=a.path,
                message=(f"contraction dim of {a.shape} is sharded over "
                         f"{axes} but backend {a.backend!r} declares no "
                         f"tp_contract_dim — partial f32 sums would change "
                         f"accumulation order (and the word dim would "
                         f"split mid-lane)"),
                hint=("move the split to the out-channel dim, or use an "
                      "exact-accumulation backend (integer popcount "
                      "all-reduce, e.g. 'xnor') for row-parallel rows"),
                data={"dim": dim, "axes": axes, "backend": a.backend}))
            continue
        parts = _parts(axes, axis_sizes)
        words, rem = divmod(k, WORD)
        if rem or (parts and parts > 1 and words % parts):
            out.append(Finding(
                rule="plan.word_lane_split", severity=ERROR, where=a.path,
                message=(f"row-parallel split of K={k} over {axes}"
                         f"{f' x{parts}' if parts else ''} does not "
                         f"divide into whole {WORD}-bit words per device"),
                hint=(f"keep K/{WORD} divisible by the model-axis size so "
                      f"every shard holds whole int32 words"),
                data={"dim": dim, "axes": axes, "k": k, "parts": parts}))
    return out


def lint_word_lane_split(plan: ExecutionPlan,
                         axis_sizes: Optional[Dict[str, int]] = None
                         ) -> List[Finding]:
    """plan.word_lane_split — placements that break a packed word lane."""
    out: List[Finding] = []
    for a in plan.layers:
        spec = _backend_spec(a.backend)
        if not _is_packed(spec) or len(a.shape) < 2 or a.sharding is None:
            continue
        out.extend(_lint_row_lanes(a, spec, axis_sizes))
    return out


def lint_unknown_axis(plan: ExecutionPlan,
                      mesh_axes: Optional[Iterable[str]] = None
                      ) -> List[Finding]:
    """plan.unknown_axis — sharding names an axis the mesh lacks."""
    known = set(mesh_axes if mesh_axes is not None else DEFAULT_MESH_AXES)
    out: List[Finding] = []
    for a in plan.layers:
        bad = sorted(_all_axes(a.sharding) - known)
        if bad:
            out.append(Finding(
                rule="plan.unknown_axis", severity=ERROR, where=a.path,
                message=(f"sharding column {a.sharding} names mesh "
                         f"axes {bad} the mesh does not have "
                         f"(known: {sorted(known)})"),
                hint=("fix the axis name, or compile the plan against the "
                      "concrete mesh so sanitize_spec drops it explicitly"),
                data={"axes": bad, "sharding": a.sharding}))
    if plan.replica_axis is not None and plan.replica_axis not in known:
        out.append(Finding(
            rule="plan.unknown_axis", severity=ERROR, where="<replica_axis>",
            message=(f"replica_axis {plan.replica_axis!r} is not a mesh "
                     f"axis (known: {sorted(known)})"),
            hint="pick a real mesh axis or None for replicated replicas",
            data={"replica_axis": plan.replica_axis}))
    return out


def lint_replica_collision(plan: ExecutionPlan) -> List[Finding]:
    """plan.replica_axis_collision — ensemble axis reused inside a
    stochastic row's own sharding column."""
    ax = plan.replica_axis
    if ax is None:
        return []
    out = []
    for a in plan.stochastic_rows():
        if ax in _all_axes(a.sharding):
            out.append(Finding(
                rule="plan.replica_axis_collision", severity=ERROR,
                where=a.path,
                message=(f"replica_axis {ax!r} also appears in the row's "
                         f"own sharding {a.sharding} — place_replicas "
                         f"would put one mesh axis on two tensor dims"),
                hint=("shard ensemble replicas over a different axis "
                      "(e.g. 'data'), or drop the axis from the row"),
                data={"replica_axis": ax, "sharding": a.sharding}))
    return out


def lint_boundary_reshard(plan: ExecutionPlan,
                          axis_sizes: Optional[Dict[str, int]] = None
                          ) -> List[Finding]:
    """plan.boundary_reshard — packed/dense boundaries predicted to
    materialize a reshard (informational; the measured audit decides)."""
    compute = plan.compute_rows()
    out: List[Finding] = []
    for prev, cur in zip(compute, compute[1:]):
        prev_spec, cur_spec = (_backend_spec(prev.backend),
                               _backend_spec(cur.backend))
        if _is_packed(prev_spec) == _is_packed(cur_spec):
            continue
        prev_out = [x for x in _axes_at(prev.sharding, len(prev.shape) - 1)
                    if x not in BATCH_AXES]
        if not prev_out:
            continue
        if _parts(prev_out, axis_sizes) == 1:
            continue                    # axis size 1: nothing to gather
        cur_in = _axes_at(cur.sharding, len(cur.shape) - 2)
        if cur_in == prev_out:
            continue                    # matched row-parallel consumer
        out.append(Finding(
            rule="plan.boundary_reshard", severity=INFO, where=cur.path,
            message=(f"packed/dense boundary {prev.path!r} "
                     f"({prev.backend}, out sharded {prev_out}) -> "
                     f"{cur.path!r} ({cur.backend}, contraction sharded "
                     f"{cur_in or 'replicated'}): GSPMD will reshard the "
                     f"activation here"),
            hint=("expected at datapath boundaries; confirm the cost in "
                  "the measured audit (launch.serve --audit-collectives)"),
            data={"producer": prev.path, "producer_out_axes": prev_out,
                  "consumer_in_axes": cur_in}))
    return out


# ---------------------------------------------------------------------------
# entry point
# ---------------------------------------------------------------------------

def lint_plan(plan: ExecutionPlan, *,
              mesh_axes: Optional[Iterable[str]] = None,
              axis_sizes: Optional[Dict[str, int]] = None) -> List[Finding]:
    """All plan lints over one manifest. ``mesh_axes`` is the axis
    vocabulary to validate names against (default: every axis the repo's
    placement rules emit); ``axis_sizes`` resolves participant counts
    (e.g. ``dict(zip(mesh.axis_names, mesh.devices.shape))``)."""
    findings: List[Finding] = []
    findings += lint_dense_fallthrough(plan)
    findings += lint_word_lane_split(plan, axis_sizes)
    findings += lint_unknown_axis(plan, mesh_axes)
    findings += lint_replica_collision(plan)
    findings += lint_boundary_reshard(plan, axis_sizes)
    return findings


def lint_plan_file(path: str,
                   **kw: Any) -> Tuple[ExecutionPlan, List[Finding]]:
    """Load a manifest from disk and lint it."""
    plan = ExecutionPlan.load(path)
    return plan, lint_plan(plan, **kw)
