"""Retrace sentinel: fail serving when a post-warmup step recompiles.

The class of bug that silently serialized sharded decode before PR 8:
``decode_step`` returned state whose placement differed from what the
next call expected, so every step retraced into a fresh (and far slower)
program — no error, no wrong answer, just a 10x throughput cliff. The
sentinel watches the jit caches of the engine's entry points during
``stream_serve`` (``engine.jit_entries()``) and records every cache-size
growth after the warmup steps; ``decode_chunk`` is allowlisted by default
because it legitimately compiles one program per distinct chunk length.

Usage::

    sentinel = RetraceSentinel(engine)
    stream_serve(engine, batcher, sentinel=sentinel)
    assert sentinel.ok, sentinel.summary()

or ``strict=True`` to raise :class:`RetraceError` at the offending step.
"""
from __future__ import annotations

from typing import Any, Dict, List, Mapping, Optional, Sequence

from repro.analysis.findings import ERROR, Finding

#: Entries allowed to compile after warmup: ``decode_chunk`` jits one
#: program per distinct static chunk length ``d`` by design; the chunked-
#: prefill entries (``prefill_chunk`` / the fused ``decode_prefill``) jit
#: one program per distinct prefill-chunk length (a short final chunk),
#: and the prefix-cache ``splice`` / ``extract`` entries first compile at
#: the first hit / capture, which can land after warmup by design.
DEFAULT_ALLOW = ("decode_chunk", "prefill_chunk", "decode_prefill",
                 "splice", "extract")


class RetraceError(RuntimeError):
    """A post-warmup serving step recompiled a jitted entry."""


class RetraceSentinel:
    """Records jit cache misses across serving steps.

    ``entries`` maps name -> jitted callable; defaults to
    ``engine.jit_entries()``. Entries whose jit wrapper does not expose a
    cache size (foreign callables) are ignored. ``warmup_steps`` is the
    number of leading loop iterations whose compiles are expected (first
    prefill + first decode); every later growth in a non-allowlisted
    entry becomes an event (and a ``serve.retrace`` Finding), or raises
    immediately with ``strict=True``."""

    def __init__(self, engine: Any = None,
                 entries: Optional[Mapping] = None,
                 *, warmup_steps: int = 1,
                 allow: Sequence[str] = DEFAULT_ALLOW,
                 strict: bool = False) -> None:
        if entries is None:
            if engine is None:
                raise ValueError("RetraceSentinel needs an engine or an "
                                 "explicit entries mapping")
            entries = engine.jit_entries()
        self._entries = {name: fn for name, fn in dict(entries).items()
                         if hasattr(fn, "_cache_size")}
        self.warmup_steps = int(warmup_steps)
        self.allow = frozenset(allow)
        self.strict = bool(strict)
        self.steps = 0
        self.events: List[Dict] = []
        self._baseline: Optional[Dict[str, int]] = None

    def sizes(self) -> Dict[str, int]:
        """Current jit cache size per watched entry."""
        return {name: int(fn._cache_size())
                for name, fn in self._entries.items()}

    def step(self) -> None:
        """Called once per serving-loop iteration (after its decode)."""
        self.steps += 1
        sizes = self.sizes()
        if self._baseline is None or self.steps <= self.warmup_steps:
            self._baseline = sizes
            return
        for name, size in sizes.items():
            before = self._baseline.get(name, 0)
            if size <= before:
                continue
            self._baseline[name] = size
            if name in self.allow:
                continue
            event = {"step": self.steps, "entry": name,
                     "cache_before": before, "cache_after": size}
            self.events.append(event)
            if self.strict:
                raise RetraceError(
                    f"serving step {self.steps} recompiled jitted entry "
                    f"{name!r} (jit cache {before} -> {size}) after "
                    f"{self.warmup_steps} warmup step(s) — a shape, "
                    f"dtype, or placement changed mid-stream")

    @property
    def ok(self) -> bool:
        return not self.events

    def findings(self) -> List[Finding]:
        return [Finding(
            rule="serve.retrace", severity=ERROR,
            where=f"{e['entry']}@step{e['step']}",
            message=(f"post-warmup recompile of {e['entry']!r} at serving "
                     f"step {e['step']} (jit cache {e['cache_before']} -> "
                     f"{e['cache_after']})"),
            hint=("something about the call changed mid-stream — check "
                  "that decode_step returns state pinned to the "
                  "init_decode placement and that prompt/token shapes "
                  "are fixed"),
            data=dict(e)) for e in self.events]

    def summary(self) -> str:
        if self.ok:
            caches = ", ".join(f"{n}:{s}"
                               for n, s in sorted(self.sizes().items()))
            return (f"retrace sentinel: {self.steps} step(s), "
                    f"0 post-warmup recompiles ({caches})")
        where = "; ".join(f"{e['entry']}@step{e['step']}"
                          for e in self.events)
        return (f"retrace sentinel: {len(self.events)} post-warmup "
                f"recompile(s) in {self.steps} step(s): {where}")
