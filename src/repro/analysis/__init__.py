"""repro.analysis — static plan & HLO verifier.

The BNN serving datapath's invariants are fixed at plan-compile/jit
time; this package verifies them *before* (plan lints), *at* (compiled
HLO lints), and *during* (retrace sentinel) serving:

* :mod:`repro.analysis.plan_lints` — ExecutionPlan manifest rules
  (``plan.*``): dense fallthrough, word-lane splits, unknown mesh axes,
  replica-axis collisions, boundary reshards.
* :mod:`repro.analysis.hlo_lints` — compiled-graph rules (``hlo.*``)
  over the jitted ``decode_step``/``prefill_into``: f32 upcasts, cache
  donation, host transfers, per-boundary collective-budget blame.
* :mod:`repro.analysis.retrace` — the ``serve.retrace`` sentinel for
  post-warmup jit recompiles during ``stream_serve``.

Run it: ``python -m repro.analysis --all-goldens`` (the CI gate), or
``--plan manifest.json``, or ``--live det --live xnor`` for the
forced-4-device live-engine smoke. Rule catalogue: docs/ANALYSIS.md.
"""
from repro.analysis.findings import (ERROR, INFO, WARNING, Finding, errors,
                                     findings_to_json, format_findings, gate,
                                     waive)
from repro.analysis.hlo_lints import (lint_cache_donation,
                                      lint_collective_budget, lint_engine,
                                      lint_f32_upcast, lint_hlo,
                                      lint_host_transfer)
from repro.analysis.plan_lints import (DEFAULT_MESH_AXES, lint_plan,
                                       lint_plan_file)
from repro.analysis.retrace import (DEFAULT_ALLOW, RetraceError,
                                    RetraceSentinel)

__all__ = [
    "ERROR", "WARNING", "INFO", "Finding", "errors", "findings_to_json",
    "format_findings", "gate", "waive",
    "lint_plan", "lint_plan_file", "DEFAULT_MESH_AXES",
    "lint_hlo", "lint_engine", "lint_f32_upcast", "lint_cache_donation",
    "lint_host_transfer", "lint_collective_budget",
    "RetraceSentinel", "RetraceError", "DEFAULT_ALLOW",
]
