"""CLI for the static verifier: ``python -m repro.analysis``.

Modes (combinable; findings are merged, the exit code is the gate):

* ``--plan manifest.json`` — plan lints over one or more manifests.
* ``--all-goldens`` — plan lints over every checked-in golden manifest
  in ``benchmarks/golden_plans/`` (the CI gate; non-plan JSON like the
  collective audit golden is skipped).
* ``--live MODE`` (repeatable: det / xnor) — full live-engine check in
  a forced-4-device subprocess: compiles the starcoder2-3b smoke plan
  on the 2x2 ("data", "model") mesh, runs plan lints against the real
  mesh, compiled-HLO lints (donation, upcasts, host transfers) with the
  committed collective budget from ``collectives.json``, then a short
  ``stream_serve`` with mid-stream refill under the retrace sentinel.

``--json out.json`` writes the merged findings machine-readably;
``--waive RULE`` drops a rule id before gating. Exit code 0 iff no
error-severity finding survives.
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import subprocess
import sys
from typing import Dict, List, Optional

from repro.analysis.findings import (Finding, findings_to_json,
                                     format_findings, gate, waive)
from repro.analysis.plan_lints import lint_plan_file

_REPO = os.path.abspath(os.path.join(os.path.dirname(__file__),
                                     os.pardir, os.pardir, os.pardir))
_GOLDEN_DIR = os.path.join(_REPO, "benchmarks", "golden_plans")
_COLLECTIVES_GOLDEN = os.path.join(_GOLDEN_DIR, "collectives.json")

# live-smoke geometry — mirrors benchmarks/check_collectives.py, so the
# committed collective budget applies verbatim
_ARCH = "starcoder2_3b"
_MESH_SHAPE = (2, 2)
_MESH_AXES = ("data", "model")
_SLOTS = 4
_PROMPT_LEN = 8
_MAX_NEW_CAP = 8


def _parse_axis_sizes(arg: Optional[str]) -> Optional[Dict[str, int]]:
    if not arg:
        return None
    out = {}
    for item in arg.split(","):
        name, _, size = item.partition("=")
        out[name.strip()] = int(size)
    return out


def _lint_manifest(path: str, mesh_axes: Optional[List[str]],
                   axis_sizes: Optional[Dict[str, int]]) -> List[Finding]:
    _, findings = lint_plan_file(path, mesh_axes=mesh_axes,
                                 axis_sizes=axis_sizes)
    return findings


def _golden_plan_files() -> List[str]:
    files = []
    for path in sorted(glob.glob(os.path.join(_GOLDEN_DIR, "*.json"))):
        with open(path) as f:
            if "layers" in json.load(f):
                files.append(path)
    return files


# ---------------------------------------------------------------------------
# live-engine smoke (runs inside the forced-multi-device subprocess)
# ---------------------------------------------------------------------------

def _live_child(mode: str) -> List[Finding]:
    import jax
    import numpy as np

    from repro.analysis.hlo_lints import lint_engine
    from repro.analysis.retrace import RetraceSentinel
    from repro.configs import base as cb
    from repro.core.policy import DEFAULT_POLICY
    from repro.engine import compile_plan
    from repro.models import transformer as T
    from repro.serve.batcher import SlotBatcher
    from repro.serve.engine import ServeEngine, stream_serve

    mesh = jax.make_mesh(_MESH_SHAPE, _MESH_AXES)
    axis_sizes = dict(zip(_MESH_AXES, _MESH_SHAPE))
    cfg = cb.get_config(_ARCH, smoke=True)
    params = T.init_lm(cfg, jax.random.key(0))
    plan = compile_plan(params, DEFAULT_POLICY, mode, warn=False, mesh=mesh)

    findings = plan.lint(mesh_axes=mesh.axis_names, axis_sizes=axis_sizes)

    packed = plan.pack(params, key=jax.random.key(1))
    engine = ServeEngine(cfg, packed, mesh=mesh, plan=plan)

    budgets = None
    if os.path.exists(_COLLECTIVES_GOLDEN):
        with open(_COLLECTIVES_GOLDEN) as f:
            audits = json.load(f)["audits"].get(mode, {})
        budgets = {entry: a["counts"] for entry, a in audits.items()}
    findings += lint_engine(engine, n_slots=_SLOTS, prompt_len=_PROMPT_LEN,
                            max_new_cap=_MAX_NEW_CAP, budgets=budgets)

    # serving smoke: more requests than slots forces mid-stream refill;
    # staggered max_new forces slot turnover — zero post-warmup recompiles
    sentinel = RetraceSentinel(engine)
    batcher = SlotBatcher(_SLOTS, _PROMPT_LEN)
    for i in range(_SLOTS + 2):
        prompt = np.full((_PROMPT_LEN,), 1 + i, dtype=np.int32)
        batcher.submit(prompt, max_new=3 + (i % 3))
    steps = stream_serve(engine, batcher, max_new_cap=_MAX_NEW_CAP,
                         sentinel=sentinel)
    print(f"live[{mode}]: {steps} steps; {sentinel.summary()}",
          file=sys.stderr)
    findings += sentinel.findings()
    return findings


def _run_live(mode: str, timeout: int = 540) -> Optional[List[Finding]]:
    """Forced-4-device subprocess wrapper (device count is fixed at
    backend init, so the live check cannot run in-process)."""
    code = (f"from repro.analysis.__main__ import _live_child; "
            f"from repro.analysis.findings import findings_to_json; "
            f"import json; "
            f"print('FINDINGS ' + json.dumps(findings_to_json("
            f"_live_child({mode!r}))))")
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=4",
               JAX_PLATFORMS="cpu")
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(_REPO, "src"), env.get("PYTHONPATH", "")])
    try:
        proc = subprocess.run([sys.executable, "-c", code], env=env,
                              capture_output=True, text=True,
                              timeout=timeout)
    except (OSError, subprocess.TimeoutExpired):
        return None
    sys.stderr.write(proc.stderr[-2000:] if proc.returncode else
                     "".join(line + "\n"
                             for line in proc.stderr.splitlines()
                             if line.startswith("live[")))
    for line in reversed(proc.stdout.splitlines()):
        if line.startswith("FINDINGS "):
            return [Finding.from_json(d)
                    for d in json.loads(line[len("FINDINGS "):])]
    return None


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.analysis",
                                 description=__doc__)
    ap.add_argument("--plan", action="append", default=[],
                    metavar="MANIFEST", help="lint a plan manifest")
    ap.add_argument("--all-goldens", action="store_true",
                    help="lint every golden manifest in "
                         "benchmarks/golden_plans/")
    ap.add_argument("--live", action="append", default=[],
                    choices=("det", "stoch", "xnor"),
                    help="live-engine check for a mode (forced 4-device "
                         "subprocess; repeatable)")
    ap.add_argument("--mesh-axes", default=None,
                    help="comma-separated axis vocabulary for plan lints "
                         "(default: data,model,pod)")
    ap.add_argument("--axis-sizes", default=None,
                    help="axis sizes for plan lints, e.g. model=2,data=2")
    ap.add_argument("--waive", action="append", default=[], metavar="RULE",
                    help="drop a rule id before gating (repeatable)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write merged findings as JSON")
    args = ap.parse_args(argv)

    mesh_axes = args.mesh_axes.split(",") if args.mesh_axes else None
    axis_sizes = _parse_axis_sizes(args.axis_sizes)

    plans = list(args.plan)
    if args.all_goldens:
        plans += _golden_plan_files()
    if not plans and not args.live:
        ap.error("nothing to do: pass --plan, --all-goldens, or --live")

    findings: List[Finding] = []
    for path in plans:
        batch = _lint_manifest(path, mesh_axes, axis_sizes)
        findings += batch
        rel = os.path.relpath(path, _REPO)
        print(format_findings(batch, title=f"plan lints: {rel}"))
    for mode in args.live:
        batch = _run_live(mode)
        if batch is None:
            print(f"live[{mode}]: subprocess unavailable, skipping "
                  f"(no multi-device CPU mesh)", file=sys.stderr)
            continue
        findings += batch
        print(format_findings(batch, title=f"live engine: {mode}"))

    findings = waive(findings, args.waive)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(findings_to_json(findings), f, indent=1)
            f.write("\n")
    code = gate(findings)
    print(f"repro.analysis: {'FAIL' if code else 'OK'} "
          f"({len(findings)} finding(s) after waivers)")
    return code


if __name__ == "__main__":
    sys.exit(main())
