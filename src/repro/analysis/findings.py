"""Structured findings for the static verifier.

Every lint rule in ``repro.analysis`` reports through one shape: a
:class:`Finding` carrying a stable rule id (``plan.dense_fallthrough``,
``hlo.cache_not_donated``, ...), a severity, the location it blames (a
plan row path, an HLO entry name, a serve step), a human message, and a
fix hint. Findings serialize to plain JSON so the CI gate and the
``--json`` CLI flag stay machine-readable; ``gate()`` turns a batch of
findings into a process exit code (errors fail, warnings don't).

Rule ids are the waiver surface: ``--waive plan.boundary_reshard``
drops every finding with that id before gating. docs/ANALYSIS.md is the
catalogue of ids.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, Iterable, List, Optional, Sequence

ERROR = "error"
WARNING = "warning"
INFO = "info"
_SEVERITIES = (ERROR, WARNING, INFO)


@dataclass(frozen=True)
class Finding:
    """One verifier finding. ``where`` is the blamed location: a plan
    row path for plan lints, the jitted entry name for HLO lints, the
    entry + step for the retrace sentinel."""
    rule: str
    severity: str
    where: str
    message: str
    hint: str = ""
    data: Dict[str, Any] = field(default_factory=dict)

    def __post_init__(self) -> None:
        if self.severity not in _SEVERITIES:
            raise ValueError(f"severity must be one of {_SEVERITIES}, "
                             f"got {self.severity!r}")

    def to_json(self) -> Dict[str, Any]:
        return {"rule": self.rule, "severity": self.severity,
                "where": self.where, "message": self.message,
                "hint": self.hint, "data": dict(self.data)}

    @classmethod
    def from_json(cls, d: Dict[str, Any]) -> "Finding":
        return cls(rule=d["rule"], severity=d["severity"],
                   where=d["where"], message=d["message"],
                   hint=d.get("hint", ""), data=dict(d.get("data", {})))


def waive(findings: Iterable[Finding],
          rules: Optional[Sequence[str]] = None) -> List[Finding]:
    """Drop findings whose rule id is in ``rules`` (the waiver list)."""
    waived = set(rules or ())
    return [f for f in findings if f.rule not in waived]


def errors(findings: Iterable[Finding]) -> List[Finding]:
    return [f for f in findings if f.severity == ERROR]


def gate(findings: Iterable[Finding]) -> int:
    """Exit code for a batch of findings: 1 if any error survives."""
    return 1 if errors(findings) else 0


def findings_to_json(findings: Iterable[Finding]) -> List[Dict[str, Any]]:
    return [f.to_json() for f in findings]


_SEV_ORDER = {ERROR: 0, WARNING: 1, INFO: 2}


def format_findings(findings: Sequence[Finding],
                    title: str = "") -> str:
    """Human-readable report: one block per finding, errors first."""
    lines: List[str] = []
    if title:
        lines.append(title)
    if not findings:
        lines.append("  no findings")
        return "\n".join(lines)
    ordered = sorted(findings,
                     key=lambda f: (_SEV_ORDER[f.severity], f.rule, f.where))
    for f in ordered:
        lines.append(f"  [{f.severity.upper():<7}] {f.rule}  @ {f.where}")
        lines.append(f"      {f.message}")
        if f.hint:
            lines.append(f"      fix: {f.hint}")
    n_err = len(errors(ordered))
    lines.append(f"  {len(ordered)} finding(s), {n_err} error(s)")
    return "\n".join(lines)
