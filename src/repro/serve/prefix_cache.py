"""Prefix KV cache: reuse prefill work across requests sharing a prompt
prefix (the multi-tenant system-prompt case).

Entries are family-agnostic batch-1 cache snapshots — one row of every
decode-cache entry along its slot axis (``models.transformer.cache_extract``)
— captured at chunk boundaries during chunked prefill and spliced back into
a live slot via ``cache_insert``. A snapshot taken after ``L`` prompt tokens
is a pure function of those tokens (and the weights/geometry), so splicing
it lets the engine skip the first ``L // chunk`` prefill chunks entirely;
a full-prompt snapshot also stores the first-token logits, making the hit
a zero-chunk prefill.

Keying: sha256 over the raw int32 prefix-token bytes, salted with a
*geometry string* (model identity + prompt_len / context geometry / chunk
size) bound on first use — a cache object reused against a different
engine or chunking self-invalidates instead of serving stale state. Chunk
size is part of the key because chunked and whole-prompt prefills agree
only to ulp order; mixing chunkings would break the bit-identical-stream
conformance invariant.

Eviction is LRU over an ``OrderedDict`` with both an entry-count and a
byte budget; evictions/hits/misses/tokens-skipped are exposed via
``stats()`` and surfaced into the serving metrics registry by
``stream_serve``.
"""
from __future__ import annotations

import collections
import dataclasses
import hashlib
from typing import Optional

import numpy as np


@dataclasses.dataclass
class PrefixEntry:
    """One cached prefix: ``length`` prompt tokens' worth of batch-1 cache
    rows (host numpy, keyed like the decode cache), plus the first-token
    logits when the snapshot covers a full prompt."""

    length: int
    cache: dict                       # name -> np.ndarray, batch-1 slot rows
    logits: Optional[np.ndarray] = None   # (1, V) only for full prompts

    @property
    def nbytes(self) -> int:
        n = sum(a.nbytes for a in self.cache.values())
        if self.logits is not None:
            n += self.logits.nbytes
        return n


class PrefixCache:
    """LRU prompt-prefix -> cache-snapshot store (host-side).

    ``max_entries`` / ``max_bytes`` bound the store (evicting least
    recently used); ``store_partial=False`` keeps only full-prompt
    snapshots (cheaper capture, no partial-prefix hits)."""

    def __init__(self, max_entries: int = 64,
                 max_bytes: Optional[int] = None,
                 store_partial: bool = True):
        self.max_entries = int(max_entries)
        self.max_bytes = max_bytes
        self.store_partial = bool(store_partial)
        self._entries: "collections.OrderedDict[str, PrefixEntry]" = \
            collections.OrderedDict()
        self._geometry: Optional[str] = None
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.tokens_skipped = 0

    # -- keying -----------------------------------------------------------

    def bind_geometry(self, geometry: str) -> None:
        """Salt the key with the serving geometry; a geometry change (new
        engine, prompt_len, context or chunk size) drops every entry —
        they describe caches of a different shape or numerics."""
        if self._geometry == geometry:
            return
        if self._geometry is not None and self._entries:
            self.evictions += len(self._entries)
            self._entries.clear()
        self._geometry = geometry

    def _key(self, tokens) -> str:
        h = hashlib.sha256((self._geometry or "").encode())
        h.update(np.ascontiguousarray(tokens, np.int32).tobytes())
        return h.hexdigest()

    # -- store ------------------------------------------------------------

    @property
    def nbytes(self) -> int:
        return sum(e.nbytes for e in self._entries.values())

    def __len__(self) -> int:
        return len(self._entries)

    def put(self, prefix, cache_rows: dict, logits=None) -> None:
        """Store a snapshot of ``len(prefix)`` prefilled tokens. Arrays are
        copied to host numpy; an existing key is refreshed in place."""
        prefix = np.asarray(prefix, np.int32)
        entry = PrefixEntry(
            length=int(prefix.shape[0]),
            cache={k: np.asarray(v) for k, v in cache_rows.items()},
            logits=None if logits is None else np.asarray(logits))
        key = self._key(prefix)
        self._entries[key] = entry
        self._entries.move_to_end(key)
        self._evict()

    def lookup(self, prompt, chunk_len: int):
        """Longest stored prefix of ``prompt`` at a chunk-aligned length
        (full prompt first). Returns ``(length, PrefixEntry)`` or None;
        counts one hit or miss per call."""
        p = np.asarray(prompt, np.int32)
        n = int(p.shape[0])
        lengths = [n] + [length for length in
                         range(n - (n % chunk_len or chunk_len), 0,
                               -chunk_len)
                         if length < n]
        for length in lengths:
            entry = self._entries.get(self._key(p[:length]))
            if entry is not None:
                self._entries.move_to_end(self._key(p[:length]))
                self.hits += 1
                self.tokens_skipped += length
                return length, entry
        self.misses += 1
        return None

    def _evict(self) -> None:
        while len(self._entries) > self.max_entries:
            self._entries.popitem(last=False)
            self.evictions += 1
        if self.max_bytes is not None:
            while len(self._entries) > 1 and self.nbytes > self.max_bytes:
                self._entries.popitem(last=False)
                self.evictions += 1

    def stats(self) -> dict:
        return {"entries": len(self._entries), "bytes": self.nbytes,
                "hits": self.hits, "misses": self.misses,
                "evictions": self.evictions,
                "tokens_skipped": self.tokens_skipped}
