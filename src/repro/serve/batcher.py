"""Request batcher: groups incoming generation requests into fixed-shape
batches (continuous batching, slot-based) so the jitted decode step never
re-specializes.

Production framing: requests arrive asynchronously; the engine keeps a fixed
number of *slots* (the compiled batch dimension). Finished slots are refilled
from the queue each step; empty slots decode padding and are masked out of
the returned streams. This is the standard continuous-batching scheme (vLLM
et al.) restricted to a static shape, which is what pjit wants.

The batcher is also the accounting ledger: every request records submit /
first-token / completion wall times (TTFT and per-request latency) and its
generated tokens, so serving throughput is derived from tokens *actually
recorded* (``tokens_generated``), never from steps-times-batch arithmetic.

Under mesh-sharded serving the slot dimension is also the *placement*
batch dim: ``ServeEngine.init_decode`` shards the decode cache's slot axes
over the "data" mesh axes, so ``n_slots`` should be a multiple of the data
axis size to shard evenly (a non-divisible count serves correctly but
replicates the cache). The batcher itself is host-side bookkeeping and
never touches device state.
"""
from __future__ import annotations

import collections
import dataclasses
import itertools
import time
from typing import Deque, Optional

import numpy as np


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray            # (S,) int32
    max_new: int
    generated: list[int] = dataclasses.field(default_factory=list)
    truncated: bool = False       # prompt was longer than the slot width
    t_submit: float = 0.0         # wall time at submit()
    t_first: Optional[float] = None   # wall time of the first recorded token
    t_done: Optional[float] = None    # wall time of the last recorded token
    # Per-token ensemble uncertainty (only filled under K-replica serving —
    # repro.stoch): replica vote agreement and mean logit variance aligned
    # with ``generated``; ``abstained`` latches once any recorded token's
    # agreement fell below the engine's abstain threshold.
    agreement: list[float] = dataclasses.field(default_factory=list)
    variance: list[float] = dataclasses.field(default_factory=list)
    abstained: bool = False

    @property
    def done(self) -> bool:
        return len(self.generated) >= self.max_new

    @property
    def ttft(self) -> Optional[float]:
        """Submit-to-first-token seconds (includes queue wait)."""
        return None if self.t_first is None else self.t_first - self.t_submit

    @property
    def latency(self) -> Optional[float]:
        """Submit-to-last-token seconds (includes queue wait)."""
        return None if self.t_done is None else self.t_done - self.t_submit


class SlotBatcher:
    def __init__(self, n_slots: int, prompt_len: int, pad_id: int = 0,
                 tracer=None):
        self.n_slots = n_slots
        self.prompt_len = prompt_len
        self.pad_id = pad_id
        self.queue: Deque[Request] = collections.deque()
        self.slots: list[Optional[Request]] = [None] * n_slots
        self._uid = itertools.count()
        self.completed: list[Request] = []
        # Slots whose prompt is still being prefilled chunk-by-chunk
        # (stream_serve's chunked-prefill mode): the request occupies the
        # slot (so it is never refilled and the stream is not idle) but it
        # is NOT active — record() skips it, so no decode garbage lands in
        # its ledger and t_first stamps on the first *generated* token,
        # never on a prefill chunk's completion.
        self.prefilling: set[int] = set()
        # Optional repro.obs.Tracer: the request lifecycle (submit ->
        # slot_refill -> request_done) lands as instant events on the same
        # timeline as the engine's spans, so queue waits are visible in the
        # trace. Disabled tracer = every call is a no-op.
        if tracer is None:
            from repro.obs.trace import NULL_TRACER as tracer
        self.tracer = tracer

    def submit(self, prompt: np.ndarray, max_new: int) -> int:
        uid = next(self._uid)
        p = np.asarray(prompt, np.int32)
        truncated = p.shape[0] > self.prompt_len
        if truncated:
            # keep the LAST prompt_len tokens: the next token conditions on
            # the suffix, so dropping the head loses far less context than
            # dropping the tail would
            p = p[-self.prompt_len:]
        elif p.shape[0] < self.prompt_len:  # left-pad to static shape
            p = np.concatenate(
                [np.full(self.prompt_len - p.shape[0], self.pad_id, np.int32), p])
        self.queue.append(Request(uid, p, max_new, truncated=truncated,
                                  t_submit=time.perf_counter()))
        self.tracer.instant("submit", uid=uid, max_new=max_new,
                            queued=len(self.queue))
        return uid

    def refill(self) -> list[int]:
        """Fills free slots from the queue; returns indices that changed."""
        changed = []
        for i, r in enumerate(self.slots):
            if r is not None and r.done:
                self.completed.append(r)
                self.slots[i] = None
                self.tracer.instant("request_done", uid=r.uid, slot=i,
                                    tokens=len(r.generated))
            if self.slots[i] is None and self.queue:
                self.slots[i] = self.queue.popleft()
                changed.append(i)
                self.tracer.instant("slot_refill", uid=self.slots[i].uid,
                                    slot=i, queued=len(self.queue))
        return changed

    def mark_prefilling(self, slot: int) -> None:
        """Flag a slot as mid-chunked-prefill: occupied but not yet
        decoding (excluded from record / active_mask / min_remaining)."""
        self.prefilling.add(slot)

    def mark_ready(self, slot: int) -> None:
        """Prefill finished: the slot joins the active decode set."""
        self.prefilling.discard(slot)

    def active_mask(self) -> np.ndarray:
        return np.array([r is not None and not r.done
                         and i not in self.prefilling
                         for i, r in enumerate(self.slots)])

    def prompts(self) -> np.ndarray:
        out = np.full((self.n_slots, self.prompt_len), self.pad_id, np.int32)
        for i, r in enumerate(self.slots):
            if r is not None:
                out[i] = r.prompt
        return out

    def record(self, tokens: np.ndarray, agreement=None, variance=None,
               abstained=None) -> None:
        """Append one emitted token per live slot; the optional per-slot
        arrays (ensemble serving) append the matching uncertainty stats."""
        now = time.perf_counter()
        for i, r in enumerate(self.slots):
            if i in self.prefilling:
                continue
            if r is not None and not r.done:
                if r.t_first is None:
                    r.t_first = now
                r.generated.append(int(tokens[i]))
                if agreement is not None:
                    r.agreement.append(float(agreement[i]))
                if variance is not None:
                    r.variance.append(float(variance[i]))
                if abstained is not None and bool(abstained[i]):
                    r.abstained = True
                if r.done:
                    r.t_done = now

    def min_remaining(self) -> Optional[int]:
        """Smallest remaining-token budget among live slots (None when no
        slot is active). The multi-step decode loop (``stream_serve``'s
        ``decode_chunk``) sizes each on-device chunk to this, so no request
        finishes strictly *inside* a chunk: completions land exactly on the
        chunk boundary, where the refill runs — slot turnover timing (and
        therefore every stream) is bit-identical to the one-token loop."""
        rem = [r.max_new - len(r.generated)
               for i, r in enumerate(self.slots)
               if r is not None and not r.done and i not in self.prefilling]
        return min(rem) if rem else None

    @property
    def tokens_generated(self) -> int:
        """Tokens actually recorded so far (completed + in-flight). The
        serving loops derive tok/s from this — counting steps * batch over-
        credits requests whose per-request ``max_new`` is below the cap and
        misses slots that finished inside the current round/step."""
        live = sum(len(r.generated) for r in self.slots if r is not None)
        return live + sum(len(r.generated) for r in self.completed)

    @property
    def idle(self) -> bool:
        return not self.queue and all(r is None or r.done for r in self.slots)
