"""Request batcher: groups incoming generation requests into fixed-shape
batches (continuous batching, slot-based) so the jitted decode step never
re-specializes.

Production framing: requests arrive asynchronously; the engine keeps a fixed
number of *slots* (the compiled batch dimension). Finished slots are refilled
from the queue each step; empty slots decode padding and are masked out of
the returned streams. This is the standard continuous-batching scheme (vLLM
et al.) restricted to a static shape, which is what pjit wants.
"""
from __future__ import annotations

import collections
import dataclasses
import itertools
from typing import Deque, Iterable, Optional

import numpy as np


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray            # (S,) int32
    max_new: int
    generated: list[int] = dataclasses.field(default_factory=list)

    @property
    def done(self) -> bool:
        return len(self.generated) >= self.max_new


class SlotBatcher:
    def __init__(self, n_slots: int, prompt_len: int, pad_id: int = 0):
        self.n_slots = n_slots
        self.prompt_len = prompt_len
        self.pad_id = pad_id
        self.queue: Deque[Request] = collections.deque()
        self.slots: list[Optional[Request]] = [None] * n_slots
        self._uid = itertools.count()
        self.completed: list[Request] = []

    def submit(self, prompt: np.ndarray, max_new: int) -> int:
        uid = next(self._uid)
        p = np.asarray(prompt, np.int32)[: self.prompt_len]
        if p.shape[0] < self.prompt_len:  # left-pad to static shape
            p = np.concatenate(
                [np.full(self.prompt_len - p.shape[0], self.pad_id, np.int32), p])
        self.queue.append(Request(uid, p, max_new))
        return uid

    def refill(self) -> list[int]:
        """Fills free slots from the queue; returns indices that changed."""
        changed = []
        for i, r in enumerate(self.slots):
            if r is not None and r.done:
                self.completed.append(r)
                self.slots[i] = None
            if self.slots[i] is None and self.queue:
                self.slots[i] = self.queue.popleft()
                changed.append(i)
        return changed

    def active_mask(self) -> np.ndarray:
        return np.array([r is not None and not r.done for r in self.slots])

    def prompts(self) -> np.ndarray:
        out = np.full((self.n_slots, self.prompt_len), self.pad_id, np.int32)
        for i, r in enumerate(self.slots):
            if r is not None:
                out[i] = r.prompt
        return out

    def record(self, tokens: np.ndarray) -> None:
        for i, r in enumerate(self.slots):
            if r is not None and not r.done:
                r.generated.append(int(tokens[i]))

    @property
    def idle(self) -> bool:
        return not self.queue and all(r is None or r.done for r in self.slots)
