"""Serving layer: step-level continuous batching over packed binary weights.

Module map:

* ``engine``  — :class:`ServeEngine` (jitted prefill / decode_step /
  prefill_into over one parameter tree; with ``mesh=``/``plan=`` it places
  params and decode state on a ("data", "model") mesh per the plan's
  sharding column), :class:`DecodeState` (the persistent slot-addressed KV
  cache + per-slot next-token logits), :func:`stream_serve` (the
  step-level serving loop), ``pack_params`` and ``packed_param_bytes``
  (weight-bytes accounting from true master shapes);
* ``batcher`` — :class:`SlotBatcher` / :class:`Request`: fixed-slot request
  queue with suffix truncation to the static prompt width, per-request
  ``max_new``, and the TTFT / latency / tokens-recorded ledger the
  throughput numbers are derived from.

**The ``stream_serve`` refill loop.** Each iteration (i) retires finished
requests and re-prefills their slots from the queue — ``batcher.refill``
retires *and* refills in one call, so a slot freed this step hosts a new
request on the next; ``ServeEngine.prefill_into`` splices the newcomer's
cache + first-token logits into the live state at a traced slot index —
then (ii) emits one token for every active slot from the state's next-token
logits, and (iii) runs one masked fixed-shape ``decode_step`` over *all*
slots. No round barrier: per-request ``max_new`` is honored exactly, a
request finishing mid-stream frees its slot for the next queued request,
and the final emission skips the trailing decode step.

The decode cache is long-lived and slot-addressed (``models.transformer.
cache_insert``): requests join and leave mid-stream while every jitted
shape stays fixed, so the decode step compiles once per (n_slots,
context_len) and never re-specializes.

**Chunked prefill + prefix reuse.** ``stream_serve(prefill_chunk=C)``
replaces the whole-prompt admission stall with the fused ``decode_prefill``
step — every iteration advances all live decode slots one token AND one
arriving prompt by one C-token chunk (a partially-prefilled slot is a
first-class cache state for every family; see ``models.transformer.
prefill_chunk``). ``prefix_cache`` (``prefix_cache.PrefixCache``) layers
prompt-prefix KV reuse on top: chunk-boundary snapshots keyed on the
prompt-prefix hash splice into a fresh slot and skip those chunks; a
full-prompt hit skips prefill entirely. Greedy streams stay bit-identical
to one-shot ``generate`` either way (tests/test_serve_conformance.py).
"""
from repro.serve.batcher import Request, SlotBatcher
from repro.serve.engine import (DecodeState, GenerationResult, ServeEngine,
                                pack_params, packed_param_bytes, stream_serve)
from repro.serve.prefix_cache import PrefixCache, PrefixEntry

__all__ = [
    "DecodeState", "GenerationResult", "PrefixCache", "PrefixEntry",
    "Request", "ServeEngine", "SlotBatcher", "pack_params",
    "packed_param_bytes", "stream_serve",
]
