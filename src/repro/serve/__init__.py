"""Serving layer: step-level continuous batching over packed binary weights.

Module map:

* ``engine``  — :class:`ServeEngine` (jitted prefill / decode_step /
  prefill_into over one parameter tree), :class:`DecodeState` (the
  persistent slot-addressed KV cache + per-slot next-token logits),
  :func:`stream_serve` (the step-level serving loop), ``pack_params`` and
  ``packed_param_bytes`` (weight-bytes accounting from true master shapes);
* ``batcher`` — :class:`SlotBatcher` / :class:`Request`: fixed-slot request
  queue with suffix truncation to the static prompt width, per-request
  ``max_new``, and the TTFT / latency / tokens-recorded ledger the
  throughput numbers are derived from.

The decode cache is long-lived and slot-addressed (``models.transformer.
cache_insert``): requests join and leave mid-stream while every jitted
shape stays fixed, so the decode step compiles once per (n_slots,
context_len) and never re-specializes.
"""
from repro.serve.batcher import Request, SlotBatcher
from repro.serve.engine import (DecodeState, GenerationResult, ServeEngine,
                                pack_params, packed_param_bytes, stream_serve)

__all__ = [
    "DecodeState", "GenerationResult", "Request", "ServeEngine",
    "SlotBatcher", "pack_params", "packed_param_bytes", "stream_serve",
]
