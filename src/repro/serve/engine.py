"""Serving engine: packed-weight inference with prefill + batched decode.

The paper's headline inference result (binarized nets cut inference time
~10x on FPGA vs the unregularized FPGA net, >25% vs GPU) maps on TPU to the
*packed-weight* serving path: projection weights are binarized once
(deterministically, Eq. 1 — the paper also evaluates inference of
stochastically-trained nets with their master-sign weights) and stored as
bitpacked int32 (+ optional per-channel scale), so decode — a weight-bytes-
bound workload — moves ~16x fewer HBM bytes.

Which datapath each layer gets is decided by the execution-plan compiler
(``repro.engine``): ``pack_params`` is a thin wrapper over
``compile_plan(...).pack(params)``, and the model code dispatches through
``apply_linear``/``apply_conv2d`` on the serving leaf types the plan
produced. Compile the plan yourself to inspect, save, or override the
per-layer assignment (``launch.serve --plan-report`` prints it).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.binarize import BinarizeMode
from repro.engine import compile_plan
from repro.models import transformer as T
from repro.models.layers import PackedLinear, XnorConv, XnorLinear


def pack_params(params, policy, mode: str | BinarizeMode = "det",
                key: Optional[jax.Array] = None, with_scale: bool = True,
                xnor_policy=None, overrides=None):
    """Binarize+bitpack every policy-selected >=2-D projection leaf.

    Equivalent to ``repro.engine.compile_plan(...).pack(params, key)`` —
    kept as the one-call convenience entry point. Stacked leaves (L, K, N)
    pack per layer via vmap; the resulting PackedLinear children keep the
    leading stack dims so ``lax.scan`` slices them exactly like dense
    leaves. MoE expert tensors (E-stacked) pack the same way. ``with_scale``
    stores the per-output-channel mean |w| (BWN alpha) so packed inference
    tracks the master weights' magnitude.

    ``mode="xnor"`` selects the fully-binary engine: weights binarize
    deterministically (Eq. 1) exactly as ``mode="det"``, but leaves *also*
    selected by ``xnor_policy`` (default ``core.policy.XNOR_POLICY``) land
    on the ``xnor`` / ``xnor_conv`` backends (activations sign-binarized +
    bitpacked on the fly, XNOR-popcount compute). Policy-selected conv
    kernels with no binary lowering serve Alg.-1 binarized values stored
    densely (the ``binarized_dense`` backend); policy-selected projections
    that cannot bitpack (K % 32 != 0, ndim < 2) serve dense — no longer
    silently: the compiled plan records the reason per layer and warns.
    See ``repro.engine`` for the backend registry and
    ``core.policy.XNOR_POLICY`` for the real-valued-input boundary."""
    plan = compile_plan(params, policy, mode, xnor_policy=xnor_policy,
                        with_scale=with_scale, overrides=overrides)
    return plan.pack(params, key=key)


def packed_param_bytes(params) -> tuple[int, int]:
    """(dense bf16 bytes, packed bytes) over policy-packed leaves."""
    dense = packed = 0
    packed_types = (PackedLinear, XnorLinear, XnorConv)
    for leaf in jax.tree_util.tree_leaves(
            params, is_leaf=lambda x: isinstance(x, packed_types)):
        if isinstance(leaf, packed_types):
            dense += leaf.k * leaf.packed.shape[-1] * 2 * max(
                1, int(jnp.prod(jnp.array(leaf.packed.shape[:-2]))))
            packed += leaf.packed.size * 4
            if leaf.scale is not None:
                packed += leaf.scale.size * 4
        else:
            dense += leaf.size * 2
            packed += leaf.size * 2
    return dense, packed


# ---------------------------------------------------------------------------
# generation
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class GenerationResult:
    tokens: jax.Array          # (B, max_new)
    logprobs: jax.Array        # (B, max_new)
    steps: int


class ServeEngine:
    """Batched prefill + greedy/temperature decode over a (possibly packed)
    parameter tree."""

    def __init__(self, cfg, params, sh=None):
        self.cfg = cfg
        self.params = params
        self.sh = sh
        self._prefill = jax.jit(
            lambda p, toks, ml: T.prefill(cfg, p, toks, sh, max_len=ml),
            static_argnums=2)
        self._decode = jax.jit(
            lambda p, cache, tok: T.decode_step(cfg, p, cache, tok, sh))

    def generate(self, prompts: jax.Array, max_new: int,
                 temperature: float = 0.0,
                 key: Optional[jax.Array] = None) -> GenerationResult:
        if temperature > 0.0 and key is None:
            raise ValueError(
                "temperature-sampled generation requires a PRNG key: pass "
                "key=jax.random.key(...) to generate(), or use "
                "temperature=0.0 for greedy decoding")
        b, s = prompts.shape[0], prompts.shape[1]
        logits, cache = self._prefill(self.params, prompts, s + max_new)
        toks, lps = [], []
        tok = None
        for i in range(max_new):
            if temperature > 0.0:
                key, sub = jax.random.split(key)
                tok = jax.random.categorical(sub, logits / temperature, axis=-1)
            else:
                tok = jnp.argmax(logits, axis=-1)
            lp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
            lps.append(jnp.take_along_axis(lp, tok[:, None], axis=-1)[:, 0])
            toks.append(tok)
            if i < max_new - 1:
                logits, cache = self._decode(self.params, cache, tok[:, None])
        return GenerationResult(jnp.stack(toks, 1), jnp.stack(lps, 1), max_new)
