"""Serving engine: packed-weight inference with prefill + batched decode.

The paper's headline inference result (binarized nets cut inference time
~10x on FPGA vs the unregularized FPGA net, >25% vs GPU) maps on TPU to the
*packed-weight* serving path: projection weights are binarized once
(deterministically, Eq. 1 — the paper also evaluates inference of
stochastically-trained nets with their master-sign weights) and stored as
bitpacked int32 (+ optional per-channel scale), so decode — a weight-bytes-
bound workload — moves ~16x fewer HBM bytes. ``pack_params`` swaps selected
2-D projection leaves for ``PackedLinear`` nodes; the unchanged model code
dispatches through ``apply_linear``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.core.binarize import BinarizeMode
from repro.core.packing import PACK
from repro.kernels import ops as kops
from repro.models import transformer as T
from repro.models.layers import PackedLinear, XnorConv, XnorLinear


def pack_params(params, policy, mode: str | BinarizeMode = "det",
                key: Optional[jax.Array] = None, with_scale: bool = True,
                xnor_policy=None):
    """Binarize+bitpack every policy-selected >=2-D projection leaf.

    Stacked leaves (L, K, N) pack per layer via vmap; the resulting
    PackedLinear children keep the leading stack dims so ``lax.scan`` slices
    them exactly like dense leaves. MoE expert tensors (E-stacked) pack the
    same way. ``with_scale`` stores the per-output-channel mean |w| (BWN
    alpha) so packed inference tracks the master weights' magnitude.

    ``mode="xnor"`` selects the fully-binary engine: weights binarize
    deterministically (Eq. 1) exactly as ``mode="det"``, but leaves *also*
    selected by ``xnor_policy`` (default ``core.policy.XNOR_POLICY``) become
    :class:`XnorLinear` — at apply time their activations are sign-binarized
    + bitpacked on the fly and the dot runs on the XNOR-popcount kernel.
    Conv-stack kernels (4-D ``conv/<i>/kernel`` leaves, VGG) become
    :class:`XnorConv` the same way — binary im2col popcount conv. Under
    every other mode (and for xnor-excluded conv layers) a policy-selected
    conv kernel is binarized but stored *densely* (±1 values [* alpha]; the
    packed-weight MXU path has no conv lowering), so serving still runs the
    Alg.-1 inference network. For the paper's FC/VGG stacks the default
    xnor policy keeps
    the first (real-valued-input) layer — and VGG's first conv block — on
    the real-valued/PackedLinear path; transformer projections all qualify,
    since their real-valued front (embedding / lm_head) is excluded from
    binarization altogether — see ``core.policy.XNOR_POLICY`` for the exact
    boundary."""
    xnor = mode == "xnor"
    if xnor:
        if xnor_policy is None:
            from repro.core.policy import XNOR_POLICY as xnor_policy
        mode = BinarizeMode.DETERMINISTIC
    mode = BinarizeMode.parse(mode)
    leaves_with_paths = jax.tree_util.tree_leaves_with_path(params)
    from repro.core.binarize import _path_str
    from repro.core.policy import is_conv_kernel

    out = []
    for i, (path, leaf) in enumerate(leaves_with_paths):
        s = _path_str(path)
        if is_conv_kernel(s) and getattr(leaf, "ndim", 0) == 4:
            if not policy.selects(s):
                out.append(leaf)
                continue
            scale = None
            if with_scale:
                scale = jnp.mean(jnp.abs(leaf.astype(jnp.float32)),
                                 axis=(0, 1, 2))
            if xnor and xnor_policy.selects(s):
                from repro.xnor.conv import pack_conv_kernel

                kh, kw, c_in, n_dim = leaf.shape
                out.append(XnorConv(pack_conv_kernel(leaf), scale,
                                    (kh, kw), c_in))
            else:
                # No packed-weight MXU conv path: serve the Alg.-1 inference
                # network with densely-stored *binarized* values (±1 [*alpha])
                # so the weights match what training optimized.
                from repro.core import binarize as B

                if mode is BinarizeMode.STOCHASTIC:
                    if key is None:
                        raise ValueError("stochastic packing requires a key")
                    wb = B.stochastic_binarize(leaf,
                                               jax.random.fold_in(key, i))
                else:
                    wb = B.deterministic_binarize(leaf)
                if scale is not None:
                    wb = (wb.astype(jnp.float32) * scale).astype(leaf.dtype)
                out.append(wb)
            continue
        if (not policy.selects(s) or leaf.ndim < 2
                or leaf.shape[-2] % PACK != 0):
            out.append(leaf)
            continue
        k_dim, n_dim = leaf.shape[-2], leaf.shape[-1]
        lead = leaf.shape[:-2]
        w2 = leaf.reshape((-1, k_dim, n_dim))
        if mode is BinarizeMode.STOCHASTIC:
            if key is None:
                raise ValueError("stochastic packing requires a key")
            ks = jax.random.split(jax.random.fold_in(key, i), w2.shape[0])
            packed = jax.vmap(
                lambda w, kk: kops.binarize_and_pack(w, kk, stochastic=True)
            )(w2, ks)
        else:
            packed = jax.vmap(
                lambda w: kops.binarize_and_pack(w, stochastic=False))(w2)
        scale = None
        if with_scale:
            scale = jnp.mean(jnp.abs(w2.astype(jnp.float32)), axis=1)  # (-1, N)
            scale = scale.reshape(lead + (n_dim,))
        packed = packed.reshape(lead + (k_dim // PACK, n_dim))
        cls = XnorLinear if (xnor and xnor_policy.selects(s)) else PackedLinear
        out.append(cls(packed, scale, k_dim))
    treedef = jax.tree_util.tree_structure(params)
    return jax.tree_util.tree_unflatten(treedef, out)


def packed_param_bytes(params) -> tuple[int, int]:
    """(dense bf16 bytes, packed bytes) over policy-packed leaves."""
    dense = packed = 0
    packed_types = (PackedLinear, XnorLinear, XnorConv)
    for leaf in jax.tree_util.tree_leaves(
            params, is_leaf=lambda x: isinstance(x, packed_types)):
        if isinstance(leaf, packed_types):
            dense += leaf.k * leaf.packed.shape[-1] * 2 * max(
                1, int(jnp.prod(jnp.array(leaf.packed.shape[:-2]))))
            packed += leaf.packed.size * 4
            if leaf.scale is not None:
                packed += leaf.scale.size * 4
        else:
            dense += leaf.size * 2
            packed += leaf.size * 2
    return dense, packed


# ---------------------------------------------------------------------------
# generation
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class GenerationResult:
    tokens: jax.Array          # (B, max_new)
    logprobs: jax.Array        # (B, max_new)
    steps: int


class ServeEngine:
    """Batched prefill + greedy/temperature decode over a (possibly packed)
    parameter tree."""

    def __init__(self, cfg, params, sh=None):
        self.cfg = cfg
        self.params = params
        self.sh = sh
        self._prefill = jax.jit(
            lambda p, toks, ml: T.prefill(cfg, p, toks, sh, max_len=ml),
            static_argnums=2)
        self._decode = jax.jit(
            lambda p, cache, tok: T.decode_step(cfg, p, cache, tok, sh))

    def generate(self, prompts: jax.Array, max_new: int,
                 temperature: float = 0.0,
                 key: Optional[jax.Array] = None) -> GenerationResult:
        b, s = prompts.shape[0], prompts.shape[1]
        logits, cache = self._prefill(self.params, prompts, s + max_new)
        toks, lps = [], []
        tok = None
        for i in range(max_new):
            if temperature > 0.0:
                key, sub = jax.random.split(key)
                tok = jax.random.categorical(sub, logits / temperature, axis=-1)
            else:
                tok = jnp.argmax(logits, axis=-1)
            lp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
            lps.append(jnp.take_along_axis(lp, tok[:, None], axis=-1)[:, 0])
            toks.append(tok)
            if i < max_new - 1:
                logits, cache = self._decode(self.params, cache, tok[:, None])
        return GenerationResult(jnp.stack(toks, 1), jnp.stack(lps, 1), max_new)
