"""Serving engine: packed-weight inference with prefill + batched decode.

The paper's headline inference result (binarized nets cut inference time
~10x on FPGA vs the unregularized FPGA net, >25% vs GPU) maps on TPU to the
*packed-weight* serving path: projection weights are binarized once
(deterministically, Eq. 1 — the paper also evaluates inference of
stochastically-trained nets with their master-sign weights) and stored as
bitpacked int32 (+ optional per-channel scale), so decode — a weight-bytes-
bound workload — moves ~16x fewer HBM bytes.

Which datapath each layer gets is decided by the execution-plan compiler
(``repro.engine``): ``pack_params`` is a thin wrapper over
``compile_plan(...).pack(params)``, and the model code dispatches through
``apply_linear``/``apply_conv2d`` on the serving leaf types the plan
produced. Compile the plan yourself to inspect, save, or override the
per-layer assignment (``launch.serve --plan-report`` prints it).

Serving is *step-level continuously batched* (:func:`stream_serve`): the
KV cache is a persistent, slot-addressed structure (``DecodeState``), a
finished request's slot is re-prefilled from the queue mid-stream
(``ServeEngine.prefill_into``), and one fixed-shape jitted ``decode_step``
advances all slots each step — sustained streaming throughput rather than
round-based batch latency, which is where the binarized datapaths' byte
savings actually pay off (cf. FINN, arXiv:1612.07119).

Serving is also *mesh-shardable*: ``ServeEngine(cfg, params, mesh=mesh,
plan=plan)`` places the packed tree and the slot-addressed decode cache on
a ("data", "model") mesh following the plan's sharding column — the
paper-to-TPU analogue of FINN-style datapath widening: BNN throughput comes
from scaling the datapath wide across compute units, not from one unit.
"""
from __future__ import annotations

import contextlib
import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.binarize import BinarizeMode
from repro.engine import compile_plan
from repro.models import transformer as T
from repro.models.layers import (PackedConv, PackedLinear, XnorConv,
                                 XnorLinear)
from repro.obs.trace import NULL_TRACER


def pack_params(params, policy, mode: str | BinarizeMode = "det",
                key: Optional[jax.Array] = None, with_scale: bool = True,
                xnor_policy=None, overrides=None):
    """Binarize+bitpack every policy-selected >=2-D projection leaf.

    Equivalent to ``repro.engine.compile_plan(...).pack(params, key)`` —
    kept as the one-call convenience entry point. Stacked leaves (L, K, N)
    pack per layer via vmap; the resulting PackedLinear children keep the
    leading stack dims so ``lax.scan`` slices them exactly like dense
    leaves. MoE expert tensors (E-stacked) pack the same way. ``with_scale``
    stores the per-output-channel mean |w| (BWN alpha) so packed inference
    tracks the master weights' magnitude.

    ``mode="xnor"`` selects the fully-binary engine: weights binarize
    deterministically (Eq. 1) exactly as ``mode="det"``, but leaves *also*
    selected by ``xnor_policy`` (default ``core.policy.XNOR_POLICY``) land
    on the ``xnor`` / ``xnor_conv`` backends (activations sign-binarized +
    bitpacked on the fly, XNOR-popcount compute). Policy-selected conv
    kernels with no binary lowering serve Alg.-1 binarized values stored
    densely (the ``binarized_dense`` backend); policy-selected projections
    that cannot bitpack (K % 32 != 0, ndim < 2) serve dense — no longer
    silently: the compiled plan records the reason per layer and warns.
    See ``repro.engine`` for the backend registry and
    ``core.policy.XNOR_POLICY`` for the real-valued-input boundary."""
    plan = compile_plan(params, policy, mode, xnor_policy=xnor_policy,
                        with_scale=with_scale, overrides=overrides)
    return plan.pack(params, key=key)


def packed_param_bytes(params) -> tuple[int, int]:
    """(dense bf16 bytes, packed bytes) over policy-packed leaves.

    The dense baseline is derived from each serving leaf's recorded
    *master-weight* shape (``leaf.master_shape``, stack dims included) —
    never from the packed array's word counts, which over-state K whenever
    a layout carries self-cancelling pad words (the xnor conv engine's
    per-tap channel padding, or any future padded layout). The packed side
    counts the int32 words actually stored (pad words are real bytes)."""
    dense = packed = 0
    packed_types = (PackedLinear, XnorLinear, XnorConv, PackedConv)
    for leaf in jax.tree_util.tree_leaves(
            params, is_leaf=lambda x: isinstance(x, packed_types)):
        if isinstance(leaf, packed_types):
            n_master = 1
            for d in leaf.master_shape:
                n_master *= d
            dense += n_master * 2
            packed += leaf.packed.size * 4
            if leaf.scale is not None:
                packed += leaf.scale.size * 4
        else:
            dense += leaf.size * 2
            packed += leaf.size * 2
    return dense, packed


# ---------------------------------------------------------------------------
# generation
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class GenerationResult:
    """Logprob convention: ``logprobs[b, i]`` is the log-probability of
    ``tokens[b, i]`` under the distribution the token was actually drawn
    from — ``softmax(logits / temperature)`` when sampling, ``softmax(
    logits)`` for greedy decoding (temperature 0). Tempered logprobs are
    therefore comparable across tokens of one generation but not across
    runs at different temperatures.

    The ensemble fields are populated only when the engine serves a
    K >= 2 :class:`repro.stoch.ReplicaSet` (None otherwise):
    ``vote_agreement[b, i]`` is the fraction of replicas whose argmax at
    step i matched the ensemble vote, ``logit_variance[b, i]`` the mean
    across-replica logit variance, and ``abstained[b]`` flags generations
    whose worst-step agreement fell below the engine's
    ``abstain_threshold``."""

    tokens: jax.Array          # (B, max_new)
    logprobs: jax.Array        # (B, max_new)
    steps: int
    logit_variance: Optional[jax.Array] = None   # (B, max_new) f32
    vote_agreement: Optional[jax.Array] = None   # (B, max_new) f32
    abstained: Optional[jax.Array] = None        # (B,) bool


@dataclasses.dataclass
class DecodeState:
    """Live state of the step-level continuous-batching engine: one
    long-lived, slot-addressed KV cache plus the next-token logits of every
    slot. Requests come and go (``prefill_into``); the state's shapes never
    change, so the jitted decode step never re-specializes."""

    cache: dict                # slot-addressed decode cache (B = n_slots);
                               # ensemble serving adds a leading (K,) axis
    logits: jax.Array          # (n_slots, vocab) next-token logits per slot
    n_slots: int
    prompt_len: int
    max_new_cap: int           # per-request max_new must be <= this
    # Ensemble-serving uncertainty of each slot's current logits (None on
    # the single-sample path): replica vote agreement and mean logit
    # variance, refreshed by every prefill_into / decode_step.
    agreement: Optional[jax.Array] = None        # (n_slots,) f32
    variance: Optional[jax.Array] = None         # (n_slots,) f32

    @property
    def context_len(self) -> int:
        return self.prompt_len + self.max_new_cap


class ServeEngine:
    """Batched prefill + greedy/temperature decode over a (possibly packed)
    parameter tree.

    Two serving modes share the same jitted model functions:

    * one-shot: ``generate(prompts, max_new)`` — prefill a batch, decode
      every row for ``max_new`` steps (the tier-1 parity oracle);
    * step-level continuous batching: ``init_decode`` builds a persistent
      slot-addressed :class:`DecodeState`, ``prefill_into`` splices a fresh
      request into a live cache at a slot index, and ``decode_step``
      advances *all* slots one token with a single fixed-shape jitted call.
      ``stream_serve`` drives the loop against a ``SlotBatcher``.

    **Mesh-sharded serving.** Pass ``mesh`` (a ``jax.sharding.Mesh`` with
    "data"/"model" axes) to serve tensor-parallel: the engine places the
    parameter tree on the mesh (packed int32 weight words TP-sharded over
    "model" on the out-channel dim — a 32-bit lane group never splits
    across devices; dense leaves on the Megatron rules), builds a
    ``ShardCtx`` so activation constraints thread through the
    ``apply_linear``/``apply_conv2d`` dispatch, and places the persistent
    decode cache with slots over "data" (``models.transformer.
    cache_pspecs``). All jitted entry points run under ``mesh_context``.
    Pass the ``plan`` the tree was packed with to follow its recorded
    sharding column exactly (otherwise equivalent rules are re-derived
    from leaf types and paths). Greedy streams stay bit-identical to the
    single-device engine (asserted in ``tests/test_distributed.py``).
    """

    def __init__(self, cfg, params, sh=None, *, mesh=None, plan=None,
                 ensemble=None, abstain_threshold: Optional[float] = None,
                 tracer=None):
        self.cfg = cfg
        self.mesh = mesh
        self.abstain_threshold = abstain_threshold
        # Observability (repro.obs): spans around every jitted entry point,
        # with a dispatch/device split via block_until_ready fencing. The
        # default NULL_TRACER makes every span site a no-op — in particular
        # no fencing, so the async dispatch pipeline is untouched.
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self._replicas = None
        if ensemble is not None:
            from repro.stoch import ReplicaSet

            if not isinstance(ensemble, ReplicaSet):
                raise TypeError(
                    f"ensemble= expects a repro.stoch.ReplicaSet "
                    f"(sample_replicas(...)), got {type(ensemble).__name__}")
            if params is not None and params is not ensemble.base:
                raise ValueError(
                    "pass either params or ensemble=ReplicaSet, not both "
                    "(the ensemble's base tree is the parameter tree)")
            plan = plan if plan is not None else ensemble.plan
        if mesh is not None:
            from repro.distributed.sharding import (ShardCtx,
                                                    place_packed_params)

            if sh is None:
                # decode=True: the serving activation layout — no sequence
                # parallelism on the one-token stream, replicated residual,
                # model-replicated cache (local in-place writes), one
                # deferred logits gather. See ShardCtx and
                # docs/ARCHITECTURE.md §Decode-step collective budget.
                sh = ShardCtx(mesh, decode=True)
            if ensemble is not None:
                from repro.stoch import place_replicas

                ensemble = place_replicas(mesh, ensemble, plan)
                params = ensemble.base
            else:
                params = place_packed_params(mesh, params, plan)
        elif ensemble is not None:
            params = ensemble.base
        elif plan is not None:
            raise ValueError("ServeEngine(plan=...) only places params on a "
                             "mesh; pass mesh= as well (or drop plan=)")
        self.params = params
        self.sh = sh
        self._prefill = jax.jit(
            lambda p, toks, ml: T.prefill(cfg, p, toks, sh, max_len=ml),
            static_argnums=2)
        # The persistent cache is donated: the per-step KV write updates the
        # long-lived buffer in place instead of copying the whole cache per
        # token. Every caller (generate / decode_step / decode_steps)
        # rebinds its state to the returned cache, so the consumed input
        # buffer is never touched again. _pin_state pins the returned state
        # to the init_decode placement: left unconstrained, GSPMD may pick a
        # different output layout (e.g. xnor's row-parallel w_o propagates
        # KV-heads-over-"model" onto the returned cache), which breaks the
        # input==output sharding invariant donation relies on and retraces
        # the jit into a slower steady-state program than the audited one.
        def _decode_fn(p, cache, tok):
            lg, cache = T.decode_step(cfg, p, cache, tok, sh)
            cache, lg = self._pin_state(cache, lg)
            return lg, cache

        self._decode = jax.jit(_decode_fn, donate_argnums=(1,))

        def _decode_chunk(p, cache, logits, d):
            """d fixed-shape greedy decode steps under one lax.scan: emits
            the argmax token per slot per step and leaves ``logits`` at the
            next-token logits (the DecodeState invariant), so the serving
            loop crosses the host boundary once per d tokens."""
            def body(carry, _):
                cache, logits = carry
                tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
                lg, cache = T.decode_step(cfg, p, cache, tok[:, None], sh)
                return (cache, lg.astype(logits.dtype)), tok

            (cache, logits), toks = jax.lax.scan(
                body, (cache, logits), None, length=d)
            cache, logits = self._pin_state(cache, logits)
            return cache, logits, jnp.moveaxis(toks, 0, 1)  # (n_slots, d)

        self._decode_chunk = jax.jit(_decode_chunk, static_argnums=3,
                                     donate_argnums=(1, 2))

        def _prefill_into(p, cache, logits, prompt, slot, ml):
            lg, one = T.prefill(cfg, p, prompt, sh, max_len=ml)
            logits = jax.lax.dynamic_update_slice_in_dim(
                logits, lg.astype(logits.dtype), slot, axis=0)
            cache = T.cache_insert(cfg, cache, one, slot)
            cache, logits = self._pin_state(cache, logits)
            return logits, cache

        self._prefill_into = jax.jit(_prefill_into, static_argnums=5)

        def _prefill_chunk(p, cache, logits, chunk_toks, slot, offset):
            """One prefill chunk for one slot, no decode (the ramp-up /
            drain path when no other slot is actively decoding)."""
            lg, cache = T.prefill_chunk(cfg, p, cache, chunk_toks, slot,
                                        offset, sh)
            logits = jax.lax.dynamic_update_slice_in_dim(
                logits, lg.astype(logits.dtype), slot, axis=0)
            cache, logits = self._pin_state(cache, logits)
            return logits, cache

        self._prefill_chunk = jax.jit(_prefill_chunk)

        def _decode_prefill(p, cache, logits, tok, keep, chunk_toks, slot,
                            offset):
            """The fused steady-state step of chunked prefill: advance all
            live decode slots one token AND one slot's prefill by one chunk,
            in a single fixed-shape program. ``keep`` (n_slots,) bool marks
            mid-prefill slots whose logits and non-rewritable cache state
            must survive the batched decode: cache_keep re-selects the old
            position counters and recurrent ssm/conv states bit-exactly
            (append-style K/V writes land where the slot's next chunk
            overwrites them — see its docstring) before the chunk runs."""
            dec_lg, dec_cache = T.decode_step(cfg, p, cache, tok, sh)
            cache = T.cache_keep(cfg, cache, dec_cache, keep)
            logits = jnp.where(keep[:, None], logits,
                               dec_lg.astype(logits.dtype))
            lg, cache = T.prefill_chunk(cfg, p, cache, chunk_toks, slot,
                                        offset, sh)
            logits = jax.lax.dynamic_update_slice_in_dim(
                logits, lg.astype(logits.dtype), slot, axis=0)
            cache, logits = self._pin_state(cache, logits)
            return logits, cache

        self._decode_prefill = jax.jit(_decode_prefill,
                                       donate_argnums=(1, 2))

        def _splice(cache, logits, one, lg, slot, use_lg):
            """Splice a prefix-cache snapshot (batch-1 rows) into a slot;
            ``use_lg`` (static) also installs the snapshot's first-token
            logits (full-prompt hits)."""
            cache = T.cache_insert(cfg, cache, one, slot)
            if use_lg:
                logits = jax.lax.dynamic_update_slice_in_dim(
                    logits, lg.astype(logits.dtype), slot, axis=0)
            cache, logits = self._pin_state(cache, logits)
            return logits, cache

        self._splice = jax.jit(_splice, static_argnums=5)

        def _extract(cache, logits, slot):
            """Batch-1 snapshot of one slot's cache rows + logits row (the
            capture side of the prefix cache)."""
            one = T.cache_extract(cfg, cache, slot)
            lg = jax.lax.dynamic_slice_in_dim(logits, slot, 1, axis=0)
            return one, lg

        self._extract = jax.jit(_extract)

        # K = 1 (or no stochastic rows) degrades to the plain single-sample
        # path above on ensemble.base — structurally the same program, so
        # the ensemble flag costs nothing and k=1 stays bit-identical.
        if ensemble is not None and ensemble.k > 1 and ensemble.stacked:
            self._replicas = ensemble
            self._build_ensemble_fns()

    def _pin_state(self, cache, logits):
        """Constrain a decode state (cache dict + next-token logits) to the
        ``init_decode`` placement, inside a jit trace. Keeps every decode /
        prefill_into output on the exact sharding the persistent buffers
        were allocated with, so the steady-state program is the same one
        the collective audit measured and donation never hits an
        input/output sharding mismatch."""
        if self.mesh is None:
            return cache, logits
        from jax.sharding import NamedSharding
        from repro.distributed.sharding import batch_axes, sanitize_spec

        pspecs = T.cache_pspecs(self.cfg, batch_axes(self.mesh))

        def pin(a, spec):
            spec = sanitize_spec(self.mesh, spec, a.shape)
            return jax.lax.with_sharding_constraint(
                a, NamedSharding(self.mesh, spec))

        cache = {k: pin(v, pspecs[k]) for k, v in cache.items()}
        return cache, pin(logits, pspecs["pos"])

    def _pin_ens_cache(self, cache):
        """Replica-axis variant of ``_pin_state`` for the K-stacked
        ensemble cache (same placement ``init_decode`` uses)."""
        if self.mesh is None:
            return cache
        from jax.sharding import NamedSharding
        from repro.distributed.sharding import batch_axes, sanitize_spec
        from repro.stoch.ensemble import prepend_replica_axis

        ax = self._replicas.plan.replica_axis
        pspecs = T.cache_pspecs(self.cfg, batch_axes(self.mesh))
        out = {}
        for k, v in cache.items():
            spec = sanitize_spec(self.mesh,
                                 prepend_replica_axis(ax, pspecs[k]), v.shape)
            out[k] = jax.lax.with_sharding_constraint(
                v, NamedSharding(self.mesh, spec))
        return out

    def _build_ensemble_fns(self):
        """Jitted K-replica variants of prefill / decode / prefill_into:
        one vmap over the stacked stochastic leaves (and, for decode, the
        replicated cache axis), shared base leaves broadcast by closure,
        replica logits condensed to EnsembleStats inside the jit."""
        from repro.stoch import ensemble_stats
        from repro.stoch.replicas import _substitute

        cfg, sh, k = self.cfg, self.sh, self._replicas.k

        def _ens_prefill(stacked, base, toks, ml):
            def one(st):
                return T.prefill(cfg, _substitute(base, st), toks, sh,
                                 max_len=ml)

            rep_lg, rep_cache = jax.vmap(one, in_axes=0, axis_size=k)(stacked)
            return ensemble_stats(rep_lg), rep_cache

        self._prefill_ens = jax.jit(_ens_prefill, static_argnums=3)

        def _ens_decode(stacked, base, cache, tok):
            def one(st, c):
                return T.decode_step(cfg, _substitute(base, st), c, tok, sh)

            rep_lg, cache = jax.vmap(one, in_axes=(0, 0),
                                     axis_size=k)(stacked, cache)
            return ensemble_stats(rep_lg), self._pin_ens_cache(cache)

        # same donation contract as the single-sample _decode: the
        # K-replica cache updates in place, callers rebind their state
        self._decode_ens = jax.jit(_ens_decode, donate_argnums=(2,))

        def _ens_prefill_into(stacked, base, cache, logits, agree, var,
                              prompt, slot, ml):
            def one(st, c):
                lg, onec = T.prefill(cfg, _substitute(base, st), prompt, sh,
                                     max_len=ml)
                return lg, T.cache_insert(cfg, c, onec, slot)

            rep_lg, cache = jax.vmap(one, in_axes=(0, 0),
                                     axis_size=k)(stacked, cache)
            es = ensemble_stats(rep_lg)          # mean (1, V); stats (1,)
            upd = jax.lax.dynamic_update_slice_in_dim
            return (upd(logits, es.mean_logits.astype(logits.dtype), slot, 0),
                    upd(agree, es.agreement, slot, 0),
                    upd(var, es.variance, slot, 0),
                    self._pin_ens_cache(cache))

        self._ens_prefill_into = jax.jit(_ens_prefill_into, static_argnums=8)

    def jit_entries(self) -> dict:
        """Name -> jitted entry point, for observability wrappers (the
        retrace sentinel watches these caches during ``stream_serve``).
        Ensemble entries appear only when the engine serves replicas;
        ``decode_chunk`` legitimately compiles one program per distinct
        chunk length (allowlisted by the sentinel's default)."""
        entries = {"prefill": self._prefill, "decode": self._decode,
                   "decode_chunk": self._decode_chunk,
                   "prefill_into": self._prefill_into,
                   "prefill_chunk": self._prefill_chunk,
                   "decode_prefill": self._decode_prefill,
                   "splice": self._splice, "extract": self._extract}
        for name in ("_prefill_ens", "_decode_ens", "_ens_prefill_into"):
            fn = getattr(self, name, None)
            if fn is not None:
                entries[name.strip("_")] = fn
        return entries

    def _mesh_ctx(self):
        """Ambient-mesh context for every jitted call (no-op off-mesh)."""
        if self.mesh is None:
            return contextlib.nullcontext()
        from repro.distributed.sharding import mesh_context

        return mesh_context(self.mesh)

    def generate(self, prompts: jax.Array, max_new: int,
                 temperature: float = 0.0,
                 key: Optional[jax.Array] = None) -> GenerationResult:
        if temperature > 0.0 and key is None:
            raise ValueError(
                "temperature-sampled generation requires a PRNG key: pass "
                "key=jax.random.key(...) to generate(), or use "
                "temperature=0.0 for greedy decoding")
        if self._replicas is not None:
            return self._generate_ensemble(prompts, max_new, temperature, key)
        b, s = prompts.shape[0], prompts.shape[1]
        with self._mesh_ctx():
            logits, cache = self._prefill(self.params, prompts, s + max_new)
            toks, lps = [], []
            tok = None
            for i in range(max_new):
                if temperature > 0.0:
                    key, sub = jax.random.split(key)
                    sample_logits = logits.astype(jnp.float32) / temperature
                    tok = jax.random.categorical(sub, sample_logits, axis=-1)
                else:
                    sample_logits = logits.astype(jnp.float32)
                    tok = jnp.argmax(logits, axis=-1)
                # logprob under the *sampled* (tempered) distribution — see
                # GenerationResult for the convention
                lp = jax.nn.log_softmax(sample_logits, axis=-1)
                lps.append(jnp.take_along_axis(lp, tok[:, None],
                                               axis=-1)[:, 0])
                toks.append(tok)
                if i < max_new - 1:
                    logits, cache = self._decode(self.params, cache,
                                                 tok[:, None])
        return GenerationResult(jnp.stack(toks, 1), jnp.stack(lps, 1), max_new)

    def _generate_ensemble(self, prompts, max_new, temperature, key):
        """One-shot generation over all K replicas: tokens decode from the
        ensemble-mean logits; every step also records vote agreement and
        logit variance (same sampling/logprob conventions as the plain
        path, applied to the mean logits)."""
        rs = self._replicas
        s = prompts.shape[1]
        with self._mesh_ctx():
            es, cache = self._prefill_ens(rs.stacked, rs.base, prompts,
                                          s + max_new)
            toks, lps, agrs, vrs = [], [], [], []
            for i in range(max_new):
                logits = es.mean_logits                  # already f32
                if temperature > 0.0:
                    key, sub = jax.random.split(key)
                    sample_logits = logits / temperature
                    tok = jax.random.categorical(sub, sample_logits, axis=-1)
                else:
                    sample_logits = logits
                    tok = jnp.argmax(logits, axis=-1)
                lp = jax.nn.log_softmax(sample_logits, axis=-1)
                lps.append(jnp.take_along_axis(lp, tok[:, None],
                                               axis=-1)[:, 0])
                toks.append(tok)
                agrs.append(es.agreement)
                vrs.append(es.variance)
                if i < max_new - 1:
                    es, cache = self._decode_ens(rs.stacked, rs.base, cache,
                                                 tok[:, None])
        agreement = jnp.stack(agrs, 1)
        abstained = None
        if self.abstain_threshold is not None:
            abstained = jnp.min(agreement, axis=1) < self.abstain_threshold
        return GenerationResult(
            jnp.stack(toks, 1), jnp.stack(lps, 1), max_new,
            logit_variance=jnp.stack(vrs, 1), vote_agreement=agreement,
            abstained=abstained)

    # -- step-level continuous batching -----------------------------------

    def init_decode(self, n_slots: int, prompt_len: int,
                    max_new_cap: int) -> DecodeState:
        """Allocate the persistent decode state: a zeroed slot-addressed
        cache sized for ``prompt_len + max_new_cap`` context positions and
        an empty next-token logits buffer. Slots fill via ``prefill_into``;
        empty slots decode padding and are masked out by the caller.

        On a mesh, the state is *placed*, not just allocated: slots shard
        over the data axes and KV sequence / SSM heads over "model"
        (``models.transformer.cache_pspecs``), so the long-lived cache
        bytes — the decode working set — scale down per device."""
        ctx = prompt_len + max_new_cap
        cache = T.init_cache(self.cfg, n_slots, ctx)
        ens = self._replicas
        agreement = variance = None
        if ens is not None:
            # one cache per replica: a leading (K,) axis on every entry,
            # kept resident across decode steps; the uncertainty columns
            # start at the no-signal values (full agreement, zero variance)
            cache = {k: jnp.zeros((ens.k,) + v.shape, v.dtype)
                     for k, v in cache.items()}
            agreement = jnp.ones((n_slots,), jnp.float32)
            variance = jnp.zeros((n_slots,), jnp.float32)
        logits = jnp.zeros((n_slots, self.cfg.vocab_size),
                           jnp.float32 if ens is not None
                           else self.cfg.activation_dtype)
        if self.mesh is not None:
            from jax.sharding import NamedSharding
            from repro.distributed.sharding import batch_axes, sanitize_spec

            pspecs = T.cache_pspecs(self.cfg, batch_axes(self.mesh))
            if ens is not None:
                from repro.stoch.ensemble import prepend_replica_axis

                pspecs = {k: prepend_replica_axis(ens.plan.replica_axis, s)
                          for k, s in pspecs.items()}

            def put(a, spec):
                spec = sanitize_spec(self.mesh, spec, a.shape)
                return jax.device_put(a, NamedSharding(self.mesh, spec))

            cache = {k: put(v, pspecs[k]) for k, v in cache.items()}
            # logits (n_slots, vocab): slot dim placed exactly like the
            # cache's pos/slot axes (same one-axis spec), vocab replicated
            slot_spec = T.cache_pspecs(self.cfg,
                                       batch_axes(self.mesh))["pos"]
            logits = put(logits, slot_spec)
            if ens is not None:
                agreement = put(agreement, slot_spec)
                variance = put(variance, slot_spec)
        return DecodeState(cache, logits, n_slots, prompt_len, max_new_cap,
                           agreement=agreement, variance=variance)

    def prefill_into(self, state: DecodeState, slot: int,
                     prompt) -> DecodeState:
        """Prefill one request (prompt of static length ``prompt_len``) and
        splice its cache + first-token logits into the live state at slot
        index ``slot``. One compiled program serves every slot (the index
        is a traced scalar; all shapes are static)."""
        tr = self.tracer
        prompt = jnp.asarray(prompt, jnp.int32).reshape(1, state.prompt_len)
        if self._replicas is not None:
            rs = self._replicas
            with tr.span("prefill_into", slot=slot), self._mesh_ctx():
                with tr.span("dispatch"):
                    logits, agree, var, cache = self._ens_prefill_into(
                        rs.stacked, rs.base, state.cache, state.logits,
                        state.agreement, state.variance, prompt,
                        jnp.int32(slot), state.context_len)
                with tr.span("device"):
                    tr.fence(logits)
            return dataclasses.replace(state, cache=cache, logits=logits,
                                       agreement=agree, variance=var)
        with tr.span("prefill_into", slot=slot), self._mesh_ctx():
            with tr.span("dispatch"):
                logits, cache = self._prefill_into(
                    self.params, state.cache, state.logits, prompt,
                    jnp.int32(slot), state.context_len)
            with tr.span("device"):
                tr.fence(logits)
        return dataclasses.replace(state, cache=cache, logits=logits)

    def decode_step(self, state: DecodeState, tokens) -> DecodeState:
        """Advance every slot one token (single fixed-shape jitted call).
        ``tokens``: (n_slots,) int32 — the token just emitted per slot;
        inactive slots feed padding and their outputs are ignored."""
        tr = self.tracer
        tokens = jnp.asarray(tokens, jnp.int32).reshape(state.n_slots, 1)
        if self._replicas is not None:
            rs = self._replicas
            with tr.span("decode_step"), self._mesh_ctx():
                with tr.span("dispatch"):
                    es, cache = self._decode_ens(rs.stacked, rs.base,
                                                 state.cache, tokens)
                with tr.span("device"):
                    tr.fence(es.mean_logits)
            return dataclasses.replace(
                state, cache=cache,
                logits=es.mean_logits.astype(state.logits.dtype),
                agreement=es.agreement, variance=es.variance)
        with tr.span("decode_step"), self._mesh_ctx():
            with tr.span("dispatch"):
                logits, cache = self._decode(self.params, state.cache,
                                             tokens)
            with tr.span("device"):
                tr.fence(logits)
        return dataclasses.replace(state, cache=cache, logits=logits)

    def decode_steps(self, state: DecodeState, d: int):
        """Advance every slot ``d`` greedy tokens in ONE jitted call (a
        fixed-shape ``lax.scan`` over ``d`` decode steps — argmax, decode,
        repeat — with the cache and logits donated through the scan).
        Returns ``(new_state, tokens)`` with ``tokens`` a (n_slots, d)
        int32 *device* array: the caller decides when to cross the host
        boundary (``jax.device_get``), so the steady-state serving loop
        pays one transfer per ``d`` tokens instead of one per token.

        Greedy only: temperature sampling threads a PRNG key per step and
        stays on the one-step path. ``state.logits`` keeps the DecodeState
        invariant (the not-yet-emitted next-token logits). Compiles one
        program per distinct ``d``; ``stream_serve`` uses a fixed chunk
        size clipped to the shortest live request, so at most
        ``decode_chunk`` variants exist."""
        if self._replicas is not None:
            raise NotImplementedError(
                "decode_steps is single-sample only; ensemble serving "
                "decodes one step at a time (stream_serve falls back)")
        tr = self.tracer
        with tr.span("decode_steps", d=d), self._mesh_ctx():
            with tr.span("dispatch"):
                cache, logits, toks = self._decode_chunk(
                    self.params, state.cache, state.logits, int(d))
            with tr.span("device"):
                tr.fence(logits)
        return dataclasses.replace(state, cache=cache, logits=logits), toks

    # -- chunked prefill + prefix reuse ------------------------------------

    def _require_single_sample(self, what: str) -> None:
        if self._replicas is not None:
            raise NotImplementedError(
                f"{what} is single-sample only; K-replica ensemble serving "
                f"prefills whole prompts (stream_serve falls back)")

    def prefill_chunk_into(self, state: DecodeState, slot: int, tokens,
                           offset: int) -> DecodeState:
        """Advance one slot's prefill by a chunk of prompt tokens (no
        decode): the ramp-up / drain path of chunked prefill. ``offset``
        is the number of prompt tokens already in the slot."""
        self._require_single_sample("prefill_chunk_into")
        tr = self.tracer
        toks = jnp.asarray(tokens, jnp.int32).reshape(1, -1)
        with tr.span("prefill_chunk", slot=slot, offset=int(offset),
                     c=int(toks.shape[1])), self._mesh_ctx():
            with tr.span("dispatch"):
                logits, cache = self._prefill_chunk(
                    self.params, state.cache, state.logits, toks,
                    jnp.int32(slot), jnp.int32(offset))
            with tr.span("device"):
                tr.fence(logits)
        return dataclasses.replace(state, cache=cache, logits=logits)

    def fused_step(self, state: DecodeState, tokens, keep_mask, slot: int,
                   chunk_tokens, offset: int) -> DecodeState:
        """The chunked-prefill steady state: ONE fixed-shape jitted call
        advances every live decode slot one token AND one slot's prefill by
        one chunk, so an arriving prompt never stalls the stream.
        ``tokens``: (n_slots,) just-emitted tokens; ``keep_mask``:
        (n_slots,) bool, True for mid-prefill slots whose state must
        survive the batched decode."""
        self._require_single_sample("fused_step")
        tr = self.tracer
        tokens = jnp.asarray(tokens, jnp.int32).reshape(state.n_slots, 1)
        keep = jnp.asarray(np.asarray(keep_mask, bool))
        toks = jnp.asarray(chunk_tokens, jnp.int32).reshape(1, -1)
        with tr.span("decode_prefill", slot=slot, offset=int(offset),
                     c=int(toks.shape[1])), self._mesh_ctx():
            with tr.span("dispatch"):
                logits, cache = self._decode_prefill(
                    self.params, state.cache, state.logits, tokens, keep,
                    toks, jnp.int32(slot), jnp.int32(offset))
            with tr.span("device"):
                tr.fence(logits)
        return dataclasses.replace(state, cache=cache, logits=logits)

    def capture_slot(self, state: DecodeState, slot: int):
        """Host (numpy) snapshot of one slot's cache rows + logits row —
        the capture side of the prefix cache. One explicit device->host
        transfer, at a chunk boundary (never in the decode steady state)."""
        self._require_single_sample("capture_slot")
        tr = self.tracer
        with tr.span("prefix_capture", slot=slot), self._mesh_ctx():
            one, lg = self._extract(state.cache, state.logits,
                                    jnp.int32(slot))
        return jax.device_get(one), jax.device_get(lg)

    def splice_into(self, state: DecodeState, slot: int, cache_rows: dict,
                    logits_row=None) -> DecodeState:
        """Splice a prefix-cache snapshot into a slot (prefix-cache hit).
        With ``logits_row`` (full-prompt snapshot) the slot is immediately
        decodable; otherwise chunked prefill continues from the snapshot's
        offset."""
        self._require_single_sample("splice_into")
        tr = self.tracer
        use_lg = logits_row is not None
        lg = (jnp.asarray(logits_row) if use_lg
              else jnp.zeros((1, state.logits.shape[1]),
                             state.logits.dtype))
        one = {k: jnp.asarray(v) for k, v in cache_rows.items()}
        with tr.span("prefix_splice", slot=slot,
                     full=bool(use_lg)), self._mesh_ctx():
            with tr.span("dispatch"):
                logits, cache = self._splice(state.cache, state.logits,
                                             one, lg, jnp.int32(slot),
                                             use_lg)
            with tr.span("device"):
                tr.fence(logits)
        return dataclasses.replace(state, cache=cache, logits=logits)


def stream_serve(engine: ServeEngine, batcher, *,
                 max_new_cap: Optional[int] = None,
                 temperature: float = 0.0,
                 key: Optional[jax.Array] = None,
                 metrics=None,
                 decode_chunk: int = 1,
                 sentinel=None,
                 prefill_chunk: int = 0,
                 prefix_cache=None,
                 arrivals=None) -> int:
    """Step-level continuous-batching serving loop.

    Each iteration: retire finished requests and re-prefill their slots
    from the queue (``batcher.refill``), emit one token for every active
    slot from the state's next-token logits, then run one masked decode
    step over all slots. A request finishing mid-stream frees its slot for
    the next queued request on the *next step* — no round barrier, and
    per-request ``max_new`` is honored exactly (``batcher.record`` stops
    appending at each request's own limit).

    ``max_new_cap`` sizes the persistent cache (default: the max over the
    currently queued requests); submitting a request with a larger
    ``max_new`` later raises. Returns the number of batched token-emission
    steps (the final emission needs no trailing decode_step, so the model
    runs ``steps - 1`` decode steps plus one prefill per request).

    ``decode_chunk > 1`` (greedy, non-ensemble serving only) switches the
    steady state onto the multi-step inner loop: each iteration runs
    ``d = min(decode_chunk, shortest live request's remaining budget)``
    decode steps in ONE jitted call (``ServeEngine.decode_steps``) and
    crosses the host boundary once per ``d`` tokens (a single explicit
    ``jax.device_get``). Clipping ``d`` to ``batcher.min_remaining()``
    keeps slot turnover on the chunk boundary, so refill timing — and
    therefore every emitted stream — is bit-identical to ``decode_chunk=1``
    (asserted in tests/test_distributed.py). Temperature sampling and
    K-replica ensemble serving fall back to the one-step loop.

    Observability: the engine's tracer (``ServeEngine(tracer=...)``) wraps
    the whole loop in a ``stream_serve`` span with one ``step`` span per
    iteration (``refill`` / ``sample`` / ``record`` children; the engine
    adds ``prefill_into`` / ``decode_step`` with dispatch/device splits).
    Pass ``metrics`` (a ``repro.obs.MetricsRegistry``) to record per-step
    latency, queue depth and slot occupancy histograms, prefill/step/token
    counters, the request-ledger TTFT/latency histograms, and a
    ``serve_tok_per_s`` gauge — the numbers ``serve_bench`` and
    ``launch.serve --metrics-out`` report.

    ``sentinel`` (a ``repro.analysis.RetraceSentinel``) is stepped once
    per loop iteration after its decode, recording any post-warmup jit
    recompile of the engine's entry points — the silent
    retrace-every-step failure mode (``launch.serve --analyze`` wires
    this up; strict sentinels raise at the offending step).

    ``prefill_chunk > 0`` (single-sample serving only) switches prompt
    admission onto *chunked prefill*: instead of one whole-prompt
    ``prefill_into`` that stalls every live decode slot, an arriving
    prompt is consumed ``prefill_chunk`` tokens at a time by the fused
    ``decode_prefill`` step — each iteration advances all live decode
    slots one token AND one mid-prefill slot by one chunk (falling back
    to a chunk-only step while no slot is actively decoding). Mid-prefill
    slots are flagged on the batcher (``mark_prefilling``) so no decode
    garbage lands in their ledger and ``t_first`` stamps on the first
    *generated* token. Ring (sliding-window) caches clamp the chunk to
    the cache length. Per-request streams stay bit-identical to the
    whole-prompt path (tests/test_serve_conformance.py).

    ``prefix_cache`` (a ``repro.serve.PrefixCache``) adds prefix KV
    reuse on top: at every chunk boundary the slot's cache rows are
    snapshotted under the prompt-prefix hash, and an arriving prompt
    whose prefix is cached splices the snapshot in (``splice_into``) and
    skips those chunks — a full-prompt hit skips prefill entirely.
    Implies chunked prefill (chunk defaults to ``prompt_len``). Hit /
    miss / eviction / tokens-skipped counters and a bytes gauge land in
    ``metrics``; capture/splice get tracer spans.

    ``arrivals`` (callable ``iteration -> bool``) injects open-loop
    request arrivals: called once per loop iteration (submitting to the
    batcher as it sees fit) and returning True while more requests may
    still arrive — the loop then idles through empty iterations instead
    of returning (serve_bench's staggered-arrival rows).
    """
    if temperature > 0.0 and key is None:
        raise ValueError("temperature-sampled serving requires a PRNG key")
    cap = max_new_cap
    if cap is None:
        pending = [r.max_new for r in batcher.queue]
        if not pending:
            return 0
        cap = max(pending)
    tr = engine.tracer
    step_h = queue_h = occ_h = None
    if metrics is not None:
        step_h = metrics.histogram("serve_step_seconds",
                                   "wall seconds per serving-loop step")
        queue_h = metrics.histogram("serve_queue_depth",
                                    "queued requests, sampled per step")
        occ_h = metrics.histogram("serve_slot_occupancy",
                                  "active-slot fraction, sampled per step")
    use_prefill_chunks = prefill_chunk > 0 or prefix_cache is not None
    if use_prefill_chunks and engine._replicas is not None:
        raise NotImplementedError(
            "chunked prefill / prefix reuse is single-sample only; drop "
            "prefill_chunk=/prefix_cache= for K-replica ensemble serving")
    chunk_len = prefill_chunk if prefill_chunk > 0 else batcher.prompt_len
    if use_prefill_chunks and engine.cfg.sliding_window:
        # ring caches need chunk <= cache length: chunk_attention's
        # post-attention ring write assigns each chunk token its own slot
        from repro.models.attention import cache_length
        chunk_len = min(chunk_len, cache_length(engine.cfg,
                                                batcher.prompt_len + cap))
    if prefix_cache is not None:
        # salt keys with the serving geometry (and this engine's identity):
        # snapshots from a different engine, context geometry or chunking
        # must never splice in — chunked and whole prefills agree only to
        # ulp order, so chunk size is part of the key
        prefix_cache.bind_geometry(
            f"{id(engine)}:{engine.cfg.family}:{engine.cfg.vocab_size}:"
            f"{batcher.prompt_len}:{cap}:{chunk_len}")
    pc_start = prefix_cache.stats() if prefix_cache is not None else None
    in_prefill: dict[int, int] = {}   # slot -> prompt tokens already in

    def _advance_prefill(state, slot, new_off):
        """Bookkeeping after a chunk landed: snapshot the chunk boundary
        into the prefix cache, and promote the slot to the active decode
        set once the whole prompt is in."""
        req = batcher.slots[slot]
        full = new_off >= batcher.prompt_len
        if prefix_cache is not None and (prefix_cache.store_partial or full):
            one, lg = engine.capture_slot(state, slot)
            prefix_cache.put(req.prompt[:new_off], one,
                             logits=lg if full else None)
        if full:
            batcher.mark_ready(slot)
            del in_prefill[slot]
        else:
            in_prefill[slot] = new_off

    t_start = time.perf_counter()
    steps = 0
    iterations = 0
    use_chunks = (decode_chunk > 1 and temperature == 0.0
                  and engine._replicas is None)
    with tr.span("stream_serve", n_slots=batcher.n_slots, cap=cap):
        with tr.span("init_decode"):
            state = engine.init_decode(batcher.n_slots, batcher.prompt_len,
                                       cap)
        try:
            while True:
                t_step = time.perf_counter()
                iterations += 1
                more_arrivals = (bool(arrivals(iterations))
                                 if arrivals is not None else False)
                with tr.span("step", step=steps):
                    with tr.span("refill"):
                        for slot in batcher.refill():
                            req = batcher.slots[slot]
                            if req.max_new > cap:
                                raise ValueError(
                                    f"request {req.uid} wants max_new="
                                    f"{req.max_new} but the decode state was "
                                    f"sized for max_new_cap={cap}")
                            if metrics is not None:
                                metrics.counter(
                                    "serve_prefills_total",
                                    "slot prefills (one per request "
                                    "admitted)").inc()
                            if not use_prefill_chunks:
                                state = engine.prefill_into(state, slot,
                                                            req.prompt)
                                continue
                            off = 0
                            if prefix_cache is not None:
                                hit = prefix_cache.lookup(req.prompt,
                                                          chunk_len)
                                if hit is not None:
                                    off, entry = hit
                                    full = off >= batcher.prompt_len
                                    state = engine.splice_into(
                                        state, slot, entry.cache,
                                        logits_row=entry.logits
                                        if full else None)
                            if off < batcher.prompt_len:
                                batcher.mark_prefilling(slot)
                                in_prefill[slot] = off
                    if metrics is not None:
                        queue_h.observe(len(batcher.queue))
                        occ_h.observe(
                            float(np.mean(batcher.active_mask())))
                    if batcher.idle:
                        if more_arrivals:
                            continue
                        return steps
                    if use_prefill_chunks and in_prefill:
                        # chunked-prefill scheduling: fuse one chunk of the
                        # oldest mid-prefill slot into the decode step when
                        # anything is decoding, else run the chunk alone
                        slot = next(iter(in_prefill))
                        off = in_prefill[slot]
                        req = batcher.slots[slot]
                        c = min(chunk_len, batcher.prompt_len - off)
                        chunk_toks = req.prompt[off:off + c]
                        if batcher.active_mask().any():
                            with tr.span("sample"):
                                if temperature > 0.0:
                                    key, sub = jax.random.split(key)
                                    tok = jax.random.categorical(
                                        sub,
                                        state.logits.astype(jnp.float32)
                                        / temperature, axis=-1)
                                else:
                                    tok = jnp.argmax(state.logits, axis=-1)
                                tok_host = np.asarray(tok)
                            with tr.span("record"):
                                batcher.record(tok_host)
                            steps += 1
                            if metrics is not None:
                                metrics.counter(
                                    "serve_steps_total",
                                    "token-emission steps").inc()
                            keep = np.array(
                                [i in batcher.prefilling
                                 for i in range(batcher.n_slots)])
                            state = engine.fused_step(state, tok, keep,
                                                      slot, chunk_toks, off)
                        else:
                            state = engine.prefill_chunk_into(
                                state, slot, chunk_toks, off)
                        _advance_prefill(state, slot, off + c)
                        if metrics is not None:
                            metrics.counter("serve_prefill_chunks_total",
                                            "prefill chunks executed").inc()
                        if sentinel is not None:
                            sentinel.step()
                        if step_h is not None:
                            step_h.observe(time.perf_counter() - t_step)
                        continue
                    if use_chunks:
                        d = min(decode_chunk, batcher.min_remaining())
                        with tr.span("chunk", d=d):
                            state, toks = engine.decode_steps(state, d)
                            # the chunk's ONE host crossing (explicit, so a
                            # jax.transfer_guard around the steady state
                            # stays silent — asserted in tests)
                            tok_chunk = jax.device_get(toks)
                        with tr.span("record"):
                            for i in range(d):
                                batcher.record(tok_chunk[:, i])
                        steps += d
                        if sentinel is not None:
                            sentinel.step()
                        if metrics is not None:
                            metrics.counter("serve_steps_total",
                                            "token-emission steps").inc(d)
                        if batcher.idle:
                            batcher.refill()
                        if step_h is not None:
                            step_h.observe(time.perf_counter() - t_step)
                        if batcher.idle and not more_arrivals:
                            return steps
                        continue
                    with tr.span("sample"):
                        if temperature > 0.0:
                            key, sub = jax.random.split(key)
                            tok = jax.random.categorical(
                                sub,
                                state.logits.astype(jnp.float32)
                                / temperature, axis=-1)
                        else:
                            tok = jnp.argmax(state.logits, axis=-1)
                        tok_host = np.asarray(tok)
                    with tr.span("record"):
                        if state.agreement is not None:
                            agr = np.asarray(state.agreement)
                            thr = engine.abstain_threshold
                            batcher.record(
                                tok_host, agreement=agr,
                                variance=np.asarray(state.variance),
                                abstained=None if thr is None
                                else agr < thr)
                        else:
                            batcher.record(tok_host)
                    steps += 1
                    if metrics is not None:
                        metrics.counter("serve_steps_total",
                                        "token-emission steps").inc()
                    if batcher.idle:
                        # flush the final completions; the trailing
                        # decode_step would be pure waste
                        batcher.refill()
                        if step_h is not None:
                            step_h.observe(time.perf_counter() - t_step)
                        if not more_arrivals:
                            return steps
                        continue
                    state = engine.decode_step(state, tok)
                    if sentinel is not None:
                        sentinel.step()
                if step_h is not None:
                    step_h.observe(time.perf_counter() - t_step)
        finally:
            if metrics is not None:
                from repro.obs.metrics import record_request_metrics

                record_request_metrics(metrics, batcher)
                if prefix_cache is not None:
                    pc = prefix_cache.stats()
                    metrics.counter(
                        "serve_prefix_hits_total",
                        "prefix-cache hits (prefill chunks skipped)").inc(
                        pc["hits"] - pc_start["hits"])
                    metrics.counter(
                        "serve_prefix_misses_total",
                        "prefix-cache misses (cold prefills)").inc(
                        pc["misses"] - pc_start["misses"])
                    metrics.counter(
                        "serve_prefix_evictions_total",
                        "prefix-cache LRU evictions").inc(
                        pc["evictions"] - pc_start["evictions"])
                    metrics.counter(
                        "serve_prefix_tokens_skipped_total",
                        "prompt tokens served from cached prefixes").inc(
                        pc["tokens_skipped"] - pc_start["tokens_skipped"])
                    metrics.gauge(
                        "serve_prefix_bytes",
                        "prefix-cache resident bytes").set(pc["bytes"])
                dt = time.perf_counter() - t_start
                if dt > 0:
                    metrics.gauge(
                        "serve_tok_per_s",
                        "recorded tokens / serving wall seconds").set(
                        batcher.tokens_generated / dt)
