"""Small shared helpers spanning jax API renames + backend dispatch.

Kept in one place so the next jax rename is a one-file fix (both kernel
packages — ``repro.kernels`` and ``repro.xnor`` — import from here).
"""
from __future__ import annotations

import jax
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams around 0.5; support both.
CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def ceil_to(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m
