"""Post-SPMD HLO cost analyzer with while-loop trip-count attribution.

Why this exists: XLA's ``compiled.cost_analysis()`` on the CPU backend counts
the body of a ``while`` op (what ``lax.scan`` lowers to) exactly once, so a
64-layer scanned transformer reports ~1/64th of its real FLOPs. This module
parses ``compiled.as_text()`` (the optimized, SPMD-partitioned, per-device
HLO), builds the computation call graph (fusion / call / while / conditional),
extracts while trip counts from the loop condition's comparison constant, and
accumulates per-device:

  * ``flops``            — dot + convolution FLOPs (2 * M * N * K semantics),
  * ``bytes``            — memory traffic at fusion boundaries (operands +
                           outputs of top-level-materialized ops; ops *inside*
                           a fusion are free, which is exactly the fusion
                           memory model),
  * ``collective_bytes`` — operand bytes of all-reduce / all-gather /
                           reduce-scatter / all-to-all / collective-permute,
                           also broken out per collective kind,

each multiplied by the product of enclosing loop trip counts.

The parser is deliberately tolerant: HLO it does not understand contributes
zero rather than raising, and every parse is cross-checkable against
``cost_analysis()`` on unscanned programs (see tests).
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict
from typing import Dict, Iterator, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s2": 1, "s4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16, "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)


def shape_bytes(shape_str: str) -> int:
    """Total bytes of an HLO shape string, incl. tuples: 'f32[8,16]{1,0}'."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _shape_dims(shape_str: str) -> Tuple[str, List[int]]:
    m = _SHAPE_RE.search(shape_str)
    if not m:
        return "", []
    dtype, dims = m.groups()
    return dtype, [int(d) for d in dims.split(",") if d]


@dataclasses.dataclass
class HloOp:
    name: str
    opcode: str
    shape: str            # result shape string
    operands: List[str]   # operand instruction names (same computation)
    raw: str              # full line
    called: List[str]     # called computation names


@dataclasses.dataclass
class HloComputation:
    name: str
    ops: Dict[str, HloOp]
    order: List[str]


_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*.*\{\s*$")
_INSTR = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*?)\s*([\w\-]+)\((.*)$"
)
_CALLED = re.compile(
    r"(?:calls|to_apply|body|condition|branch_computations)=\{?%?([\w.\-]+(?:,\s*%?[\w.\-]+)*)\}?"
)
_OPERAND_NAME = re.compile(r"%([\w.\-]+)")


def parse_hlo(text: str) -> Dict[str, HloComputation]:
    """Parses optimized HLO text into computations."""
    comps: Dict[str, HloComputation] = {}
    cur: Optional[HloComputation] = None
    for line in text.splitlines():
        stripped = line.rstrip()
        hdr = _COMP_HDR.match(stripped.strip())
        if hdr and ("->" in stripped):
            cur = HloComputation(hdr.group(1), {}, [])
            comps[cur.name] = cur
            continue
        if cur is None:
            continue
        if stripped.strip() == "}":
            cur = None
            continue
        m = _INSTR.match(stripped)
        if not m:
            continue
        name, shape, opcode, rest = m.groups()
        # operand names: only those before any metadata/attribute section
        args_part = rest.split("), ")[0] if "), " in rest else rest
        operands = _OPERAND_NAME.findall(args_part)
        called: List[str] = []
        for cm in _CALLED.finditer(rest):
            for c in cm.group(1).split(","):
                called.append(c.strip().lstrip("%"))
        cur.ops[name] = HloOp(name, opcode, shape, operands, stripped, called)
        cur.order.append(name)
    return comps


# ---------------------------------------------------------------------------
# trip counts
# ---------------------------------------------------------------------------

_CONST_INT = re.compile(r"=\s*s(?:8|16|32|64)\[\]\s*constant\((\d+)\)")
_CMP_DIR = re.compile(r"direction=(\w+)")


def while_trip_count(cond: HloComputation) -> Optional[int]:
    """Extracts the trip count from a canonical while-condition computation.

    Matches the XLA canonical form: induction variable starting at 0,
    incremented by 1, compared (LT/LE/GT/GE) against a constant N.
    Returns None when the form is not recognized (caller treats as 1)."""
    const = None
    direction = None
    for op in cond.ops.values():
        m = _CONST_INT.search(op.raw)
        if op.opcode == "constant" and m:
            const = int(m.group(1))
        if op.opcode == "compare":
            d = _CMP_DIR.search(op.raw)
            if d:
                direction = d.group(1)
    if const is None:
        return None
    if direction in ("LT", "GT"):
        return const
    if direction in ("LE", "GE"):
        return const + 1
    return const


# ---------------------------------------------------------------------------
# FLOPs of dot / convolution
# ---------------------------------------------------------------------------

_DNUMS = re.compile(
    r"lhs_contracting_dims=\{([0-9,]*)\}.*?rhs_contracting_dims=\{([0-9,]*)\}")
_LHS_BATCH = re.compile(r"lhs_batch_dims=\{([0-9,]*)\}")
_OPERAND_SHAPES = re.compile(r"([a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)\s*%")


def _dot_flops(op: HloOp, comp: HloComputation) -> int:
    """2 * batch * M * N * K for a dot; needs lhs shape + contracting dims."""
    # Result shape gives batch*M*N; contracted size from lhs operand shape.
    _, out_dims = _shape_dims(op.shape)
    out_elems = 1
    for d in out_dims:
        out_elems *= d
    m = _DNUMS.search(op.raw)
    lhs_name = op.operands[0] if op.operands else None
    lhs_op = comp.ops.get(lhs_name) if lhs_name else None
    if m and lhs_op is not None:
        _, lhs_dims = _shape_dims(lhs_op.shape)
        k = 1
        cdims = [int(x) for x in m.group(1).split(",") if x]
        for c in cdims:
            if c < len(lhs_dims):
                k *= lhs_dims[c]
        return 2 * out_elems * k
    # Fallback: operand shapes inline in the raw line.
    shapes = _OPERAND_SHAPES.findall(op.raw)
    if m and shapes:
        _, lhs_dims = _shape_dims(shapes[0])
        k = 1
        for c in (int(x) for x in m.group(1).split(",") if x):
            if c < len(lhs_dims):
                k *= lhs_dims[c]
        return 2 * out_elems * k
    return 0


_CONV_WINDOW = re.compile(r"window=\{size=([0-9x]+)")


def _conv_flops(op: HloOp, comp: HloComputation) -> int:
    _, out_dims = _shape_dims(op.shape)
    out_elems = 1
    for d in out_dims:
        out_elems *= d
    # kernel operand: second operand
    k_elems = 1
    if len(op.operands) >= 2:
        k_op = comp.ops.get(op.operands[1])
        if k_op is not None:
            _, kd = _shape_dims(k_op.shape)
            # flops = 2 * out_elems * (kernel spatial * in_channels)
            if len(kd) >= 2:
                k_elems = 1
                for d in kd[:-1]:  # all but output-feature dim (layout-dependent,
                    k_elems *= d   # conservative)
    return 2 * out_elems * k_elems


# ---------------------------------------------------------------------------
# main accumulation
# ---------------------------------------------------------------------------

_MATERIALIZING = {
    "fusion", "dot", "convolution", "custom-call", "all-reduce", "all-gather",
    "reduce-scatter", "all-to-all", "collective-permute", "dynamic-slice",
    "dynamic-update-slice", "reduce", "sort", "broadcast", "iota", "copy",
    "transpose", "reshape", "concatenate", "slice", "pad", "gather", "scatter",
    "select-and-scatter", "reduce-window", "convert", "rng-bit-generator",
    "cholesky", "triangular-solve", "exponential",
}

_CHEAP = {"parameter", "constant", "tuple", "get-tuple-element", "bitcast",
          "after-all", "partition-id", "replica-id"}


@dataclasses.dataclass
class HloCost:
    flops: float = 0.0
    bytes: float = 0.0
    collective_bytes: float = 0.0
    collective_bytes_by_kind: Dict[str, float] = dataclasses.field(
        default_factory=lambda: defaultdict(float))
    collective_count: Dict[str, int] = dataclasses.field(
        default_factory=lambda: defaultdict(int))
    dot_flops_by_shape: Dict[str, float] = dataclasses.field(
        default_factory=lambda: defaultdict(float))
    # Top-level (fusion-boundary) copy ops: under SPMD these are where
    # resharding materializes when no collective is needed (e.g. a layout
    # change at the packed/dense boundary). Trip-count weighted like the
    # collectives, so a copy inside a decode scan counts once per step.
    copy_count: int = 0
    copy_bytes: float = 0.0
    unparsed_while: int = 0

    def as_dict(self) -> dict:
        return {
            "flops": self.flops,
            "bytes": self.bytes,
            "collective_bytes": self.collective_bytes,
            "collective_bytes_by_kind": dict(self.collective_bytes_by_kind),
            "collective_count": dict(self.collective_count),
            "copy_count": self.copy_count,
            "copy_bytes": self.copy_bytes,
            "unparsed_while": self.unparsed_while,
        }


def _operand_bytes(op: HloOp, comp: HloComputation) -> int:
    total = 0
    for name in op.operands:
        o = comp.ops.get(name)
        if o is not None:
            total += shape_bytes(o.shape)
    return total


def _entry_name(comps: Dict[str, HloComputation]) -> str:
    """ENTRY computation: the one never called by others."""
    called = set()
    for c in comps.values():
        for op in c.ops.values():
            called.update(op.called)
    roots = [n for n in comps if n not in called]
    return roots[-1] if roots else next(iter(comps))


def analyze(text: str, entry: Optional[str] = None) -> HloCost:
    comps = parse_hlo(text)
    if not comps:
        return HloCost()
    if entry is None:
        entry = _entry_name(comps)
    cost = HloCost()
    _walk(comps, comps[entry], 1.0, cost, depth=0, in_fusion=False)
    return cost


def _walk(comps: Dict[str, HloComputation], comp: HloComputation,
          mult: float, cost: HloCost, depth: int, in_fusion: bool) -> None:
    if depth > 40:  # pathological recursion guard
        return
    for name in comp.order:
        op = comp.ops[name]
        oc = op.opcode

        if oc == "while":
            body = cond = None
            m_body = re.search(r"body=%?([\w.\-]+)", op.raw)
            m_cond = re.search(r"condition=%?([\w.\-]+)", op.raw)
            if m_body:
                body = comps.get(m_body.group(1))
            if m_cond:
                cond = comps.get(m_cond.group(1))
            trips = while_trip_count(cond) if cond is not None else None
            if trips is None:
                trips = 1
                cost.unparsed_while += 1
            if body is not None:
                _walk(comps, body, mult * trips, cost, depth + 1, in_fusion)
            continue

        if oc == "conditional":
            # count the most expensive branch once (upper bound among branches
            # would double count; pick max via sub-walk into each)
            for c in op.called:
                sub = comps.get(c)
                if sub is not None:
                    _walk(comps, sub, mult, cost, depth + 1, in_fusion)
            continue

        if oc == "fusion":
            # FLOPs: walk inside; bytes: fusion boundary only.
            for c in op.called:
                sub = comps.get(c)
                if sub is not None:
                    _walk(comps, sub, mult, cost, depth + 1, in_fusion=True)
            if not in_fusion:
                cost.bytes += mult * (shape_bytes(op.shape) + _operand_bytes(op, comp))
            continue

        if oc in ("call", "async-start", "async-done", "custom-call"):
            for c in op.called:
                sub = comps.get(c)
                if sub is not None:
                    _walk(comps, sub, mult, cost, depth + 1, in_fusion)

        if oc == "dot":
            f = _dot_flops(op, comp)
            cost.flops += mult * f
            cost.dot_flops_by_shape[op.shape] += mult * f
        elif oc == "convolution":
            cost.flops += mult * _conv_flops(op, comp)

        for kind in _COLLECTIVES:
            if oc == kind or oc.startswith(kind + "-"):
                b = _operand_bytes(op, comp)
                if b == 0:
                    b = shape_bytes(op.shape)
                cost.collective_bytes += mult * b
                cost.collective_bytes_by_kind[kind] += mult * b
                cost.collective_count[kind] += int(mult)
                break

        if not in_fusion and oc == "copy":
            cost.copy_count += int(mult)
            cost.copy_bytes += mult * shape_bytes(op.shape)

        if not in_fusion and oc in _MATERIALIZING and oc != "fusion":
            # Sliced reads/writes touch only the slice, not the full operand.
            if oc in ("dynamic-slice", "slice", "gather"):
                cost.bytes += mult * 2 * shape_bytes(op.shape)
            elif oc in ("dynamic-update-slice", "scatter"):
                upd = comp.ops.get(op.operands[1]) if len(op.operands) > 1 else None
                upd_b = shape_bytes(upd.shape) if upd is not None else shape_bytes(op.shape)
                cost.bytes += mult * 2 * upd_b
            else:
                cost.bytes += mult * (shape_bytes(op.shape) + _operand_bytes(op, comp))


# ---------------------------------------------------------------------------
# trip-weighted op iteration + module-header facts (used by repro.analysis)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class OpVisit:
    """One op reached from the entry computation, with the product of
    enclosing while trip counts (``mult``) — the same attribution
    :func:`analyze` uses, exposed as a walk instead of a sum."""
    op: HloOp
    mult: float
    computation: str
    in_fusion: bool


def iter_ops(text: str, entry: Optional[str] = None) -> Iterator[OpVisit]:
    """Yields every op reachable from ``entry`` (default: the ENTRY
    computation), trip-count weighted, descending into while bodies,
    conditional branches, calls, and fusions (``in_fusion=True`` inside)."""
    comps = parse_hlo(text)
    if not comps:
        return
    if entry is None:
        entry = _entry_name(comps)
    yield from _iter_comp(comps, comps[entry], 1.0, 0, False)


def _iter_comp(comps: Dict[str, HloComputation], comp: HloComputation,
               mult: float, depth: int, in_fusion: bool) -> Iterator[OpVisit]:
    if depth > 40:  # pathological recursion guard (mirrors _walk)
        return
    for name in comp.order:
        op = comp.ops[name]
        yield OpVisit(op, mult, comp.name, in_fusion)
        oc = op.opcode
        if oc == "while":
            m_body = re.search(r"body=%?([\w.\-]+)", op.raw)
            m_cond = re.search(r"condition=%?([\w.\-]+)", op.raw)
            body = comps.get(m_body.group(1)) if m_body else None
            cond = comps.get(m_cond.group(1)) if m_cond else None
            trips = while_trip_count(cond) if cond is not None else None
            if body is not None:
                yield from _iter_comp(comps, body, mult * (trips or 1),
                                      depth + 1, in_fusion)
        elif oc == "fusion":
            for c in op.called:
                sub = comps.get(c)
                if sub is not None:
                    yield from _iter_comp(comps, sub, mult, depth + 1, True)
        elif oc in ("conditional", "call", "async-start", "async-done",
                    "custom-call"):
            for c in op.called:
                sub = comps.get(c)
                if sub is not None:
                    yield from _iter_comp(comps, sub, mult, depth + 1,
                                          in_fusion)


_ALIAS_ENTRY = re.compile(
    r"\{([0-9,\s]*)\}:\s*\((\d+),\s*\{[0-9,\s]*\},\s*([\w\-]+)\)")


def input_output_aliases(text: str) -> List[Tuple[Tuple[int, ...], int, str]]:
    """Donation facts from the ``HloModule`` header's
    ``input_output_alias={ {1}: (13, {}, may-alias), ... }`` attribute:
    a list of (output tuple index, parameter number, alias kind). Empty
    when the module declares no aliasing — i.e. nothing was donated."""
    start = text.find("input_output_alias={")
    if start < 0:
        return []
    i = start + len("input_output_alias={")
    depth = 1
    while i < len(text) and depth:
        if text[i] == "{":
            depth += 1
        elif text[i] == "}":
            depth -= 1
        i += 1
    block = text[start:i]
    out = []
    for m in _ALIAS_ENTRY.finditer(block):
        idx = tuple(int(x) for x in m.group(1).replace(" ", "").split(",")
                    if x)
        out.append((idx, int(m.group(2)), m.group(3)))
    return out


_OP_NAME = re.compile(r'op_name="([^"]*)"')


def op_metadata_name(op: HloOp) -> str:
    """The ``metadata={op_name="..."}`` source attribution of one op
    (empty string when absent) — the jaxpr path XLA recorded, e.g.
    ``jit(_decode_fn)/while/body/jit(_xnor_matmul_packed)/reduce_sum``."""
    m = _OP_NAME.search(op.raw)
    return m.group(1) if m else ""


def collective_summary(text: str) -> Dict[str, Tuple[int, float]]:
    """kind -> (count, bytes), trip-count weighted."""
    cost = analyze(text)
    return {
        k: (cost.collective_count[k], cost.collective_bytes_by_kind[k])
        for k in cost.collective_bytes_by_kind
    }
