"""Three-term roofline model for TPU v5e from compiled-HLO analysis.

    compute    = FLOPs            / (chips * PEAK_FLOPS)
    memory     = bytes            / (chips * HBM_BW)
    collective = collective_bytes / (chips * ICI_BW)

FLOPs / bytes / collective_bytes come from ``core.hlo_analysis.analyze`` run
on the per-device SPMD-partitioned module, so they are *already* per-chip:
the `/chips` division is therefore applied only to analytically-derived
whole-model quantities (MODEL_FLOPS), and the HLO-derived terms use the
per-device numbers directly. Both conventions are kept explicit below.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

# TPU v5e hardware constants (per chip).
PEAK_FLOPS_BF16 = 197e12        # FLOP/s
HBM_BW = 819e9                  # B/s
ICI_BW = 50e9                   # B/s per link (per the assignment)

# Energy proxy constants for the paper's power comparison (Table I analogue).
# Order-of-magnitude figures for a 5nm-class accelerator: ~0.6 pJ/bf16-FLOP at
# the MXU, ~6 pJ/HBM byte, ~3 pJ/ICI byte. Used ONLY for the derived-energy
# column, clearly labeled as a model, never as a measurement.
PJ_PER_FLOP = 0.6e-12
PJ_PER_HBM_BYTE = 6e-12
PJ_PER_ICI_BYTE = 3e-12


@dataclasses.dataclass
class RooflineTerms:
    """Per-step roofline terms, all in seconds (per device)."""

    compute_s: float
    memory_s: float
    collective_s: float
    flops_per_device: float
    bytes_per_device: float
    collective_bytes_per_device: float
    chips: int
    model_flops: Optional[float] = None    # analytic 6ND / 2ND, whole model
    hbm_bytes_per_device: Optional[float] = None  # from memory_analysis

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def bound_time_s(self) -> float:
        """Lower-bound step time: max of the three terms (perfect overlap)."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def no_overlap_time_s(self) -> float:
        return self.compute_s + self.memory_s + self.collective_s

    @property
    def useful_flops_fraction(self) -> Optional[float]:
        """MODEL_FLOPS / total HLO FLOPs — catches remat/redundancy waste."""
        if self.model_flops is None or self.flops_per_device <= 0:
            return None
        return self.model_flops / (self.flops_per_device * self.chips)

    @property
    def mfu_bound(self) -> Optional[float]:
        """Model-FLOPs utilization upper bound implied by the roofline."""
        if self.model_flops is None or self.bound_time_s <= 0:
            return None
        ideal = self.model_flops / (self.chips * PEAK_FLOPS_BF16)
        return ideal / self.bound_time_s

    def energy_joules(self) -> float:
        """Derived energy proxy per step per device (labeled model, not
        measurement) — the Table-I power analogue."""
        return (self.flops_per_device * PJ_PER_FLOP
                + self.bytes_per_device * PJ_PER_HBM_BYTE
                + self.collective_bytes_per_device * PJ_PER_ICI_BYTE)

    def as_dict(self) -> dict:
        return {
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "bound_time_s": self.bound_time_s,
            "flops_per_device": self.flops_per_device,
            "bytes_per_device": self.bytes_per_device,
            "collective_bytes_per_device": self.collective_bytes_per_device,
            "chips": self.chips,
            "model_flops": self.model_flops,
            "useful_flops_fraction": self.useful_flops_fraction,
            "mfu_bound": self.mfu_bound,
            "hbm_bytes_per_device": self.hbm_bytes_per_device,
            "energy_joules_per_device": self.energy_joules(),
        }


def from_hlo_cost(
    cost,
    chips: int,
    model_flops: Optional[float] = None,
    hbm_bytes_per_device: Optional[float] = None,
) -> RooflineTerms:
    """Builds terms from a ``hlo_analysis.HloCost`` of the per-device module."""
    return RooflineTerms(
        compute_s=cost.flops / PEAK_FLOPS_BF16,
        memory_s=cost.bytes / HBM_BW,
        collective_s=cost.collective_bytes / ICI_BW,
        flops_per_device=cost.flops,
        bytes_per_device=cost.bytes,
        collective_bytes_per_device=cost.collective_bytes,
        chips=chips,
        model_flops=model_flops,
        hbm_bytes_per_device=hbm_bytes_per_device,
    )


def model_flops_train(n_params_active: float, n_tokens: float) -> float:
    """6 * N * D (fwd 2ND + bwd 4ND) per step."""
    return 6.0 * n_params_active * n_tokens


def model_flops_infer(n_params_active: float, n_tokens: float) -> float:
    """2 * N * D per forward."""
    return 2.0 * n_params_active * n_tokens
