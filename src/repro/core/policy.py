"""Binarization policy: which parameters Alg. (1) binarizes.

Follows the BinaryConnect / BNN-literature convention the paper inherits:
projection ("matmul-shaped") weights are binarized; embeddings, norms,
biases, MoE routers, SSM state-dynamics parameters and (optionally) the LM
head stay full precision. The policy is path-pattern based so configs can
override it per architecture.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Sequence


# Leaf-name suffixes that are *always* excluded (non-matmul params).
_DEFAULT_EXCLUDE = (
    r".*(^|/)(embed|embedding|pos_embed|frontend)(/|$).*",
    r".*(scale|gamma|beta|bias)$",
    r".*(^|/)(ln|norm|rmsnorm|batchnorm|bn)[^/]*(/|$).*",
    r".*(^|/)router(/|$).*",
    # SSM dynamics + depthwise-conv *leaves*. 2-D conv stacks
    # (`conv/<i>/kernel`, matmul-shaped after im2col) are NOT excluded:
    # Alg. 1 binarizes them like any projection, and under mode="xnor" they
    # lower through `repro.xnor.conv`.
    r".*(^|/)(A_log|dt_bias|D|conv)$",
    r".*(^|/)lm_head(/|$).*",
)

# What is binarized: 2-D+ projection kernels.
_DEFAULT_INCLUDE = (
    r".*(kernel|w_qkv|w_o|w_q|w_k|w_v|wi|wo|w_gate|w_up|w_down|in_proj|out_proj|x_proj)$",
)


@dataclasses.dataclass(frozen=True)
class BinarizePolicy:
    """Selects parameter-tree paths for binarization.

    A path is selected iff it matches any ``include`` pattern and no
    ``exclude`` pattern. Paths are '/'-joined key paths, e.g.
    ``layers/attn/w_qkv``.
    """

    include: Sequence[str] = _DEFAULT_INCLUDE
    exclude: Sequence[str] = _DEFAULT_EXCLUDE

    def __post_init__(self):
        object.__setattr__(self, "_inc", tuple(re.compile(p) for p in self.include))
        object.__setattr__(self, "_exc", tuple(re.compile(p) for p in self.exclude))

    def selects(self, path: str) -> bool:
        if not any(p.fullmatch(path) for p in self._inc):
            return False
        return not any(p.fullmatch(path) for p in self._exc)

    def excluded_by(self, path: str) -> str | None:
        """The first exclude pattern blocking an otherwise-included path
        (None if the path is selected or matches no include pattern). Used
        by the execution-plan compiler to record *why* a layer was kept off
        a binary backend."""
        if not any(p.fullmatch(path) for p in self._inc):
            return None
        for p in self._exc:
            if p.fullmatch(path):
                return p.pattern
        return None

    def selected_paths(self, params) -> list[str]:
        import jax

        out = []
        for path, _ in jax.tree_util.tree_leaves_with_path(params):
            from repro.core.binarize import _path_str

            s = _path_str(path)
            if self.selects(s):
                out.append(s)
        return out


#: Paper-faithful default policy.
DEFAULT_POLICY = BinarizePolicy()

#: Binarize nothing (the paper's "No Regularizer" baseline).
NONE_POLICY = BinarizePolicy(include=())


# ---------------------------------------------------------------------------
# XNOR (fully-binary) activation eligibility
# ---------------------------------------------------------------------------

# Layers whose *inputs* are real-valued stay on the packed-weight (or dense)
# path. This guard covers the paper's FC/VGG stacks, where index 0 of
# `layers/` (FC nets) or `fc/` (the VGG classifier head) consumes raw pixels
# / conv features, and VGG's first conv block (`conv/0..1`), which sits
# closest to the raw pixels — blocks 2-5 lower to `repro.xnor.conv`. This
# is an *activation* boundary only: the training weight policy
# (launch.train.make_paper_policy) still binarizes conv/1's weights, and
# pack_params serves them binarized-dense. Transformer paths are untouched
# by it: their stacked
# scan leaves (`layers/attn/w_qkv`, ...) carry no per-layer index, so under
# mode="xnor" *every* selected projection binarizes its activations — the
# transformer's real-valued front (embedding, lm_head) is already kept
# dense by the weight policy.
_XNOR_EXTRA_EXCLUDE = (
    r"(^|.*/)(layers|fc)/0/[^/]+$",
    r"(^|.*/)conv/[01]/kernel$",
)

#: Which weight-binarized leaves may *also* binarize their activations and
#: dispatch to the XNOR-popcount engine (``repro.xnor``). A leaf must be
#: selected by both the weight policy and this one to become an XnorLinear.
XNOR_POLICY = BinarizePolicy(exclude=_DEFAULT_EXCLUDE + _XNOR_EXTRA_EXCLUDE)


def xnor_policy(extra_exclude: Sequence[str] = ()) -> BinarizePolicy:
    """XNOR eligibility with model-specific real-valued-input layers added."""
    return BinarizePolicy(
        exclude=_DEFAULT_EXCLUDE + _XNOR_EXTRA_EXCLUDE + tuple(extra_exclude))


_XNOR_BOUNDARY_RES = tuple(re.compile(p) for p in _XNOR_EXTRA_EXCLUDE)


def is_xnor_boundary(path: str) -> bool:
    """True iff ``path`` is excluded from binary activations *because its
    input is real-valued* (the Alg.-1 first-layer / first-conv-block
    boundary patterns), as opposed to a generic policy exclusion. The plan
    compiler uses this to phrase the per-layer reason."""
    return any(p.fullmatch(path) for p in _XNOR_BOUNDARY_RES)


#: 2-D conv-stack kernels (VGG-style `conv/<i>/kernel` paths). These are
#: 4-D (kh, kw, C, N) leaves: under mode="xnor" they pack into XnorConv
#: (im2col popcount conv); other packing modes leave them dense, since the
#: packed-weight MXU path has no conv lowering.
_CONV_KERNEL_RE = re.compile(r"(^|.*/)conv/\d+/kernel$")


def is_conv_kernel(path: str) -> bool:
    return bool(_CONV_KERNEL_RE.fullmatch(path))
