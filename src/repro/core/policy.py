"""Binarization policy: which parameters Alg. (1) binarizes.

Follows the BinaryConnect / BNN-literature convention the paper inherits:
projection ("matmul-shaped") weights are binarized; embeddings, norms,
biases, MoE routers, SSM state-dynamics parameters and (optionally) the LM
head stay full precision. The policy is path-pattern based so configs can
override it per architecture.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Sequence


# Leaf-name suffixes that are *always* excluded (non-matmul params).
_DEFAULT_EXCLUDE = (
    r".*(^|/)(embed|embedding|pos_embed|frontend)(/|$).*",
    r".*(scale|gamma|beta|bias)$",
    r".*(^|/)(ln|norm|rmsnorm|batchnorm|bn)[^/]*(/|$).*",
    r".*(^|/)router(/|$).*",
    r".*(^|/)(A_log|dt_bias|D|conv)(/|$).*",   # SSM dynamics + depthwise conv
    r".*(^|/)lm_head(/|$).*",
)

# What is binarized: 2-D+ projection kernels.
_DEFAULT_INCLUDE = (
    r".*(kernel|w_qkv|w_o|w_q|w_k|w_v|wi|wo|w_gate|w_up|w_down|in_proj|out_proj|x_proj)$",
)


@dataclasses.dataclass(frozen=True)
class BinarizePolicy:
    """Selects parameter-tree paths for binarization.

    A path is selected iff it matches any ``include`` pattern and no
    ``exclude`` pattern. Paths are '/'-joined key paths, e.g.
    ``layers/attn/w_qkv``.
    """

    include: Sequence[str] = _DEFAULT_INCLUDE
    exclude: Sequence[str] = _DEFAULT_EXCLUDE

    def __post_init__(self):
        object.__setattr__(self, "_inc", tuple(re.compile(p) for p in self.include))
        object.__setattr__(self, "_exc", tuple(re.compile(p) for p in self.exclude))

    def selects(self, path: str) -> bool:
        if not any(p.fullmatch(path) for p in self._inc):
            return False
        return not any(p.fullmatch(path) for p in self._exc)

    def selected_paths(self, params) -> list[str]:
        import jax

        out = []
        for path, _ in jax.tree_util.tree_leaves_with_path(params):
            from repro.core.binarize import _path_str

            s = _path_str(path)
            if self.selects(s):
                out.append(s)
        return out


#: Paper-faithful default policy.
DEFAULT_POLICY = BinarizePolicy()

#: Binarize nothing (the paper's "No Regularizer" baseline).
NONE_POLICY = BinarizePolicy(include=())
