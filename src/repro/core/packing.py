"""Bitpacking of binary {-1,+1} tensors into int32 lanes.

This is the TPU-native analogue of the paper's DSP-free weight storage: a
binarized weight matrix is stored as one *bit* per weight (sign bit, +1 -> 1,
-1 -> 0), packed 32 weights per int32 word along the leading (contraction)
axis. HBM traffic for weight fetch drops 16x vs bf16 / 32x vs f32; the Pallas
``binary_matmul`` kernel unpacks blocks inside VMEM.

Layout convention: for a weight of shape (K, N), the packed form has shape
(K // 32, N) int32, where bit ``b`` of word ``[k32, n]`` holds the sign of
``w[k32 * 32 + b, n]``. K must be a multiple of 32 (all framework layer dims
are multiples of 128, so this always holds; ``pad_to_pack`` is provided for
odd user shapes).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

PACK = 32


def pad_to_pack(w: jax.Array, axis: int = 0) -> jax.Array:
    """Pads ``axis`` up to a multiple of 32 with -1 entries (bit 0)."""
    k = w.shape[axis]
    rem = (-k) % PACK
    if rem == 0:
        return w
    pad = [(0, 0)] * w.ndim
    pad[axis] = (0, rem)
    return jnp.pad(w, pad, constant_values=-1.0)


def pack_bits(w_pm1: jax.Array) -> jax.Array:
    """Packs a {-1,+1} tensor of shape (K, ...) into (K//32, ...) int32.

    Sign convention: +1 -> bit 1, -1/0 -> bit 0 (matches Eq. (1)).
    """
    k = w_pm1.shape[0]
    if k % PACK != 0:
        raise ValueError(f"leading dim {k} not a multiple of {PACK}; use pad_to_pack")
    bits = (w_pm1 > 0).astype(jnp.uint32)
    bits = bits.reshape((k // PACK, PACK) + w_pm1.shape[1:])
    shifts = jnp.arange(PACK, dtype=jnp.uint32).reshape((1, PACK) + (1,) * (w_pm1.ndim - 1))
    words = jnp.sum(bits << shifts, axis=1, dtype=jnp.uint32)
    return words.astype(jnp.int32)


def unpack_bits(words: jax.Array, dtype=jnp.float32) -> jax.Array:
    """Inverse of :func:`pack_bits`: (K//32, ...) int32 -> (K, ...) ±1."""
    w = words.astype(jnp.uint32)
    shifts = jnp.arange(PACK, dtype=jnp.uint32).reshape((1, PACK) + (1,) * (w.ndim - 1))
    bits = (w[:, None] >> shifts) & jnp.uint32(1)
    pm1 = jnp.where(bits == 1, 1.0, -1.0).astype(dtype)
    return pm1.reshape((w.shape[0] * PACK,) + w.shape[1:])


def packed_nbytes(shape: tuple[int, ...]) -> int:
    """Bytes of the packed representation of a (K, N, ...) weight."""
    k = shape[0]
    rest = int(np.prod(shape[1:])) if len(shape) > 1 else 1
    return ((k + PACK - 1) // PACK) * rest * 4


def compression_ratio(shape: tuple[int, ...], dtype_bytes: int = 2) -> float:
    """Weight-bytes compression vs a ``dtype_bytes``-wide dense tensor."""
    dense = int(np.prod(shape)) * dtype_bytes
    return dense / packed_nbytes(shape)
