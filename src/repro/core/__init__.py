"""Core: the paper's technique (binary weight regularization) + analysis tools."""
from repro.core.binarize import (
    BinarizeMode,
    binarize_tree,
    clip_tree,
    clip_weights,
    deterministic_binarize,
    hard_sigmoid,
    stochastic_binarize,
)
from repro.core.policy import DEFAULT_POLICY, NONE_POLICY, BinarizePolicy

__all__ = [
    "BinarizeMode", "binarize_tree", "clip_tree", "clip_weights",
    "deterministic_binarize", "hard_sigmoid", "stochastic_binarize",
    "BinarizePolicy", "DEFAULT_POLICY", "NONE_POLICY",
]
