"""Binary weight regularization (the paper's core technique).

Implements, as composable JAX transforms:

* Eq. (1)  deterministic binarization   w_b = sign(w)  (with sign(0) = -1,
  matching the paper's ``w <= 0 -> -1`` convention),
* Eq. (2)  stochastic binarization      P(w_b = +1) = sigma(w),
* Eq. (3)  hard sigmoid                 sigma(x) = clip((x+1)/2, 0, 1),
* Alg. (1) the BinaryConnect training algorithm: real-valued *master* weights
  are binarized on every forward/backward pass, gradients flow through the
  binarization via a straight-through estimator (STE), master weights are
  clipped to [-1, +1] after each update.

All functions are pure and jit/vmap/pjit friendly; the stochastic path is
keyed explicitly (deterministic given a key) so training steps stay
reproducible and resumable.
"""
from __future__ import annotations

import enum
from typing import Any

import jax
import jax.numpy as jnp


class BinarizeMode(enum.Enum):
    """Which regularizer Alg. 1's ``binarize()`` uses."""

    NONE = "none"
    DETERMINISTIC = "det"
    STOCHASTIC = "stoch"

    @classmethod
    def parse(cls, value: "BinarizeMode | str | None") -> "BinarizeMode":
        if value is None:
            return cls.NONE
        if isinstance(value, cls):
            return value
        for m in cls:
            if value in (m.value, m.name, m.name.lower()):
                return m
        raise ValueError(f"unknown binarize mode: {value!r}")


def hard_sigmoid(x: jax.Array) -> jax.Array:
    """Eq. (3): sigma(x) = clip((x+1)/2, 0, 1)."""
    return jnp.clip((x + 1.0) / 2.0, 0.0, 1.0)


def clip_weights(w: jax.Array, lo: float = -1.0, hi: float = 1.0) -> jax.Array:
    """Alg. (1) step 4: w <- clip(w). Keeps master weights inside the region
    where the stochastic projection (Eq. 2) has non-degenerate probability."""
    return jnp.clip(w, lo, hi)


def deterministic_binarize(w: jax.Array) -> jax.Array:
    """Eq. (1): w_b = -1 if w <= 0 else +1, in w's dtype."""
    return jnp.where(w > 0, 1.0, -1.0).astype(w.dtype)


def stochastic_binarize(w: jax.Array, key: jax.Array) -> jax.Array:
    """Eq. (2): w_b = +1 with probability hard_sigmoid(w), else -1."""
    p = hard_sigmoid(w.astype(jnp.float32))
    u = jax.random.uniform(key, w.shape, jnp.float32)
    return jnp.where(u < p, 1.0, -1.0).astype(w.dtype)


@jax.custom_vjp
def _ste_identity(w_master: jax.Array, w_b: jax.Array) -> jax.Array:
    """Returns w_b in the forward pass; routes the cotangent to w_master.

    This is the straight-through estimator of Alg. (1): dC/dw_b is accumulated
    directly onto the real-valued weight (the saturation of the STE — zeroing
    the gradient outside [-1, 1] — is provided by ``clip_weights`` on the
    master copy, exactly as the paper's step 4 does)."""
    del w_master
    return w_b


def _ste_fwd(w_master, w_b):
    return w_b, None


def _ste_bwd(_, g):
    # Gradient w.r.t. the master weight is the gradient w.r.t. the binary
    # weight (straight-through); the binary tensor itself is non-differentiable.
    return g, jnp.zeros_like(g)


_ste_identity.defvjp(_ste_fwd, _ste_bwd)


def binarize(
    w: jax.Array,
    mode: BinarizeMode | str,
    key: jax.Array | None = None,
) -> jax.Array:
    """Alg. (1) ``binarize()``: differentiable-through binarization of a
    master weight.

    Args:
      w: real-valued master weight (any float dtype).
      mode: NONE (identity), DETERMINISTIC (Eq. 1) or STOCHASTIC (Eq. 2).
      key: PRNG key, required iff mode is STOCHASTIC.

    Returns:
      Tensor of the same shape/dtype whose *values* are in {-1, +1} (for the
      binarized modes) and whose vjp routes gradients to ``w`` unchanged.
    """
    mode = BinarizeMode.parse(mode)
    if mode is BinarizeMode.NONE:
        return w
    if mode is BinarizeMode.DETERMINISTIC:
        w_b = deterministic_binarize(jax.lax.stop_gradient(w))
    else:
        if key is None:
            raise ValueError("stochastic binarization requires a PRNG key")
        w_b = stochastic_binarize(jax.lax.stop_gradient(w), key)
    return _ste_identity(w, w_b)


# ---------------------------------------------------------------------------
# Pytree-level API: binarize a whole parameter tree under a policy.
# ---------------------------------------------------------------------------

def binarize_tree(
    params: Any,
    mode: BinarizeMode | str,
    policy,
    key: jax.Array | None = None,
) -> Any:
    """Applies ``binarize`` to every leaf selected by ``policy``.

    ``policy`` is a ``repro.core.policy.BinarizePolicy`` (or anything with a
    ``selects(path) -> bool``). Unselected leaves pass through untouched.
    Each selected leaf gets an independent fold of the key (stable in the
    tree-path ordering, so the step is reproducible)."""
    mode = BinarizeMode.parse(mode)
    if mode is BinarizeMode.NONE:
        return params

    leaves_with_paths = jax.tree_util.tree_leaves_with_path(params)
    selected = [policy.selects(_path_str(p)) for p, _ in leaves_with_paths]
    n_selected = sum(selected)

    keys: list = [None] * len(leaves_with_paths)
    if mode is BinarizeMode.STOCHASTIC:
        if key is None:
            raise ValueError("stochastic binarization requires a PRNG key")
        subkeys = jax.random.split(key, max(n_selected, 1))
        it = iter(subkeys)
        keys = [next(it) if s else None for s in selected]

    out_leaves = []
    for (path, leaf), sel, k in zip(leaves_with_paths, selected, keys):
        out_leaves.append(binarize(leaf, mode, k) if sel else leaf)
    treedef = jax.tree_util.tree_structure(params)
    return jax.tree_util.tree_unflatten(treedef, out_leaves)


def clip_tree(params: Any, policy) -> Any:
    """Alg. (1) step 4 over a pytree: clip selected master weights to [-1,1]."""
    leaves_with_paths = jax.tree_util.tree_leaves_with_path(params)
    out = [
        clip_weights(leaf) if policy.selects(_path_str(path)) else leaf
        for path, leaf in leaves_with_paths
    ]
    treedef = jax.tree_util.tree_structure(params)
    return jax.tree_util.tree_unflatten(treedef, out)


def _path_str(path) -> str:
    parts = []
    for entry in path:
        if hasattr(entry, "key"):
            parts.append(str(entry.key))
        elif hasattr(entry, "idx"):
            parts.append(str(entry.idx))
        elif hasattr(entry, "name"):
            parts.append(str(entry.name))
        else:  # pragma: no cover - future jax path entry kinds
            parts.append(str(entry))
    return "/".join(parts)
