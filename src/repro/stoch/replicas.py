"""K-replica sampling of a stochastically-binarized network.

The paper's stochastic binarization (Eq. 2/3) draws each binary weight as a
Bernoulli sample of the hard sigmoid of the master weight. A single
``plan.pack(params, key)`` freezes *one* such sample forever; this module
draws K independent samples — K complete packed networks — and holds them
together with a leading replica axis, so inference can ensemble-average the
replicas (``repro.stoch.ensemble``) and quote calibrated uncertainty.

Bitpacking is what makes this affordable: one replica of a binary layer is
1 bit/weight, so even K = 16 replicas cost what *one* bf16 copy of that
layer costs. Leaves the plan does not binarize (embeddings, norms, biases,
dense fallthroughs) are **shared** across replicas — stored once in the
base tree and broadcast into every replica at apply time, never copied K
times.

Key-fold convention: replica r packs with ``replica_key(key, r)``, which is
``key`` itself for r = 0 — so a K = 1 ensemble is *bit-identical* to the
existing single-sample pack path ``plan.pack(params, key)`` (asserted in
tests/test_stoch_ensemble.py). Within a replica the per-leaf folding is the
engine's own (fold by leaf index, then per-stack-layer split), untouched.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax

from repro.core.binarize import BinarizeMode, _path_str
from repro.engine import registry
from repro.engine.plan import ExecutionPlan, _leaf_context


def replica_key(key: jax.Array, r: int) -> jax.Array:
    """PRNG key for replica ``r``. Replica 0 uses ``key`` unchanged so the
    first replica reproduces ``plan.pack(params, key)`` bit-for-bit; later
    replicas fold in their index."""
    return key if r == 0 else jax.random.fold_in(key, r)


@dataclasses.dataclass
class ReplicaSet:
    """K packed replicas of one network.

    ``base`` is the full replica-0 serving tree (the ordinary
    ``plan.pack`` output — shared leaves live here exactly once).
    ``stacked`` maps the path of every stochastic row to its serving node
    with each stored array stacked on a new leading (K,) replica axis.
    ``merge_replica(r)`` materializes the complete serving tree of one
    replica; the ensemble forward (``repro.stoch.ensemble``) instead vmaps
    over ``stacked`` directly and closes over the shared ``base`` leaves.
    """

    base: Any                          # full serving tree, replica 0
    stacked: dict[str, Any]            # path -> serving node, arrays (K, ...)
    k: int
    paths: tuple[str, ...]             # stochastic-row paths, tree order
    plan: ExecutionPlan

    def merge_replica(self, r: int):
        """Full serving tree for replica ``r`` (shared leaves + that
        replica's slice of every stacked node)."""
        if not 0 <= r < self.k:
            raise IndexError(f"replica {r} out of range for k={self.k}")
        picked = {p: _index_node(n, r) for p, n in self.stacked.items()}
        return _substitute(self.base, picked)

    def tree_nbytes(self) -> int:
        """Total stored bytes: shared base + the K-stacked stochastic
        leaves (replica 0's copy in ``base`` is counted as part of the
        stack, not double-counted)."""
        stoch = set(self.paths)
        total = 0
        for path, node in _serving_nodes(self.base):
            if path not in stoch:
                total += _node_nbytes(node)
        for node in self.stacked.values():
            total += _node_nbytes(node)
        return total


def _serving_nodes(tree):
    types = registry.serving_leaf_types()
    is_leaf = lambda x: isinstance(x, types)  # noqa: E731
    return [(_path_str(p), n) for p, n in
            jax.tree_util.tree_leaves_with_path(tree, is_leaf=is_leaf)]


def _node_nbytes(node) -> int:
    return sum(a.nbytes for a in jax.tree_util.tree_leaves(node))


def _index_node(node, r: int):
    return jax.tree.map(lambda a: a[r], node)


def _stack_nodes(nodes: list):
    """Stack the stored arrays of structurally-identical serving nodes on a
    new leading replica axis (static aux data taken from the first)."""
    import jax.numpy as jnp

    kids0, treedef = jax.tree_util.tree_flatten(nodes[0])
    cols = [jax.tree_util.tree_flatten(n)[0] for n in nodes]
    stacked = [jnp.stack([col[i] for col in cols]) for i in range(len(kids0))]
    return jax.tree_util.tree_unflatten(treedef, stacked)


def _substitute(base, picked: dict[str, Any]):
    """Replace serving nodes of ``base`` at the given paths."""
    types = registry.serving_leaf_types()
    is_leaf = lambda x: isinstance(x, types)  # noqa: E731

    def pick(path, node):
        return picked.get(_path_str(path), node)

    return jax.tree_util.tree_map_with_path(pick, base, is_leaf=is_leaf)


def sample_replicas(params, plan: ExecutionPlan, key: jax.Array,
                    k: int) -> ReplicaSet:
    """Draw ``k`` independent stochastic-binarization samples of ``params``
    under ``plan``.

    Only the plan's stochastic rows (``plan.stochastic_rows()`` — the
    leaves whose pack transform consumes the PRNG key) are re-sampled per
    replica; everything else is packed once and shared. Replica r packs
    with ``replica_key(key, r)`` so replica 0 is bit-identical to
    ``plan.pack(params, key)``.
    """
    if k < 1:
        raise ValueError(f"ensemble size k must be >= 1, got {k}")
    if plan.mode != "stoch":
        raise ValueError(
            f"sample_replicas needs a stochastic plan (mode='stoch'), got "
            f"mode={plan.mode!r}: det/xnor packs are keyless, every replica "
            f"would be identical")
    rows = plan.stochastic_rows()
    paths = tuple(a.path for a in rows)
    masters = {_path_str(p): leaf for p, leaf in
               jax.tree_util.tree_leaves_with_path(params)}

    base = plan.pack(params, key=replica_key(key, 0))
    base_nodes = dict(_serving_nodes(base))

    stacked: dict[str, Any] = {}
    for a in rows:
        lc = _leaf_context(a, plan.mode)
        spec = registry.get_backend(a.backend)
        reps = [base_nodes[a.path]]                    # replica 0: reuse base
        for r in range(1, k):
            pc = registry.PackContext(
                weight_mode=BinarizeMode.STOCHASTIC,
                key=replica_key(key, r), with_scale=plan.with_scale)
            reps.append(spec.pack(lc, masters[a.path], pc))
        stacked[a.path] = _stack_nodes(reps)
    return ReplicaSet(base=base, stacked=stacked, k=k, paths=paths,
                      plan=plan)
