"""Stochastic ensemble subsystem: K-replica packed BNN inference.

The paper's stochastically-binarized network (Eq. 2/3) defines a
*distribution* over binary networks; this package samples K complete packed
replicas from it (``sample_replicas``), runs them in one vmapped forward
(``ensemble_forward``), and condenses the replica logits into calibrated
uncertainty statistics (``ensemble_stats`` — mean logits, logit variance,
vote agreement). Bitpacked storage makes the replication affordable: K
replicas of a binary layer cost K/16 of one bf16 copy, so even K = 16 fits
in a single dense layer's byte budget. Shared (non-stochastic) leaves are
stored once and broadcast — never copied per replica.

Integration points: ``repro.engine.plan`` records the ensemble mesh axis
(``replica_axis``, manifest v3); ``repro.serve.engine.ServeEngine`` accepts
``ensemble=ReplicaSet`` and threads agreement / variance / abstention into
every GenerationResult; ``launch/serve.py --ensemble K`` drives it.
"""
from repro.stoch.ensemble import (EnsembleStats, ensemble_forward,
                                  ensemble_stats, place_replicas,
                                  replica_specs)
from repro.stoch.replicas import ReplicaSet, replica_key, sample_replicas

__all__ = [
    "EnsembleStats", "ReplicaSet", "ensemble_forward", "ensemble_stats",
    "place_replicas", "replica_key", "replica_specs", "sample_replicas",
]
