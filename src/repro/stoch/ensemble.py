"""Vmapped ensemble forward + uncertainty statistics + mesh placement.

``ensemble_forward`` runs one model function over every replica of a
:class:`~repro.stoch.replicas.ReplicaSet` in a single ``jax.vmap`` — the
replica axis maps over the *stacked* stochastic leaves only, while the
shared base leaves are closed over and broadcast, so XLA never materializes
K copies of embeddings / norms / dense fallthroughs. Backend dispatch is
type-keyed (``repro.engine.registry``), and the serving leaf classes carry
their static aux data through ``vmap`` untouched, so the packed / xnor /
packed_conv datapaths all vmap as-is.

``ensemble_stats`` condenses the (K, ..., V) replica logits into the
user-visible uncertainty signal: ensemble-mean logits, mean per-logit
across-replica variance, and vote agreement (the fraction of replicas whose
argmax matches the ensemble argmax).

``place_replicas`` puts a ReplicaSet on a mesh: base leaves follow the
plan's recorded sharding column exactly as single-sample serving does, and
each stacked leaf gets the plan's ``replica_axis`` ("data" / "model" /
None) prepended to its row's column — replicas shard over the chosen mesh
axis while every inner dim keeps its single-replica placement.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.stoch.replicas import ReplicaSet, _substitute


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class EnsembleStats:
    """Per-input ensemble uncertainty summary (all f32).

    ``mean_logits``  (..., V)  ensemble-mean logits (what gets decoded)
    ``variance``     (...,)    across-replica logit variance, meaned over V
    ``agreement``    (...,)    fraction of replicas voting with the ensemble
    """

    mean_logits: jax.Array
    variance: jax.Array
    agreement: jax.Array

    def tree_flatten(self):
        return (self.mean_logits, self.variance, self.agreement), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


def ensemble_stats(rep_logits: jax.Array) -> EnsembleStats:
    """Condense (K, ..., V) per-replica logits into :class:`EnsembleStats`.

    Agreement compares each replica's argmax against the argmax of the
    ensemble *mean* — a unanimous ensemble scores 1.0 regardless of K."""
    x = rep_logits.astype(jnp.float32)
    mean = jnp.mean(x, axis=0)                               # (..., V)
    variance = jnp.mean(jnp.var(x, axis=0), axis=-1)         # (...,)
    votes = jnp.argmax(x, axis=-1)                           # (K, ...)
    winner = jnp.argmax(mean, axis=-1)                       # (...,)
    agreement = jnp.mean((votes == winner[None]).astype(jnp.float32), axis=0)
    return EnsembleStats(mean, variance, agreement)


def ensemble_forward(rs: ReplicaSet, fn: Callable[[Any], jax.Array],
                     *, stats: bool = True):
    """Run ``fn(serving_tree) -> logits`` once per replica via ``vmap``.

    Returns :class:`EnsembleStats` (default) or the raw (K, ..., V)
    replica logits (``stats=False``). ``fn`` must be traceable (it is
    called under ``vmap``); jit the *caller* for a single fused ensemble
    step. For k = 1 the vmap is skipped entirely — the call lowers to
    exactly the single-sample program (bit-identity with the non-ensemble
    path, asserted in tests)."""
    if rs.k == 1:
        logits = fn(rs.base)[None]
    else:
        def one(stacked_slice):
            return fn(_substitute(rs.base, stacked_slice))

        logits = jax.vmap(one, in_axes=0, axis_size=rs.k)(rs.stacked)
    return ensemble_stats(logits) if stats else logits


def prepend_replica_axis(rax: Optional[str], spec):
    """``PartitionSpec(rax, *spec)`` with ``rax`` deduplicated from the
    inner entries first (a mesh-axis name may appear at most once in a
    spec; the replica axis wins the collision). ``rax=None`` prepends a
    replicated leading dim."""
    from jax.sharding import PartitionSpec as P

    entries = []
    for e in spec:
        if isinstance(e, (tuple, list)):
            kept = tuple(a for a in e if a != rax)
            entries.append(kept if kept else None)
        else:
            entries.append(None if e == rax else e)
    return P(rax, *entries)


def replica_specs(rs: ReplicaSet, *, mesh=None) -> dict[str, Any]:
    """PartitionSpec pytree for the *stacked* nodes of ``rs``: the plan's
    ``replica_axis`` on the leading (K,) dim, the row's recorded sharding
    column (rank-adapted per stored array) on the inner dims. The replica
    axis wins a name collision — a column entry naming the same mesh axis
    is dropped, since a name may appear at most once in a spec."""
    from repro.distributed.sharding import (_adapt_spec, sanitize_spec,
                                            serving_leaf_pspec)

    rax = rs.plan.replica_axis
    out: dict[str, Any] = {}
    for path, node in rs.stacked.items():
        row = rs.plan[path]
        spec = row.pspec
        if spec is None:                      # v1-manifest row: re-derive
            spec = serving_leaf_pspec(path, node)

        def spec_for(a, spec=spec):
            full = prepend_replica_axis(rax, _adapt_spec(spec, a.ndim - 1))
            return (sanitize_spec(mesh, full, a.shape)
                    if mesh is not None else full)

        out[path] = jax.tree.map(spec_for, node)
    return out


def place_replicas(mesh, rs: ReplicaSet,
                   plan: Optional[Any] = None) -> ReplicaSet:
    """Place a ReplicaSet on ``mesh``: base leaves via the ordinary
    plan-column placement (``place_packed_params``), stacked leaves with
    the plan's ``replica_axis`` prepended (:func:`replica_specs`). A
    ``replica_axis`` of None (or a K not divisible by the axis size)
    replicates the stack."""
    from jax.sharding import NamedSharding

    from repro.distributed.sharding import place_packed_params

    plan = plan if plan is not None else rs.plan
    base = place_packed_params(mesh, rs.base, plan)
    specs = replica_specs(rs, mesh=mesh)
    stacked = {
        path: jax.tree.map(
            lambda a, s: jax.device_put(a, NamedSharding(mesh, s)),
            node, specs[path])
        for path, node in rs.stacked.items()}
    return ReplicaSet(base=base, stacked=stacked, k=rs.k, paths=rs.paths,
                      plan=rs.plan)
