"""Sharded, prefetching, deterministic data pipeline.

Design: batches are pure functions of the step index (data/synthetic.py), so

* resume-exactness: restarting at step k regenerates batch k bit-identically
  (no iterator state in checkpoints — tested in tests/test_checkpoint.py);
* sharding: each host materializes only its slice of the global batch
  (``host_slice``), and the on-device layout follows the mesh's data axes;
* straggler tolerance: a worker that falls behind can skip ahead to the
  fleet's step counter without coordination, since any batch is
  reconstructible from its index alone;
* prefetch: a background thread keeps ``depth`` batches ready so host-side
  generation overlaps device compute.
"""
from __future__ import annotations

import queue
import threading
from typing import Callable, Iterator, Optional

import jax


class Prefetcher:
    """Background-thread prefetch of an index-driven batch function."""

    def __init__(self, batch_fn: Callable[[int], object], start_step: int = 0,
                 depth: int = 2):
        self._fn = batch_fn
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._step = start_step
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        step = self._step
        while not self._stop.is_set():
            try:
                batch = self._fn(step)
            except Exception as e:  # surface errors on the consumer side
                self._q.put(e)
                return
            # block until there is room (or stop)
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        item = self._q.get()
        if isinstance(item, Exception):
            raise item
        return item

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2.0)


def host_slice(global_batch: int, process_index: Optional[int] = None,
               process_count: Optional[int] = None) -> slice:
    """The slice of the global batch this host materializes."""
    pi = jax.process_index() if process_index is None else process_index
    pc = jax.process_count() if process_count is None else process_count
    per = global_batch // pc
    return slice(pi * per, (pi + 1) * per)


def skip_ahead(current_step: int, fleet_step: int, max_skip: int = 1_000_000) -> int:
    """Straggler mitigation: jump a lagging worker to the fleet's step.

    Pure bookkeeping — batches are index-addressed, so no data is lost and
    no peer coordination is needed. ``max_skip`` bounds silent divergence."""
    if fleet_step < current_step:
        return current_step
    return min(fleet_step, current_step + max_skip)
