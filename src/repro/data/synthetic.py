"""Deterministic synthetic datasets (the container is offline — see
DESIGN.md §8.3).

Three generators, all shape-compatible with the real datasets they stand in
for and all *step-indexed*: batch ``i`` is a pure function of (seed, i), so
a restarted trainer reproduces the exact batch stream with no data-state
checkpointing (this is also the straggler story: any host can regenerate any
batch).

* ``mnist_like``    — 784-dim, 10 classes: class-conditional prototypes +
                      noise, linearly-separable-ish so learning curves are
                      meaningful (det/stoch/none comparisons transfer).
* ``cifar_like``    — (32, 32, 3), 10 classes: prototype images with
                      structured (low-frequency) noise.
* ``lm_tokens``     — token streams with Zipf-ish marginals and a Markov
                      flavour so perplexity decreases under training.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

N_CLASSES = 10


@dataclasses.dataclass(frozen=True)
class SyntheticSpec:
    kind: str                 # "mnist" | "cifar" | "lm"
    n_train: int
    batch_size: int
    seq_len: int = 0
    vocab_size: int = 0
    seed: int = 0

    @property
    def steps_per_epoch(self) -> int:
        return max(self.n_train // self.batch_size, 1)


def _class_key(seed: int) -> jax.Array:
    return jax.random.key(seed ^ 0x5EED)


def mnist_like(spec: SyntheticSpec, step: int | jax.Array):
    """-> (images (B, 784) f32 in [0,1], labels (B,) int32)."""
    proto = jax.random.uniform(_class_key(spec.seed), (N_CLASSES, 784))
    key = jax.random.fold_in(jax.random.key(spec.seed), step)
    k1, k2 = jax.random.split(key)
    labels = jax.random.randint(k1, (spec.batch_size,), 0, N_CLASSES)
    noise = 0.35 * jax.random.normal(k2, (spec.batch_size, 784))
    x = jnp.clip(proto[labels] + noise, 0.0, 1.0)
    return x, labels


def cifar_like(spec: SyntheticSpec, step: int | jax.Array):
    """-> (images (B, 32, 32, 3) f32, labels (B,) int32)."""
    proto = jax.random.uniform(_class_key(spec.seed + 1), (N_CLASSES, 8, 8, 3))
    proto = jax.image.resize(proto, (N_CLASSES, 32, 32, 3), "linear")
    key = jax.random.fold_in(jax.random.key(spec.seed + 1), step)
    k1, k2 = jax.random.split(key)
    labels = jax.random.randint(k1, (spec.batch_size,), 0, N_CLASSES)
    lowf = jax.random.normal(k2, (spec.batch_size, 8, 8, 3))
    noise = 0.25 * jax.image.resize(lowf, (spec.batch_size, 32, 32, 3), "linear")
    x = jnp.clip(proto[labels] + noise, 0.0, 1.0)
    return x, labels


def lm_tokens(spec: SyntheticSpec, step: int | jax.Array):
    """-> (tokens (B, S+1) int32); inputs = [:, :-1], labels = [:, 1:].

    Zipf marginal with a deterministic bigram drift: learnable structure."""
    key = jax.random.fold_in(jax.random.key(spec.seed + 2), step)
    k1, k2 = jax.random.split(key)
    b, s, v = spec.batch_size, spec.seq_len + 1, spec.vocab_size
    # Zipf via inverse-CDF on uniform
    u = jax.random.uniform(k1, (b, s), minval=1e-6)
    ranks = jnp.floor(jnp.power(u, -1.0 / 1.1)) % v
    base = ranks.astype(jnp.int32)
    # deterministic bigram flavour: every other token correlates with previous
    shifted = jnp.roll(base, 1, axis=1)
    mix = jax.random.bernoulli(k2, 0.3, (b, s))
    toks = jnp.where(mix, (shifted * 7 + 13) % v, base)
    return toks


def eval_batch(spec: SyntheticSpec, step: int = 10_000_000):
    """A held-out batch (step index far outside the training range)."""
    if spec.kind == "mnist":
        return mnist_like(spec, step)
    if spec.kind == "cifar":
        return cifar_like(spec, step)
    return lm_tokens(spec, step)


def train_batch(spec: SyntheticSpec, step: int | jax.Array):
    if spec.kind == "mnist":
        return mnist_like(spec, step)
    if spec.kind == "cifar":
        return cifar_like(spec, step)
    return lm_tokens(spec, step)
