"""Checkpoint manager: atomic, asynchronous, keep-k, resume-from-latest.

Format: one ``step_<N>/arrays.npz`` per checkpoint (leaves keyed by their
tree path) plus ``meta.json``; a ``COMMITTED`` marker file is written last
so a crash mid-write can never produce a checkpoint that ``latest_step``
would pick up (atomicity via marker + directory rename). An optional
background thread makes ``save`` non-blocking so checkpoint I/O overlaps
training compute (the fault-tolerance requirement at pod scale).

Restore takes a *template* pytree (from ``init``) and returns it with leaf
values replaced — structure/dtype mismatches fail loudly. Restoring onto a
different mesh/device count is handled by ``repro.ft.elastic``.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Optional

import jax
import numpy as np

_MARKER = "COMMITTED"


def _path_str(path) -> str:
    from repro.core.binarize import _path_str as ps
    return ps(path)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, async_save: bool = True):
        self.directory = directory
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # -- write --------------------------------------------------------------
    def save(self, step: int, tree: Any, metadata: Optional[dict] = None,
             block: bool = False) -> None:
        # Snapshot to host memory synchronously (cheap), write in background.
        leaves_with_paths = jax.tree_util.tree_leaves_with_path(tree)

        def to_host(v):
            if hasattr(v, "dtype") and jax.dtypes.issubdtype(
                    v.dtype, jax.dtypes.prng_key):
                v = jax.random.key_data(v)
            return np.asarray(jax.device_get(v))

        host = {_path_str(p): to_host(v) for p, v in leaves_with_paths}
        meta = dict(metadata or {}, step=int(step), time=time.time(),
                    n_leaves=len(host))
        self.wait()  # one in-flight save at a time
        if self.async_save and not block:
            self._thread = threading.Thread(
                target=self._write, args=(step, host, meta), daemon=True)
            self._thread.start()
        else:
            self._write(step, host, meta)

    def _write(self, step: int, host: dict, meta: dict) -> None:
        final = os.path.join(self.directory, f"step_{step:010d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        np.savez(os.path.join(tmp, "arrays.npz"), **host)
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump(meta, f)
        with open(os.path.join(tmp, _MARKER), "w") as f:
            f.write("ok")
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._gc()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep] if self.keep > 0 else []:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:010d}"),
                          ignore_errors=True)

    # -- read ---------------------------------------------------------------
    def all_steps(self) -> list[int]:
        out = []
        for name in sorted(os.listdir(self.directory)):
            full = os.path.join(self.directory, name)
            if (name.startswith("step_") and not name.endswith(".tmp")
                    and os.path.exists(os.path.join(full, _MARKER))):
                out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, template: Any, step: Optional[int] = None) -> Any:
        step = self.latest_step() if step is None else step
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint in {self.directory}")
        path = os.path.join(self.directory, f"step_{step:010d}", "arrays.npz")
        data = np.load(path)
        leaves_with_paths = jax.tree_util.tree_leaves_with_path(template)
        new_leaves = []
        for p, leaf in leaves_with_paths:
            key = _path_str(p)
            if key not in data:
                raise KeyError(f"checkpoint missing leaf {key}")
            arr = data[key]
            is_key = hasattr(leaf, "dtype") and jax.dtypes.issubdtype(
                leaf.dtype, jax.dtypes.prng_key)
            if not is_key and tuple(arr.shape) != tuple(leaf.shape):
                raise ValueError(
                    f"shape mismatch for {key}: ckpt {arr.shape} vs "
                    f"template {leaf.shape}")
            if is_key:
                new_leaves.append(jax.random.wrap_key_data(
                    jax.numpy.asarray(arr)))
            else:
                new_leaves.append(jax.numpy.asarray(arr, dtype=leaf.dtype))
        treedef = jax.tree_util.tree_structure(template)
        return jax.tree_util.tree_unflatten(treedef, new_leaves)

    def read_meta(self, step: Optional[int] = None) -> dict:
        step = self.latest_step() if step is None else step
        with open(os.path.join(self.directory, f"step_{step:010d}",
                               "meta.json")) as f:
            return json.load(f)
