"""Sharding rules: logical-axis -> mesh-axis mapping (DP / TP / FSDP / EP / SP).

Models are written against *logical* activation/parameter axes and call
``ShardCtx.act(x, kind)`` at block boundaries; the context resolves the kind
to a ``PartitionSpec`` for the active mesh (or no-ops on a single device, so
smoke tests never touch device state).

Conventions (single-pod mesh ("data", "model"), multi-pod ("pod", "data",
"model")):

* batch dims           -> ("pod", "data")                  [DP]
* d_ff / expert dims   -> "model"                          [Megatron TP —
  d_ff % 16 == 0 holds for every assigned arch; asserted in tests]
* flattened heads*hd   -> "model"  (avoids head-count divisibility issues
  for the 24/40/56-head archs)
* experts              -> "model" when n_experts % 16 == 0 else unsharded
* KV-cache             -> batch over "data", sequence over "model"
  (flash-decoding-style sharded attention; XLA inserts the softmax combine)
* params               -> TP dim over "model"; with FSDP also shard the
  largest replicated dim over "data" (ZeRO-3)
* packed serving leaves (PackedLinear / XnorLinear / XnorConv)
                       -> out-channel (N) dim over "model"; the bitpacked
  int32 word dim (K // 32) is NEVER sharded, so a 32-bit lane group never
  splits across devices. ``place_packed_params`` applies these rules (or a
  compiled ExecutionPlan's recorded sharding column) to a serving tree.
"""
from __future__ import annotations

import dataclasses
import functools
import re
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def batch_axes(mesh: Optional[Mesh]) -> tuple:
    if mesh is None:
        return ("data",)
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def mesh_context(mesh: Mesh):
    """Context manager activating ``mesh`` as the ambient mesh.

    Spans the jax API change: ``jax.set_mesh`` (jax >= 0.5-era) vs entering
    the ``Mesh`` object itself (jax 0.4.x)."""
    set_mesh = getattr(jax, "set_mesh", None)
    if set_mesh is not None:
        return set_mesh(mesh)
    return mesh


@dataclasses.dataclass
class ShardCtx:
    """Activation-sharding helper threaded through model code.

    ``decode=True`` selects the *serving* layout (``ServeEngine`` builds its
    context this way): sequence parallelism is pointless on a one-token
    stream (seq=1 cannot shard over "model" without padding permutes), so
    the residual / attention activations replicate, the KV/SSM cache drops
    its "model" axis (writes become device-local — no reshard copies), and
    the per-projection all-gathers collapse to one collective per TP matmul
    with a single deferred gather at the logits. See
    docs/ARCHITECTURE.md §Decode-step collective budget."""

    mesh: Optional[Mesh] = None
    enable: bool = True
    decode: bool = False

    def _p(self, *spec) -> Optional[P]:
        return P(*spec)

    def act(self, x: jax.Array, kind: str) -> jax.Array:
        """Applies a with_sharding_constraint for a logical activation kind."""
        if not self.enable or self.mesh is None:
            return x
        dp = batch_axes(self.mesh)
        specs = {
            # Residual stream: seq over "model" = Megatron sequence
            # parallelism — GSPMD inserts the SP all-gather before each
            # TP block and the reduce-scatter after it, and the per-layer
            # scan carry (the remat-saved activation) shrinks by the TP
            # degree. See EXPERIMENTS.md §Perf iteration 1.
            "btd": P(dp, "model", None),       # (batch, seq, d_model)
            "btf": P(dp, None, "model"),       # (batch, seq, d_ff)
            "btq": P(dp, None, "model"),       # (batch, seq, heads*hd)
            "bthd": P(dp, None, "model", None),# (batch, seq, heads, hd)
            "btv": P(dp, None, "model"),       # logits (vocab TP-sharded)
            "bv": P(dp, None),                 # last-token logits, gathered
            "bte": P(dp, None, None),          # router logits (small)
            "ecd": P(None, dp, "model"),       # MoE buffer (E, cap, d)
            "ecf": P(None, dp, "model"),       # MoE hidden (E, cap, f)
            "a": P(dp),                        # MoE assignment vectors (T*k,)
            "ad": P(dp, "model"),              # MoE per-assignment acts
            "btn": P(dp, None, "model"),       # ssm inner (batch, seq, d_inner)
            "bsh": P(dp, None, "model"),       # ssm dt (batch, seq, heads)
            "bcqqh": P(dp, None, None, None, "model"),  # SSD decay blocks
            "bchpn": P(dp, None, "model", None, None),  # SSD chunk states
            "cache_kv": P(None, dp, "model", None, None),  # (L, B, S, kv, hd)
            "ssm_state": P(None, dp, "model", None, None), # (L, B, heads, hp, N)
        }
        if self.decode:
            specs.update({
                # replicated residual/attention stream: attention internals
                # (RoPE, cache write, softmax, PV einsum) run device-local
                "btd": P(dp, None, None),
                "btq": P(dp, None, None),
                "bthd": P(dp, None, None, None),
                # MLP hidden replicated too: the col-parallel up-projection
                # all-gathers its (tiny) output so the down-projection
                # contracts full-K locally — partial f32 sums behind an
                # all-reduce could change summation order vs single device
                # (the xnor row-parallel down-proj still all-reduces its
                # *integer* popcount partials, which is exact)
                "btf": P(dp, None, None),
                # one all-gather right after the col-parallel qkv matmul
                "qkv": P(dp, None, None),
                # "btv" stays V-sharded (the base spec): pinning the logits
                # dot's output replicated makes GSPMD all-gather the whole
                # tied-embedding table (weight bytes) instead of the tiny
                # (B, V) activation. The deferred gather is the separate
                # "bv" constraint applied AFTER the head matmul
                # (transformer._decode_head_out).
                # cache entries keep "model" off every axis: updates are
                # in-place local writes (donation-friendly, no reshards)
                "cache_kv": P(None, dp),
                "ssm_state": P(None, dp),
            })
        else:
            specs["qkv"] = P(dp, None, "model")   # fused qkv projection out
        spec = specs.get(kind)
        if spec is None:
            return x
        spec = P(*spec[: x.ndim])
        try:
            return jax.lax.with_sharding_constraint(x, NamedSharding(self.mesh, spec))
        except (ValueError, TypeError):
            return x


# ---------------------------------------------------------------------------
# Parameter PartitionSpecs, generated from tree paths by pattern rules.
# ---------------------------------------------------------------------------

# (path regex, spec builder given ndim). Later rules win. Cached: the
# 13-entry closure table is built once per (fsdp, dp_axes), not per leaf.
@functools.lru_cache(maxsize=None)
def _pspec_rules(fsdp: bool, dp_axes=("data",)):
    dp = dp_axes if len(dp_axes) > 1 else dp_axes[0]

    def rule(last_model_dim, fsdp_dim=None):
        def build(ndim: int):
            spec = [None] * ndim
            if last_model_dim is not None:
                spec[last_model_dim % ndim] = "model"
            if fsdp and fsdp_dim is not None and (fsdp_dim % ndim) != (
                    (last_model_dim or 0) % ndim if last_model_dim is not None else -99):
                spec[fsdp_dim % ndim] = dp
            return P(*spec)
        return build

    return [
        # (V, D): vocab-parallel (Megatron embedding). TP on V keeps BOTH
        # tied-embedding consumers weight-stationary: the lookup is a
        # masked local take + one small f32 all-reduce (exact — each output
        # element is one shard's row + zeros), and the tied logits matmul
        # w.T is col-parallel on V, so no device ever moves the (V, D)
        # table. TP on D instead made GSPMD reshard+gather the whole table
        # every decode step (measured: ~60% of decode-step collective
        # bytes).
        (re.compile(r".*embed.*"), rule(-2, -1)),
        (re.compile(r".*lm_head.*"), rule(-1, -2)),          # (D, V): vocab TP
        (re.compile(r".*(scale|gamma|beta|bias|A_log|dt_bias|D)$"), rule(None)),
        (re.compile(r".*router.*"), rule(None, -2)),
        (re.compile(r".*w_qkv$"), rule(-1, -2)),             # (.., D, q+2kv): TP out
        (re.compile(r".*w_o$"), rule(-2, -1)),               # (.., q, D): TP in
        (re.compile(r".*w_(gate|up)$"), rule(-1, -2)),       # (.., D, F)
        (re.compile(r".*wi$"), rule(-1, -2)),
        (re.compile(r".*w_down$"), rule(-2, -1)),            # (.., F, D)
        (re.compile(r".*wo$"), rule(-2, -1)),
        (re.compile(r".*in_proj$"), rule(-1, -2)),           # ssm
        (re.compile(r".*out_proj$"), rule(-2, -1)),
        (re.compile(r".*conv$"), rule(-1)),                  # depthwise (w, d_inner)
    ]


def leaf_pspec(path: str, ndim: int, fsdp: bool = False,
               dp_axes=("data",)) -> P:
    """Megatron-style PartitionSpec for one *master-weight* leaf, resolved
    from its '/'-joined tree path (later rules win). This is the single
    source of the dense sharding rules: ``params_pspecs`` maps it over a
    tree, and the execution-plan compiler records it per plan row for every
    leaf a binary backend does not claim."""
    rules = _pspec_rules(bool(fsdp), tuple(dp_axes))
    chosen = P()
    for pat, build in rules:
        if pat.fullmatch(path):
            chosen = build(ndim) if ndim else P()
    # sanity: spec rank must not exceed leaf rank
    if len(chosen) > ndim:
        chosen = P(*list(chosen)[:ndim])
    return chosen


def params_pspecs(params, fsdp: bool = False, dp_axes=("data",)):
    """PartitionSpec tree matching ``params`` by path patterns.

    ``dp_axes``: the data-parallel mesh axes FSDP shards over — on the
    multi-pod mesh this must include "pod" (32-way ZeRO-3, not 16)."""

    def spec_for(path, leaf):
        from repro.core.binarize import _path_str

        return leaf_pspec(_path_str(path), getattr(leaf, "ndim", 0),
                          fsdp=fsdp, dp_axes=dp_axes)

    return jax.tree_util.tree_map_with_path(spec_for, params)


def shardings_from_pspecs(mesh: Mesh, pspecs):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        pspecs,
        is_leaf=lambda x: isinstance(x, P),
    )


# ---------------------------------------------------------------------------
# Serving-tree placement: put a packed parameter tree on a mesh, following
# the sharding column of a compiled ExecutionPlan (repro.engine.plan).
# ---------------------------------------------------------------------------

def spec_to_json(spec) -> list:
    """``PartitionSpec`` -> JSON-stable list (entries: None | str | [str..]).
    Inverse of :func:`spec_from_json`."""
    return [list(e) if isinstance(e, (tuple, list)) else e for e in spec]


def spec_from_json(entries) -> P:
    return P(*[tuple(e) if isinstance(e, list) else e for e in entries])


def _serving_leaf_types():
    from repro.engine import registry

    return registry.serving_leaf_types()


def backend_leaf_spec(path: str, master_ndim: int, backend_spec) -> Optional[P]:
    """Master-shape PartitionSpec for a leaf owned by a registered backend.

    A backend declaring ``tp_contract_dim`` opts its input-sharded
    (Megatron row-parallel) projections — the leaves whose *path rule*
    (:func:`leaf_pspec`) puts "model" on the contraction dim (w_o, wo,
    w_down, out_proj) — into contraction sharding: the packed int32 *word*
    dim splits over "model" (whole words only, so a 32-bit lane group still
    never crosses a device) and GSPMD finishes the matmul with one
    all-reduce of partial popcount sums instead of gathering and
    re-scattering the activation at the packed/dense boundary. Everything
    else falls back to the backend's out-channel ``tp_dim``. Returns None
    when the backend declares neither (dense path rules apply)."""
    cd = getattr(backend_spec, "tp_contract_dim", None)
    if cd is not None and master_ndim >= 2:
        mspec = leaf_pspec(path, master_ndim)
        entries = list(mspec) + [None] * (master_ndim - len(mspec))
        if entries[cd % master_ndim] == "model":
            return tp_spec(cd, master_ndim)
    if backend_spec.tp_dim is not None:
        return tp_spec(backend_spec.tp_dim, master_ndim)
    return None


def serving_leaf_pspec(path: str, leaf) -> P:
    """PartitionSpec for one *serving-tree* leaf (plan-free fallback).

    Consults the backend registry, so user-registered backends behave like
    the built-ins: a serving leaf whose backend declares a ``tp_dim``
    shards that master dim over "model" (for the bitpacked built-ins, the
    out-channel / N dim — never the word (K//32) dim, so a 32-bit lane
    group is never split across devices), and a backend declaring
    ``tp_contract_dim`` shards its row-parallel projections on the
    contraction/word dim instead (:func:`backend_leaf_spec` — same rules
    the plan compiler records). Plain arrays, and serving leaves whose
    backend declares neither, follow the Megatron path rules
    (:func:`leaf_pspec`)."""
    from repro.engine import registry

    from repro.core.policy import is_conv_kernel

    spec = registry.spec_for_serving_leaf(leaf)
    if spec is not None:
        shape = getattr(leaf, "master_shape", getattr(leaf, "shape", ()))
        s = backend_leaf_spec(path, len(shape), spec)
        if s is not None:
            return s
    elif is_conv_kernel(path) and getattr(leaf, "ndim", 0) == 4:
        # conv-stack kernels stay plain arrays under the binarized_dense
        # backend (and dense), so the registry cannot identify them by
        # type; TP-shard the out-channel dim like compile_plan records for
        # binarized_dense (a valid conv sharding for dense masters too)
        s = tp_spec(-1, 4)
        if s is not None:
            return s
    return leaf_pspec(path, getattr(leaf, "ndim", 0))


def _adapt_spec(spec: P, ndim: int) -> P:
    """Fit a master-shape spec onto an array of rank ``ndim`` by dropping
    the second-to-last entry per excess rank (serving layouts collapse the
    *contraction-side* master dims into the word dim, or omit them entirely:
    an XnorConv packs (kh, kw, C, N) into 2-D (words, N); a PackedLinear's
    per-channel scale drops the K dim, keeping (stack..., N)). The
    out-channel dim is last in every layout, so this alignment keeps an
    out-channel "model" on N and never leaks a row-parallel contraction
    "model" onto a stack/scale dim."""
    entries = list(spec)
    while len(entries) > max(ndim, 1):
        entries.pop(-2)
    if ndim == 0:
        entries = []
    return P(*entries)


def _place_serving_node(mesh: Mesh, spec: P, node, types=None):
    """device_put one plan row's serving node (packed leaf or plain array)
    under its master-shape spec, rank-adapting (and re-sanitizing — a word
    dim can be non-divisible where its master dim was divisible) to each
    stored array."""
    def put(a):
        if a is None or not hasattr(a, "ndim"):
            return a
        s = _adapt_spec(spec, a.ndim)
        s = sanitize_spec(mesh, s, a.shape)
        return jax.device_put(a, NamedSharding(mesh, s))

    if isinstance(node, types if types is not None
                  else _serving_leaf_types()):
        # generic over any registered pytree node class: place each stored
        # array, keep the node's static aux data
        kids, treedef = jax.tree_util.tree_flatten(node)
        return jax.tree_util.tree_unflatten(
            treedef, [put(a) for a in kids])
    return put(node)


def place_packed_params(mesh: Mesh, params, plan=None):
    """Place a (possibly packed) parameter tree on ``mesh``.

    With ``plan`` (a compiled :class:`repro.engine.ExecutionPlan`), each
    leaf follows its plan row's recorded sharding column; without one (or
    for v1-manifest rows), specs are re-derived from leaf types and paths
    (:func:`serving_leaf_pspec`) — equivalent for every typed serving leaf,
    while plain-array 4-D conv kernels uniformly TP-shard the out-channel
    dim (the ``binarized_dense`` rule; a dense-backend conv row's recorded
    column may instead be replicated — both placements are correct, the
    plan's is authoritative when given). Packed int32 weight words are always
    sharded on the out-channel dim over "model" (never splitting a 32-bit
    lane group); per-channel scales follow their N dim; dense leaves follow
    the Megatron rules. Axes named in a spec but absent from ``mesh`` are
    dropped (a "model"-annotated plan placed on a data-only mesh simply
    replicates those dims)."""
    from repro.core.binarize import _path_str

    types = _serving_leaf_types()                 # one registry walk, not
    is_leaf = lambda x: isinstance(x, types)      # noqa: E731 — per node
    nodes = jax.tree_util.tree_leaves_with_path(params, is_leaf=is_leaf)
    row_spec = {}
    if plan is not None:
        if len(plan.layers) != len(nodes):
            raise ValueError(
                f"plan/params mismatch: plan has {len(plan.layers)} rows, "
                f"tree has {len(nodes)} leaves")
        row_spec = {a.path: a.pspec for a in plan.layers}
    out = []
    for path, node in nodes:
        s = _path_str(path)
        if plan is not None and s not in row_spec:
            raise ValueError(
                f"plan/params mismatch: tree leaf {s!r} has no plan row "
                f"(the plan was compiled for a different tree)")
        # a v1-manifest row carries no sharding column (pspec None):
        # re-derive from the leaf type / path, same rules as compile
        spec = row_spec.get(s)
        if spec is None:
            spec = serving_leaf_pspec(s, node)
        spec = sanitize_spec(mesh, spec,
                             getattr(node, "master_shape",
                                     getattr(node, "shape", ())))
        out.append(_place_serving_node(mesh, spec, node, types))
    treedef = jax.tree_util.tree_structure(params, is_leaf=is_leaf)
    return jax.tree_util.tree_unflatten(treedef, out)


def tp_spec(tp_dim: int, ndim: int) -> Optional[P]:
    """"model"-on-one-dim spec for a backend's registered ``tp_dim`` (None
    when the leaf is not matmul-shaped). The single construction both the
    plan compiler (``engine.plan._row_sharding``) and the plan-free
    placement fallback (:func:`serving_leaf_pspec`) use, so the two paths
    cannot diverge."""
    if ndim < 2:
        return None
    entries = [None] * ndim
    entries[tp_dim % ndim] = "model"
    return P(*entries)


def sanitize_spec(mesh: Mesh, spec: P, shape) -> P:
    """Drop spec axes a concrete mesh cannot honour: axis names missing
    from the mesh, dims not divisible by their axis size (placement stays
    correct — those dims replicate), and entries beyond the array rank."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    out = []
    for i, e in enumerate(spec):
        if i >= len(shape):     # spec longer than the array: truncate
            break
        axes = e if isinstance(e, (tuple, list)) else (e,)
        axes = [a for a in axes if a is not None and a in sizes]
        n = 1
        for a in axes:
            n *= sizes[a]
        if not axes or shape[i] % n != 0:
            out.append(None)
        elif len(axes) == 1:
            out.append(axes[0])
        else:
            out.append(tuple(axes))
    return P(*out)


def divisibility_report(cfg, n_model: int = 16) -> dict:
    """Which dims shard cleanly over the model axis (documented invariant)."""
    return {
        "d_ff": cfg.d_ff % n_model == 0 if cfg.d_ff else True,
        "q_dim": cfg.q_dim % n_model == 0 if cfg.has_attention else True,
        "kv_dim": cfg.kv_dim % n_model == 0 if cfg.has_attention else True,
        "d_inner": (cfg.d_inner % n_model == 0) if cfg.ssm_state else True,
        "experts": (cfg.n_experts % n_model == 0) if cfg.n_experts else True,
        "vocab": cfg.vocab_size % n_model == 0,
    }
