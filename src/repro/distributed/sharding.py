"""Sharding rules: logical-axis -> mesh-axis mapping (DP / TP / FSDP / EP / SP).

Models are written against *logical* activation/parameter axes and call
``ShardCtx.act(x, kind)`` at block boundaries; the context resolves the kind
to a ``PartitionSpec`` for the active mesh (or no-ops on a single device, so
smoke tests never touch device state).

Conventions (single-pod mesh ("data", "model"), multi-pod ("pod", "data",
"model")):

* batch dims           -> ("pod", "data")                  [DP]
* d_ff / expert dims   -> "model"                          [Megatron TP —
  d_ff % 16 == 0 holds for every assigned arch; asserted in tests]
* flattened heads*hd   -> "model"  (avoids head-count divisibility issues
  for the 24/40/56-head archs)
* experts              -> "model" when n_experts % 16 == 0 else unsharded
* KV-cache             -> batch over "data", sequence over "model"
  (flash-decoding-style sharded attention; XLA inserts the softmax combine)
* params               -> TP dim over "model"; with FSDP also shard the
  largest replicated dim over "data" (ZeRO-3)
* packed serving leaves (PackedLinear / XnorLinear / XnorConv)
                       -> out-channel (N) dim over "model"; the bitpacked
  int32 word dim (K // 32) is NEVER sharded, so a 32-bit lane group never
  splits across devices. ``place_packed_params`` applies these rules (or a
  compiled ExecutionPlan's recorded sharding column) to a serving tree.
"""
from __future__ import annotations

import dataclasses
import functools
import re
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def batch_axes(mesh: Optional[Mesh]) -> tuple:
    if mesh is None:
        return ("data",)
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def mesh_context(mesh: Mesh):
    """Context manager activating ``mesh`` as the ambient mesh.

    Spans the jax API change: ``jax.set_mesh`` (jax >= 0.5-era) vs entering
    the ``Mesh`` object itself (jax 0.4.x)."""
    set_mesh = getattr(jax, "set_mesh", None)
    if set_mesh is not None:
        return set_mesh(mesh)
    return mesh


@dataclasses.dataclass
class ShardCtx:
    """Activation-sharding helper threaded through model code."""

    mesh: Optional[Mesh] = None
    enable: bool = True

    def _p(self, *spec) -> Optional[P]:
        return P(*spec)

    def act(self, x: jax.Array, kind: str) -> jax.Array:
        """Applies a with_sharding_constraint for a logical activation kind."""
        if not self.enable or self.mesh is None:
            return x
        dp = batch_axes(self.mesh)
        specs = {
            # Residual stream: seq over "model" = Megatron sequence
            # parallelism — GSPMD inserts the SP all-gather before each
            # TP block and the reduce-scatter after it, and the per-layer
            # scan carry (the remat-saved activation) shrinks by the TP
            # degree. See EXPERIMENTS.md §Perf iteration 1.
            "btd": P(dp, "model", None),       # (batch, seq, d_model)
            "btf": P(dp, None, "model"),       # (batch, seq, d_ff)
            "btq": P(dp, None, "model"),       # (batch, seq, heads*hd)
            "bthd": P(dp, None, "model", None),# (batch, seq, heads, hd)
            "btv": P(dp, None, "model"),       # logits (vocab TP-sharded)
            "bte": P(dp, None, None),          # router logits (small)
            "ecd": P(None, dp, "model"),       # MoE buffer (E, cap, d)
            "ecf": P(None, dp, "model"),       # MoE hidden (E, cap, f)
            "a": P(dp),                        # MoE assignment vectors (T*k,)
            "ad": P(dp, "model"),              # MoE per-assignment acts
            "btn": P(dp, None, "model"),       # ssm inner (batch, seq, d_inner)
            "bsh": P(dp, None, "model"),       # ssm dt (batch, seq, heads)
            "bcqqh": P(dp, None, None, None, "model"),  # SSD decay blocks
            "bchpn": P(dp, None, "model", None, None),  # SSD chunk states
            "cache_kv": P(None, dp, "model", None, None),  # (L, B, S, kv, hd)
            "ssm_state": P(None, dp, "model", None, None), # (L, B, heads, hp, N)
        }
        spec = specs.get(kind)
        if spec is None:
            return x
        spec = P(*spec[: x.ndim])
        try:
            return jax.lax.with_sharding_constraint(x, NamedSharding(self.mesh, spec))
        except (ValueError, TypeError):
            return x


# ---------------------------------------------------------------------------
# Parameter PartitionSpecs, generated from tree paths by pattern rules.
# ---------------------------------------------------------------------------

# (path regex, spec builder given ndim). Later rules win. Cached: the
# 13-entry closure table is built once per (fsdp, dp_axes), not per leaf.
@functools.lru_cache(maxsize=None)
def _pspec_rules(fsdp: bool, dp_axes=("data",)):
    dp = dp_axes if len(dp_axes) > 1 else dp_axes[0]

    def rule(last_model_dim, fsdp_dim=None):
        def build(ndim: int):
            spec = [None] * ndim
            if last_model_dim is not None:
                spec[last_model_dim % ndim] = "model"
            if fsdp and fsdp_dim is not None and (fsdp_dim % ndim) != (
                    (last_model_dim or 0) % ndim if last_model_dim is not None else -99):
                spec[fsdp_dim % ndim] = dp
            return P(*spec)
        return build

    return [
        (re.compile(r".*embed.*"), rule(-1, -2)),           # (V, D): TP on D? keep V
        (re.compile(r".*lm_head.*"), rule(-1, -2)),          # (D, V): vocab TP
        (re.compile(r".*(scale|gamma|beta|bias|A_log|dt_bias|D)$"), rule(None)),
        (re.compile(r".*router.*"), rule(None, -2)),
        (re.compile(r".*w_qkv$"), rule(-1, -2)),             # (.., D, q+2kv): TP out
        (re.compile(r".*w_o$"), rule(-2, -1)),               # (.., q, D): TP in
        (re.compile(r".*w_(gate|up)$"), rule(-1, -2)),       # (.., D, F)
        (re.compile(r".*wi$"), rule(-1, -2)),
        (re.compile(r".*w_down$"), rule(-2, -1)),            # (.., F, D)
        (re.compile(r".*wo$"), rule(-2, -1)),
        (re.compile(r".*in_proj$"), rule(-1, -2)),           # ssm
        (re.compile(r".*out_proj$"), rule(-2, -1)),
        (re.compile(r".*conv$"), rule(-1)),                  # depthwise (w, d_inner)
    ]


def leaf_pspec(path: str, ndim: int, fsdp: bool = False,
               dp_axes=("data",)) -> P:
    """Megatron-style PartitionSpec for one *master-weight* leaf, resolved
    from its '/'-joined tree path (later rules win). This is the single
    source of the dense sharding rules: ``params_pspecs`` maps it over a
    tree, and the execution-plan compiler records it per plan row for every
    leaf a binary backend does not claim."""
    rules = _pspec_rules(bool(fsdp), tuple(dp_axes))
    chosen = P()
    for pat, build in rules:
        if pat.fullmatch(path):
            chosen = build(ndim) if ndim else P()
    # sanity: spec rank must not exceed leaf rank
    if len(chosen) > ndim:
        chosen = P(*list(chosen)[:ndim])
    return chosen


def params_pspecs(params, fsdp: bool = False, dp_axes=("data",)):
    """PartitionSpec tree matching ``params`` by path patterns.

    ``dp_axes``: the data-parallel mesh axes FSDP shards over — on the
    multi-pod mesh this must include "pod" (32-way ZeRO-3, not 16)."""

    def spec_for(path, leaf):
        from repro.core.binarize import _path_str

        return leaf_pspec(_path_str(path), getattr(leaf, "ndim", 0),
                          fsdp=fsdp, dp_axes=dp_axes)

    return jax.tree_util.tree_map_with_path(spec_for, params)


def shardings_from_pspecs(mesh: Mesh, pspecs):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        pspecs,
        is_leaf=lambda x: isinstance(x, P),
    )


# ---------------------------------------------------------------------------
# Serving-tree placement: put a packed parameter tree on a mesh, following
# the sharding column of a compiled ExecutionPlan (repro.engine.plan).
# ---------------------------------------------------------------------------

def spec_to_json(spec) -> list:
    """``PartitionSpec`` -> JSON-stable list (entries: None | str | [str..]).
    Inverse of :func:`spec_from_json`."""
    return [list(e) if isinstance(e, (tuple, list)) else e for e in spec]


def spec_from_json(entries) -> P:
    return P(*[tuple(e) if isinstance(e, list) else e for e in entries])


def _serving_leaf_types():
    from repro.engine import registry

    return registry.serving_leaf_types()


def serving_leaf_pspec(path: str, leaf) -> P:
    """PartitionSpec for one *serving-tree* leaf (plan-free fallback).

    Consults the backend registry, so user-registered backends behave like
    the built-ins: a serving leaf whose backend declares a ``tp_dim``
    shards that master dim over "model" (for the bitpacked built-ins, the
    out-channel / N dim — never the word (K//32) dim, so a 32-bit lane
    group is never split across devices). Plain arrays, and serving leaves
    whose backend declares no ``tp_dim``, follow the Megatron path rules
    (:func:`leaf_pspec`)."""
    from repro.engine import registry

    from repro.core.policy import is_conv_kernel

    spec = registry.spec_for_serving_leaf(leaf)
    tp_dim = spec.tp_dim if spec is not None else None
    if tp_dim is None and is_conv_kernel(path) and \
            getattr(leaf, "ndim", 0) == 4:
        # conv-stack kernels stay plain arrays under the binarized_dense
        # backend (and dense), so the registry cannot identify them by
        # type; TP-shard the out-channel dim like compile_plan records for
        # binarized_dense (a valid conv sharding for dense masters too)
        tp_dim = -1
    if tp_dim is not None:
        shape = getattr(leaf, "master_shape", getattr(leaf, "shape", ()))
        spec = tp_spec(tp_dim, len(shape))
        if spec is not None:
            return spec
    return leaf_pspec(path, getattr(leaf, "ndim", 0))


def _adapt_spec(spec: P, ndim: int) -> P:
    """Fit a master-shape spec onto an array of rank ``ndim`` by keeping the
    TRAILING entries (serving layouts collapse *leading* master dims: an
    XnorConv packs (kh, kw, C, N) into 2-D (words, N), stacked linears keep
    their lead dims). The out-channel dim is last in every layout, so the
    trailing alignment preserves the TP assignment exactly."""
    entries = list(spec)
    if len(entries) > ndim:
        entries = entries[len(entries) - ndim:]
    return P(*entries)


def _place_serving_node(mesh: Mesh, spec: P, node, types=None):
    """device_put one plan row's serving node (packed leaf or plain array)
    under its master-shape spec, rank-adapting to each stored array."""
    def put(a):
        if a is None or not hasattr(a, "ndim"):
            return a
        s = _adapt_spec(spec, a.ndim)
        return jax.device_put(a, NamedSharding(mesh, s))

    if isinstance(node, types if types is not None
                  else _serving_leaf_types()):
        # generic over any registered pytree node class: place each stored
        # array, keep the node's static aux data
        kids, treedef = jax.tree_util.tree_flatten(node)
        return jax.tree_util.tree_unflatten(
            treedef, [put(a) for a in kids])
    return put(node)


def place_packed_params(mesh: Mesh, params, plan=None):
    """Place a (possibly packed) parameter tree on ``mesh``.

    With ``plan`` (a compiled :class:`repro.engine.ExecutionPlan`), each
    leaf follows its plan row's recorded sharding column; without one (or
    for v1-manifest rows), specs are re-derived from leaf types and paths
    (:func:`serving_leaf_pspec`) — equivalent for every typed serving leaf,
    while plain-array 4-D conv kernels uniformly TP-shard the out-channel
    dim (the ``binarized_dense`` rule; a dense-backend conv row's recorded
    column may instead be replicated — both placements are correct, the
    plan's is authoritative when given). Packed int32 weight words are always
    sharded on the out-channel dim over "model" (never splitting a 32-bit
    lane group); per-channel scales follow their N dim; dense leaves follow
    the Megatron rules. Axes named in a spec but absent from ``mesh`` are
    dropped (a "model"-annotated plan placed on a data-only mesh simply
    replicates those dims)."""
    from repro.core.binarize import _path_str

    types = _serving_leaf_types()                 # one registry walk, not
    is_leaf = lambda x: isinstance(x, types)      # noqa: E731 — per node
    nodes = jax.tree_util.tree_leaves_with_path(params, is_leaf=is_leaf)
    row_spec = {}
    if plan is not None:
        if len(plan.layers) != len(nodes):
            raise ValueError(
                f"plan/params mismatch: plan has {len(plan.layers)} rows, "
                f"tree has {len(nodes)} leaves")
        row_spec = {a.path: a.pspec for a in plan.layers}
    out = []
    for path, node in nodes:
        s = _path_str(path)
        if plan is not None and s not in row_spec:
            raise ValueError(
                f"plan/params mismatch: tree leaf {s!r} has no plan row "
                f"(the plan was compiled for a different tree)")
        # a v1-manifest row carries no sharding column (pspec None):
        # re-derive from the leaf type / path, same rules as compile
        spec = row_spec.get(s)
        if spec is None:
            spec = serving_leaf_pspec(s, node)
        spec = sanitize_spec(mesh, spec,
                             getattr(node, "master_shape",
                                     getattr(node, "shape", ())))
        out.append(_place_serving_node(mesh, spec, node, types))
    treedef = jax.tree_util.tree_structure(params, is_leaf=is_leaf)
    return jax.tree_util.tree_unflatten(treedef, out)


def tp_spec(tp_dim: int, ndim: int) -> Optional[P]:
    """"model"-on-one-dim spec for a backend's registered ``tp_dim`` (None
    when the leaf is not matmul-shaped). The single construction both the
    plan compiler (``engine.plan._row_sharding``) and the plan-free
    placement fallback (:func:`serving_leaf_pspec`) use, so the two paths
    cannot diverge."""
    if ndim < 2:
        return None
    entries = [None] * ndim
    entries[tp_dim % ndim] = "model"
    return P(*entries)


def sanitize_spec(mesh: Mesh, spec: P, shape) -> P:
    """Drop spec axes a concrete mesh cannot honour: axis names missing
    from the mesh, dims not divisible by their axis size (placement stays
    correct — those dims replicate), and entries beyond the array rank."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    out = []
    for i, e in enumerate(spec):
        if i >= len(shape):     # spec longer than the array: truncate
            break
        axes = e if isinstance(e, (tuple, list)) else (e,)
        axes = [a for a in axes if a is not None and a in sizes]
        n = 1
        for a in axes:
            n *= sizes[a]
        if not axes or shape[i] % n != 0:
            out.append(None)
        elif len(axes) == 1:
            out.append(axes[0])
        else:
            out.append(tuple(axes))
    return P(*out)


def divisibility_report(cfg, n_model: int = 16) -> dict:
    """Which dims shard cleanly over the model axis (documented invariant)."""
    return {
        "d_ff": cfg.d_ff % n_model == 0 if cfg.d_ff else True,
        "q_dim": cfg.q_dim % n_model == 0 if cfg.has_attention else True,
        "kv_dim": cfg.kv_dim % n_model == 0 if cfg.has_attention else True,
        "d_inner": (cfg.d_inner % n_model == 0) if cfg.ssm_state else True,
        "experts": (cfg.n_experts % n_model == 0) if cfg.n_experts else True,
        "vocab": cfg.vocab_size % n_model == 0,
    }
