"""Sharding rules: logical-axis -> mesh-axis mapping (DP / TP / FSDP / EP / SP).

Models are written against *logical* activation/parameter axes and call
``ShardCtx.act(x, kind)`` at block boundaries; the context resolves the kind
to a ``PartitionSpec`` for the active mesh (or no-ops on a single device, so
smoke tests never touch device state).

Conventions (single-pod mesh ("data", "model"), multi-pod ("pod", "data",
"model")):

* batch dims           -> ("pod", "data")                  [DP]
* d_ff / expert dims   -> "model"                          [Megatron TP —
  d_ff % 16 == 0 holds for every assigned arch; asserted in tests]
* flattened heads*hd   -> "model"  (avoids head-count divisibility issues
  for the 24/40/56-head archs)
* experts              -> "model" when n_experts % 16 == 0 else unsharded
* KV-cache             -> batch over "data", sequence over "model"
  (flash-decoding-style sharded attention; XLA inserts the softmax combine)
* params               -> TP dim over "model"; with FSDP also shard the
  largest replicated dim over "data" (ZeRO-3)
"""
from __future__ import annotations

import dataclasses
import re
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def batch_axes(mesh: Optional[Mesh]) -> tuple:
    if mesh is None:
        return ("data",)
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def mesh_context(mesh: Mesh):
    """Context manager activating ``mesh`` as the ambient mesh.

    Spans the jax API change: ``jax.set_mesh`` (jax >= 0.5-era) vs entering
    the ``Mesh`` object itself (jax 0.4.x)."""
    set_mesh = getattr(jax, "set_mesh", None)
    if set_mesh is not None:
        return set_mesh(mesh)
    return mesh


@dataclasses.dataclass
class ShardCtx:
    """Activation-sharding helper threaded through model code."""

    mesh: Optional[Mesh] = None
    enable: bool = True

    def _p(self, *spec) -> Optional[P]:
        return P(*spec)

    def act(self, x: jax.Array, kind: str) -> jax.Array:
        """Applies a with_sharding_constraint for a logical activation kind."""
        if not self.enable or self.mesh is None:
            return x
        dp = batch_axes(self.mesh)
        specs = {
            # Residual stream: seq over "model" = Megatron sequence
            # parallelism — GSPMD inserts the SP all-gather before each
            # TP block and the reduce-scatter after it, and the per-layer
            # scan carry (the remat-saved activation) shrinks by the TP
            # degree. See EXPERIMENTS.md §Perf iteration 1.
            "btd": P(dp, "model", None),       # (batch, seq, d_model)
            "btf": P(dp, None, "model"),       # (batch, seq, d_ff)
            "btq": P(dp, None, "model"),       # (batch, seq, heads*hd)
            "bthd": P(dp, None, "model", None),# (batch, seq, heads, hd)
            "btv": P(dp, None, "model"),       # logits (vocab TP-sharded)
            "bte": P(dp, None, None),          # router logits (small)
            "ecd": P(None, dp, "model"),       # MoE buffer (E, cap, d)
            "ecf": P(None, dp, "model"),       # MoE hidden (E, cap, f)
            "a": P(dp),                        # MoE assignment vectors (T*k,)
            "ad": P(dp, "model"),              # MoE per-assignment acts
            "btn": P(dp, None, "model"),       # ssm inner (batch, seq, d_inner)
            "bsh": P(dp, None, "model"),       # ssm dt (batch, seq, heads)
            "bcqqh": P(dp, None, None, None, "model"),  # SSD decay blocks
            "bchpn": P(dp, None, "model", None, None),  # SSD chunk states
            "cache_kv": P(None, dp, "model", None, None),  # (L, B, S, kv, hd)
            "ssm_state": P(None, dp, "model", None, None), # (L, B, heads, hp, N)
        }
        spec = specs.get(kind)
        if spec is None:
            return x
        spec = P(*spec[: x.ndim])
        try:
            return jax.lax.with_sharding_constraint(x, NamedSharding(self.mesh, spec))
        except (ValueError, TypeError):
            return x


# ---------------------------------------------------------------------------
# Parameter PartitionSpecs, generated from tree paths by pattern rules.
# ---------------------------------------------------------------------------

# (path regex, spec builder given ndim). Later rules win.
def _pspec_rules(fsdp: bool, dp_axes=("data",)):
    dp = dp_axes if len(dp_axes) > 1 else dp_axes[0]

    def rule(last_model_dim, fsdp_dim=None):
        def build(ndim: int):
            spec = [None] * ndim
            if last_model_dim is not None:
                spec[last_model_dim % ndim] = "model"
            if fsdp and fsdp_dim is not None and (fsdp_dim % ndim) != (
                    (last_model_dim or 0) % ndim if last_model_dim is not None else -99):
                spec[fsdp_dim % ndim] = dp
            return P(*spec)
        return build

    return [
        (re.compile(r".*embed.*"), rule(-1, -2)),           # (V, D): TP on D? keep V
        (re.compile(r".*lm_head.*"), rule(-1, -2)),          # (D, V): vocab TP
        (re.compile(r".*(scale|gamma|beta|bias|A_log|dt_bias|D)$"), rule(None)),
        (re.compile(r".*router.*"), rule(None, -2)),
        (re.compile(r".*w_qkv$"), rule(-1, -2)),             # (.., D, q+2kv): TP out
        (re.compile(r".*w_o$"), rule(-2, -1)),               # (.., q, D): TP in
        (re.compile(r".*w_(gate|up)$"), rule(-1, -2)),       # (.., D, F)
        (re.compile(r".*wi$"), rule(-1, -2)),
        (re.compile(r".*w_down$"), rule(-2, -1)),            # (.., F, D)
        (re.compile(r".*wo$"), rule(-2, -1)),
        (re.compile(r".*in_proj$"), rule(-1, -2)),           # ssm
        (re.compile(r".*out_proj$"), rule(-2, -1)),
        (re.compile(r".*conv$"), rule(-1)),                  # depthwise (w, d_inner)
    ]


def params_pspecs(params, fsdp: bool = False, dp_axes=("data",)):
    """PartitionSpec tree matching ``params`` by path patterns.

    ``dp_axes``: the data-parallel mesh axes FSDP shards over — on the
    multi-pod mesh this must include "pod" (32-way ZeRO-3, not 16)."""
    rules = _pspec_rules(fsdp, dp_axes)

    def spec_for(path, leaf):
        from repro.core.binarize import _path_str

        s = _path_str(path)
        ndim = getattr(leaf, "ndim", 0)
        chosen = P()
        for pat, build in rules:
            if pat.fullmatch(s):
                chosen = build(ndim) if ndim else P()
        # sanity: spec rank must not exceed leaf rank
        if len(chosen) > ndim:
            chosen = P(*list(chosen)[:ndim])
        return chosen

    return jax.tree_util.tree_map_with_path(spec_for, params)


def shardings_from_pspecs(mesh: Mesh, pspecs):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        pspecs,
        is_leaf=lambda x: isinstance(x, P),
    )


def divisibility_report(cfg, n_model: int = 16) -> dict:
    """Which dims shard cleanly over the model axis (documented invariant)."""
    return {
        "d_ff": cfg.d_ff % n_model == 0 if cfg.d_ff else True,
        "q_dim": cfg.q_dim % n_model == 0 if cfg.has_attention else True,
        "kv_dim": cfg.kv_dim % n_model == 0 if cfg.has_attention else True,
        "d_inner": (cfg.d_inner % n_model == 0) if cfg.ssm_state else True,
        "experts": (cfg.n_experts % n_model == 0) if cfg.n_experts else True,
        "vocab": cfg.vocab_size % n_model == 0,
    }
