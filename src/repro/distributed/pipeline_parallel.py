"""GPipe-style pipeline parallelism with ``shard_map`` + ``ppermute``.

Layers are split into S stages along a ``stage`` mesh axis; a step streams M
microbatches through the stages in S + M - 1 ticks. Per tick every device
runs its stage on its current activation and forwards the result to the next
stage with ``lax.ppermute`` (the collective-permute on the TPU ICI torus —
neighbour exchange, the cheapest possible collective), overlapping each
stage's compute with its neighbour's: the canonical compute/comm-overlap
trick at pod scale.

The implementation is deliberately self-contained (activation-shape-
preserving stage fns) — it is used by tests and the PP example, and is the
config-selectable alternative to pure DPxTP for deep archs (80-layer
internvl2 / 72-layer jamba) where TP collectives saturate before compute.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

# jax.shard_map graduated from jax.experimental around 0.6; support both.
_shard_map = getattr(jax, "shard_map", None)
if _shard_map is None:
    from jax.experimental.shard_map import shard_map as _shard_map


def pipeline_forward(
    stage_fn: Callable,          # (stage_params, x) -> y  (same shape)
    n_stages: int,
    axis_name: str = "stage",
):
    """Builds the per-device pipelined forward to run under ``shard_map``.

    Call with stage-stacked params (leading dim = n_stages, sharded over the
    stage axis, one slice per device) and microbatched input
    (n_micro, mb, ...) replicated per stage; returns (n_micro, mb, ...)
    outputs valid on the *last* stage (other stages return zeros)."""

    def per_device(stage_params, micro):  # micro: (n_micro, mb, ...)
        stage_params = jax.tree.map(lambda a: a[0], stage_params)  # local slice
        stage = jax.lax.axis_index(axis_name)
        n_micro = micro.shape[0]
        ticks = n_micro + n_stages - 1
        perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]

        def body(t, carry):
            buf, outputs = carry
            # stage 0 ingests microbatch t (when in range); others use buf
            mb_idx = jnp.clip(t, 0, n_micro - 1)
            inp = jnp.where(stage == 0, micro[mb_idx], buf)
            out = stage_fn(stage_params, inp)
            # last stage emits microbatch (t - (n_stages - 1))
            out_idx = jnp.clip(t - (n_stages - 1), 0, n_micro - 1)
            emit = jnp.logical_and(stage == n_stages - 1,
                                   t >= n_stages - 1)
            outputs = jax.lax.cond(
                emit,
                lambda o: o.at[out_idx].set(out),
                lambda o: o,
                outputs)
            # forward activations to the next stage
            buf = jax.lax.ppermute(out, axis_name, perm)
            return buf, outputs

        buf0 = jnp.zeros_like(micro[0])
        outs0 = jnp.zeros_like(micro)
        _, outputs = jax.lax.fori_loop(0, ticks, body, (buf0, outs0))
        return outputs

    return per_device


def run_pipeline(mesh: Mesh, stage_fn: Callable, stage_params, micro,
                 axis_name: str = "stage"):
    """Convenience wrapper: shard_map the pipelined forward over ``mesh``.

    ``stage_params`` leaves have leading dim n_stages; ``micro`` is
    (n_micro, mb, ...). Returns (n_micro, mb, ...) gathered outputs."""
    n_stages = mesh.shape[axis_name]
    fwd = pipeline_forward(stage_fn, n_stages, axis_name)
    pspec_params = jax.tree.map(lambda _: P(axis_name), stage_params)
    import inspect
    sig = inspect.signature(_shard_map).parameters
    # the replication-check kwarg was renamed check_rep -> check_vma
    check_kw = {"check_vma": False} if "check_vma" in sig else {"check_rep": False}
    out = _shard_map(
        fwd, mesh=mesh,
        in_specs=(pspec_params, P()),
        out_specs=P(axis_name),   # (stage, n_micro, mb, ...): last stage valid
        **check_kw,
    )(stage_params, micro)
    # out has a leading stage axis from out_specs; take the last stage's copy
    n_micro = micro.shape[0]
    return out.reshape((n_stages, n_micro) + micro.shape[1:])[-1]


def reference_forward(stage_fn: Callable, stage_params, micro):
    """Serial oracle: apply all stages to every microbatch."""
    n_stages = jax.tree.leaves(stage_params)[0].shape[0]

    def one(x):
        for s in range(n_stages):
            p = jax.tree.map(lambda a: a[s], stage_params)
            x = stage_fn(p, x)
        return x

    return jax.vmap(one)(micro)
