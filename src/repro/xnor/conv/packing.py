"""Geometry, weight layout and byte accounting for the XNOR conv engine.

im2col lowering: a (B, H, W, C) NHWC activation convolved with a
(kh, kw, C, N) HWIO kernel is a (B*OH*OW, K) x (K, N) matmul with
K = kh*kw*C, so the binary conv reuses the ``repro.xnor`` popcount-GEMM
machinery once patches are sign-binarized and bitpacked.

Word layout ("per-tap"): the contraction axis flattens in (kh, kw, C) order
and each spatial tap's C channels are padded *independently* up to a whole
number of 32-bit words (``cw = ceil(C/32)``), so tap t owns words
``[t*cw, (t+1)*cw)``. Two consequences:

* channels pack once per input pixel (the word for pixel (y, x) is the same
  in every patch that covers it), which is what makes the fused Pallas patch
  kernel cheap, and
* the channel-pad bits are 0 on both operands (activations pad with 0,
  :func:`pack_conv_kernel` pads weights with -1 -> bit 0), so they XOR to 0
  and drop out of ``dot = K - 2*popcount`` with K the *true* kh*kw*C.

SAME-padding correction: spatially zero-padded border pixels do NOT
self-cancel — their activation bit is 0 (≡ -1) while the weight bit is the
real sign bit, so the raw formula counts ``-sign(w)`` where dense zero-padded
convolution counts 0. Equivalently, a border pixel's *effective* contraction
length is ``K_eff = K - P*C`` (P out-of-bounds taps). The exact fix is
additive and depends only on the output coordinate and the weights:

    dot_true[(i,j), n] = dot_raw[(i,j), n] + sum_{t in padded(i,j)} wsum[t, n]
    wsum[t, n]         = sum_c sign(w)[t, c, n] = 2*popcount(tap t words) - C

:func:`border_correction` builds that (OH*OW, N) table from the packed
weights alone (a popcount plus a tiny mask matmul); the oracle in ``ref.py``
proves the corrected output equals dense zero-padded sign-conv exactly.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.packing import PACK
from repro.kernels import ops as kops


def conv_geometry(h: int, w: int, ksize, stride, padding):
    """Static conv geometry, XLA semantics: (oh, ow, ((ph0,ph1),(pw0,pw1)))."""
    kh, kw = ksize
    sh, sw = stride
    if padding == "SAME":
        oh, ow = -(-h // sh), -(-w // sw)
        pth = max((oh - 1) * sh + kh - h, 0)
        ptw = max((ow - 1) * sw + kw - w, 0)
        pads = ((pth // 2, pth - pth // 2), (ptw // 2, ptw - ptw // 2))
    elif padding == "VALID":
        oh, ow = (h - kh) // sh + 1, (w - kw) // sw + 1
        pads = ((0, 0), (0, 0))
    else:
        (ph0, ph1), (pw0, pw1) = padding
        oh = (h + ph0 + ph1 - kh) // sh + 1
        ow = (w + pw0 + pw1 - kw) // sw + 1
        pads = ((ph0, ph1), (pw0, pw1))
    if oh < 1 or ow < 1:
        raise ValueError(f"empty conv output for {(h, w)} k={ksize} s={stride}")
    return oh, ow, pads


def tap_words(c: int) -> int:
    """int32 words per spatial tap (channels padded to a word boundary)."""
    return (c + PACK - 1) // PACK


def patch_words(ksize, c: int) -> int:
    """Packed words per im2col patch row: kh*kw*ceil(C/32)."""
    return ksize[0] * ksize[1] * tap_words(c)


def conv_k(ksize, c: int) -> int:
    """True contraction length kh*kw*C (the K in ``K - 2*popcount``)."""
    return ksize[0] * ksize[1] * c


def pack_conv_kernel(w: jax.Array) -> jax.Array:
    """Eq.-1 binarize + bitpack a (kh, kw, C, N) kernel to (kh*kw*cw, N) int32
    in the per-tap word layout (channel pad bits are 0, i.e. -1)."""
    kh, kw, c, n = w.shape
    cpad = tap_words(c) * PACK - c
    wp = jnp.pad(w, ((0, 0), (0, 0), (0, cpad), (0, 0)), constant_values=-1.0)
    return kops.binarize_and_pack(wp.reshape(kh * kw * tap_words(c) * PACK, n))


def kernel_tap_sums(w_packed: jax.Array, ksize, c: int) -> jax.Array:
    """(kh*kw, N) int32: sum_c sign(w)[tap, c, n], read off the packed words.

    popcount counts the +1 bits; the per-tap channel pad bits are 0, so the
    -1 count uses the *true* C, not the padded word width."""
    kh, kw = ksize
    words = w_packed.reshape(kh * kw, tap_words(c), -1).astype(jnp.uint32)
    pc = jnp.sum(jax.lax.population_count(words).astype(jnp.int32), axis=1)
    return 2 * pc - c


def padding_mask(h: int, w: int, ksize, stride, padding) -> np.ndarray:
    """(OH*OW, kh*kw) int32: 1 where tap (dy, dx) of output pixel (i, j)
    reads a spatially zero-padded input position. Pure numpy (static)."""
    kh, kw = ksize
    sh, sw = stride
    oh, ow, ((ph0, _), (pw0, _)) = conv_geometry(h, w, ksize, stride, padding)
    rows = np.arange(oh)[:, None] * sh + np.arange(kh)[None, :] - ph0  # (OH,kh)
    cols = np.arange(ow)[:, None] * sw + np.arange(kw)[None, :] - pw0  # (OW,kw)
    row_bad = (rows < 0) | (rows >= h)
    col_bad = (cols < 0) | (cols >= w)
    mask = row_bad[:, None, :, None] | col_bad[None, :, None, :]
    return mask.reshape(oh * ow, kh * kw).astype(np.int32)


def border_correction(w_packed: jax.Array, h: int, w: int, ksize, stride,
                      padding, c: int) -> jax.Array | None:
    """(OH*OW, N) int32 to ADD to the raw popcount dot so zero-padded border
    taps contribute 0 instead of -sign(w). None when nothing is padded."""
    mask = padding_mask(h, w, ksize, stride, padding)
    if not mask.any():
        return None
    return jnp.einsum("pt,tn->pn", jnp.asarray(mask),
                      kernel_tap_sums(w_packed, ksize, c))


def conv_epilogue(dot: jax.Array, corr: jax.Array | None,
                  scale: jax.Array | None, out_dtype,
                  b: int, oh: int, ow: int, n: int) -> jax.Array:
    """Shared tail of both conv paths (ops + ref oracle): add the border
    correction, apply the per-channel scale, resolve out_dtype (int32, or
    f32 when scaled), reshape (B*OH*OW, N) -> NHWC."""
    dot = dot.reshape(b, oh * ow, n)
    if corr is not None:
        dot = dot + corr[None]
    if out_dtype is None:
        out_dtype = jnp.int32 if scale is None else jnp.float32
    out = dot
    if scale is not None:
        out = dot.astype(jnp.float32) * scale.astype(jnp.float32)
    return out.astype(out_dtype).reshape(b, oh, ow, n)


# ---------------------------------------------------------------------------
# byte accounting (the paper's HBM-traffic argument, conv edition)
# ---------------------------------------------------------------------------

def patch_nbytes_dense(b: int, oh: int, ow: int, ksize, c: int,
                       dtype_bytes: int = 2) -> int:
    """HBM bytes of the dense im2col patch matrix (bf16 by default)."""
    return b * oh * ow * conv_k(ksize, c) * dtype_bytes


def patch_nbytes_packed(b: int, oh: int, ow: int, ksize, c: int) -> int:
    """HBM bytes of the bitpacked patch matrix (16x less for C % 32 == 0)."""
    return b * oh * ow * patch_words(ksize, c) * 4
