"""Pallas TPU kernel for fused patch extraction + sign-binarize + bitpack.

``patch_pack_pallas`` turns a zero-padded (B, Hp, Wp, C) activation into the
bitpacked im2col matrix (B, OH, OW, kh*kw*ceil(C/32)) int32 in one pass, so
the full-width conv activation never round-trips through HBM between
binarization and the popcount GEMM — only the 1-bit packed patches leave the
chip (the conv analogue of ``xnor.kernel.sign_pack_pallas``).

The per-tap word layout (see ``xnor.conv.packing``) is what makes the fusion
cheap: channels pack per *pixel* once — word j of pixel (y, x) is the same in
every patch that covers that pixel — so the kernel packs the whole image to
(Hp, Wp, cw) words and then only *gathers* words per tap. Tap gathers use
static strided-window reshapes (slice [dy : dy+OH*s] -> (OH, s, ...) ->
[:, 0]), which lower to plain slices; the wrapper pads the image with s-1
slack rows/cols of zeros so every window is in range.

Grid is (B,): one program per image, the whole padded image resident in
VMEM. That is the right trade at the paper's CIFAR scale (the largest VGG
slab, 34x34x512 f32, is ~2.3 MB); bigger images would need an OH-blocked
grid, which the blocked popcount GEMM downstream already supports.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.compat import CompilerParams as _CompilerParams
from repro.core.packing import PACK


def _patch_pack_kernel(x_ref, o_ref, *, ksize, stride, oh: int, ow: int,
                       c: int):
    """(1, Hp, Wp, C) float -> (1, OH, OW, kh*kw*cw) int32 packed patches."""
    kh, kw = ksize
    sh, sw = stride
    cw = (c + PACK - 1) // PACK
    img = x_ref[0]                                   # (Hp, Wp, C)
    bits = (img > 0).astype(jnp.uint32)              # Eq. (1): x <= 0 -> bit 0
    if cw * PACK != c:                               # channel pad: bit 0
        bits = jnp.pad(bits, ((0, 0), (0, 0), (0, cw * PACK - c)))
    hp, wp = bits.shape[0], bits.shape[1]
    shifts = jnp.arange(PACK, dtype=jnp.uint32)
    words = jnp.sum(bits.reshape(hp, wp, cw, PACK) << shifts, axis=-1,
                    dtype=jnp.uint32)                # (Hp, Wp, cw): per pixel
    taps = []
    for dy in range(kh):
        for dx in range(kw):
            t = words[dy:dy + oh * sh, dx:dx + ow * sw]
            taps.append(t.reshape(oh, sh, ow, sw, cw)[:, 0, :, 0, :])
    o_ref[0] = jnp.concatenate(taps, axis=-1).astype(jnp.int32)


def patch_pack_pallas(
    xp: jax.Array,
    *,
    ksize,
    stride=(1, 1),
    oh: int,
    ow: int,
    interpret: bool = False,
) -> jax.Array:
    """Fused im2col + sign + bitpack over an already spatially zero-padded
    (B, Hp, Wp, C) input (``ops.py`` computes the padding, including the
    stride slack). Returns (B, OH, OW, kh*kw*ceil(C/32)) int32."""
    b, hp, wp, c = xp.shape
    kh, kw = ksize
    sh, sw = stride
    if hp < kh - 1 + oh * sh or wp < kw - 1 + ow * sw:
        raise ValueError(
            f"padded image {(hp, wp)} too small for k={ksize} s={stride} "
            f"out={(oh, ow)} (needs {(kh - 1 + oh * sh, kw - 1 + ow * sw)})")
    k32 = kh * kw * ((c + PACK - 1) // PACK)
    return pl.pallas_call(
        functools.partial(_patch_pack_kernel, ksize=ksize, stride=stride,
                          oh=oh, ow=ow, c=c),
        grid=(b,),
        in_specs=[pl.BlockSpec((1, hp, wp, c), lambda i: (i, 0, 0, 0))],
        out_specs=pl.BlockSpec((1, oh, ow, k32), lambda i: (i, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, oh, ow, k32), jnp.int32),
        interpret=interpret,
        compiler_params=_CompilerParams(dimension_semantics=("parallel",)),
    )(xp)
