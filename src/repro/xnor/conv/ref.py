"""Pure-jnp oracles for the XNOR conv engine (exact integer ground truth).

Mirrors ``xnor/ref.py``: straight-line jnp with no blocking, used by the
parity tests and as the portable fallback. All three views of the binary
convolution are exactly equal (integer arithmetic, no rounding):

  * ``xnor_conv2d_ref`` — packed im2col patches -> popcount GEMM -> border
    correction (what the kernel path computes)
  * ``sign_conv_ref``   — ``lax.conv(sign(x), sign(w))`` with zero padding
    in f32 (the semantic spec: padded border pixels contribute 0)
  * the Pallas path in ``xnor.conv.ops``
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.packing import PACK
from repro.xnor import packing as apack
from repro.xnor import ref as xref
from repro.xnor.conv.packing import (border_correction, conv_epilogue,
                                     conv_geometry, conv_k, tap_words)


def conv_patches_ref(x: jax.Array, ksize, stride=(1, 1),
                     padding="SAME") -> jax.Array:
    """Zero-filled im2col: (B, H, W, C) -> (B, OH, OW, kh*kw*C), taps in
    (kh, kw, C) order (the layout ``pack_conv_kernel`` flattens to)."""
    b, h, w, _ = x.shape
    kh, kw = ksize
    sh, sw = stride
    oh, ow, pads = conv_geometry(h, w, ksize, stride, padding)
    xp = jnp.pad(x, ((0, 0), pads[0], pads[1], (0, 0)))
    taps = [xp[:, dy:dy + (oh - 1) * sh + 1:sh, dx:dx + (ow - 1) * sw + 1:sw]
            for dy in range(kh) for dx in range(kw)]
    return jnp.concatenate(taps, axis=-1)


def sign_pack_patches_ref(x: jax.Array, ksize, stride=(1, 1),
                          padding="SAME") -> jax.Array:
    """Sign-binarize + bitpack patches in the per-tap word layout:
    (B, H, W, C) -> (B, OH, OW, kh*kw*ceil(C/32)) int32. Spatial zero pad
    and channel pad both carry sign bit 0."""
    c = x.shape[-1]
    kh, kw = ksize
    p = conv_patches_ref(x, ksize, stride, padding)
    b, oh, ow, _ = p.shape
    p = p.reshape(b, oh, ow, kh * kw, c)
    p = jnp.pad(p, ((0, 0),) * 4 + ((0, tap_words(c) * PACK - c),))
    return apack.pack_activations(
        p.reshape(b, oh, ow, kh * kw * tap_words(c) * PACK))


def xnor_conv2d_ref(x: jax.Array, w_packed: jax.Array,
                    scale: jax.Array | None = None, *, ksize, c_in: int,
                    stride=(1, 1), padding="SAME",
                    out_dtype=None) -> jax.Array:
    """End-to-end oracle: packed patches -> ``K - 2*popcount(xor)`` GEMM ->
    border correction [-> per-channel scale]. Integer-exact against
    :func:`sign_conv_ref` including SAME-padding borders."""
    b, h, w, _ = x.shape
    oh, ow, _ = conv_geometry(h, w, ksize, stride, padding)
    n = w_packed.shape[-1]
    a = sign_pack_patches_ref(x, ksize, stride, padding)
    dot = xref.xnor_matmul_ref(a.reshape(b * oh * ow, -1), w_packed,
                               conv_k(ksize, c_in))
    corr = border_correction(w_packed, h, w, ksize, stride, padding, c_in)
    return conv_epilogue(dot, corr, scale, out_dtype, b, oh, ow, n)


def sign_conv_ref(x: jax.Array, w: jax.Array, stride=(1, 1),
                  padding="SAME") -> jax.Array:
    """The semantic spec: ``conv(sign(x), sign(w))`` densely in f32, with
    signs taken BEFORE zero padding so border pixels contribute 0."""
    _, h, wd, _ = x.shape
    _, _, pads = conv_geometry(h, wd, w.shape[:2], stride, padding)
    xs = jnp.where(x > 0, 1.0, -1.0).astype(jnp.float32)
    ws = jnp.where(w > 0, 1.0, -1.0).astype(jnp.float32)
    return jax.lax.conv_general_dilated(
        xs, ws, window_strides=stride, padding=list(pads),
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
