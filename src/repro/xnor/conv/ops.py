"""Public jit'd wrappers for the XNOR conv engine.

Same contract as ``xnor/ops.py``: handle arbitrary static geometry (any
stride, SAME/VALID/explicit padding, ragged spatial dims, kh*kw*C not a
multiple of 32), pick interpret mode automatically off-TPU, and fall back to
the jnp oracles under ``use_pallas=False``. The popcount GEMM itself is the
existing ``xnor.ops.xnor_matmul_packed`` — this module only lowers conv onto
it: fused patch packing in front, exact zero-padding border correction
behind (see ``xnor.conv.packing`` for the correction math).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.compat import on_tpu as _on_tpu
from repro.xnor import ops as xops
from repro.xnor.conv import ref
from repro.xnor.conv.kernel import patch_pack_pallas
from repro.xnor.conv.packing import (border_correction, conv_epilogue,
                                     conv_geometry, conv_k, patch_words)


@functools.partial(jax.jit,
                   static_argnames=("ksize", "stride", "padding", "use_pallas"))
def sign_and_pack_patches(
    x: jax.Array,
    *,
    ksize,
    stride=(1, 1),
    padding="SAME",
    use_pallas: bool = True,
) -> jax.Array:
    """Fused sign-binarize + bitpack of im2col patches:
    (B, H, W, C) -> (B, OH, OW, kh*kw*ceil(C/32)) int32.

    The full-width activation never leaves the kernel unpacked; only the
    packed patch words are written back. Spatial zero padding and per-tap
    channel padding both carry sign bit 0 (see ``xnor.conv.packing``)."""
    b, h, w, c = x.shape
    kh, kw = ksize
    sh, sw = stride
    oh, ow, ((ph0, ph1), (pw0, pw1)) = conv_geometry(h, w, ksize, stride,
                                                     padding)
    if not use_pallas:
        return ref.sign_pack_patches_ref(x, ksize, stride, padding)
    # Stride slack: the kernel's windowed reshape reads [dy, dy + OH*sh) —
    # up to sh-1 rows past the last tap — so over-pad with zeros (bit 0,
    # never selected into a patch).
    eh = max(0, kh - 1 + oh * sh - (h + ph0 + ph1))
    ew = max(0, kw - 1 + ow * sw - (w + pw0 + pw1))
    xp = jnp.pad(x, ((0, 0), (ph0, ph1 + eh), (pw0, pw1 + ew), (0, 0)))
    return patch_pack_pallas(xp, ksize=ksize, stride=stride, oh=oh, ow=ow,
                             interpret=not _on_tpu())


def xnor_conv2d(
    x: jax.Array,
    w_packed: jax.Array,
    scale: jax.Array | None = None,
    *,
    ksize,
    c_in: int,
    stride=(1, 1),
    padding="SAME",
    out_dtype=None,
    use_pallas: bool = True,
) -> jax.Array:
    """Fully-binary 2-D convolution, NHWC x (packed HWIO) -> NHWC.

    ``x`` is a real-valued (or already ±1) activation; ``w_packed`` is a
    ``pack_conv_kernel``-layout (kh*kw*ceil(c_in/32), N) int32 weight.
    Exactly equals ``conv(sign(x), sign(w))`` with zero padding (integers,
    no rounding — border pixels contribute 0, not -1), optionally times a
    per-output-channel ``scale``. ``out_dtype`` defaults to int32, or f32
    when a scale is applied."""
    return _xnor_conv2d(x, w_packed, scale, ksize=tuple(ksize), c_in=c_in,
                        stride=tuple(stride), padding=padding,
                        out_dtype=out_dtype, use_pallas=use_pallas)


@functools.partial(
    jax.jit, static_argnames=("ksize", "c_in", "stride", "padding",
                              "out_dtype", "use_pallas"))
def _xnor_conv2d(
    x: jax.Array,
    w_packed: jax.Array,
    scale: jax.Array | None,
    *,
    ksize,
    c_in: int,
    stride,
    padding,
    out_dtype,
    use_pallas: bool,
) -> jax.Array:
    b, h, w, c = x.shape
    if c != c_in:
        raise ValueError(f"x has C={c}, packed kernel expects C={c_in}")
    if w_packed.shape[0] != patch_words(ksize, c_in):
        raise ValueError(
            f"w_packed has {w_packed.shape[0]} words, layout needs "
            f"{patch_words(ksize, c_in)} (k={ksize}, C={c_in})")
    n = w_packed.shape[-1]
    oh, ow, _ = conv_geometry(h, w, ksize, stride, padding)
    a = sign_and_pack_patches(x, ksize=ksize, stride=stride, padding=padding,
                              use_pallas=use_pallas)
    dot = xops.xnor_matmul_packed(a.reshape(b * oh * ow, -1), w_packed,
                                  None, k=conv_k(ksize, c_in),
                                  use_pallas=use_pallas,
                                  allow_extra_words=True)
    corr = border_correction(w_packed, h, w, ksize, stride, padding, c_in)
    return conv_epilogue(dot, corr, scale, out_dtype, b, oh, ow, n)
