"""XNOR-popcount binary 2-D convolution engine (the paper's CIFAR-10 path).

Lowers convolution onto the fully-binary GEMM in ``repro.xnor``: a fused
Pallas kernel sign-binarizes and bitpacks im2col patches along the kh*kw*C
contraction axis (per-tap word layout), the dot runs on the existing
``K - 2*popcount(xor)`` kernel, and an exact additive correction restores
zero-padding semantics at SAME borders (padded pixels contribute 0, not -1).

Modules
  packing   geometry, per-tap weight layout, border-correction math, bytes
  kernel    Pallas fused patch-extraction + sign + bitpack
  ref       pure-jnp oracles (exact integer ground truth)
  ops       jit'd public wrappers (``xnor_conv2d``, ``sign_and_pack_patches``)
"""
from repro.xnor.conv.ops import sign_and_pack_patches, xnor_conv2d
from repro.xnor.conv.packing import (border_correction, conv_geometry, conv_k,
                                     pack_conv_kernel, patch_nbytes_dense,
                                     patch_nbytes_packed, patch_words)

__all__ = [
    "xnor_conv2d", "sign_and_pack_patches", "pack_conv_kernel",
    "conv_geometry", "conv_k", "patch_words", "border_correction",
    "patch_nbytes_dense", "patch_nbytes_packed",
]
