"""Pallas TPU kernels for the fully-binary compute path.

Two kernels, mirroring the paper's FPGA pipeline:

* ``sign_pack_pallas`` — fused sign-binarize (Eq. 1) + bitpack of activations
  along the last axis, ``(M, K) f32/bf16 -> (M, K//32) int32``. Fusing the
  two means the full-width activation never round-trips through HBM between
  binarization and the matmul: only the 1-bit packed words leave the chip.

* ``xnor_matmul_pallas`` — the XNOR-popcount matmul over packed operands:

      dot[m, n] = K - 2 * sum_j popcount(a[m, j] XOR w[j, n])

  with an int32 VMEM accumulator carried across the K grid dimension. This
  is pure VPU integer work (XOR + popcount + add) — the TPU analogue of the
  paper's DSP-free XNOR/popcount datapath; no MXU, no floating point until
  the optional per-channel scale at flush.

Layouts: a_packed (M, K//32) int32   (xnor.packing — packed along last axis)
         w_packed (K//32, N) int32   (core.packing — packed along first axis)
         out      (M, N)     int32, or f32 when a scale is fused.

``k_total`` is the *true* contraction length: 0-bit padding on both operands
XORs to 0, contributes nothing to the popcount, and drops out of the formula
(see xnor.packing). Block constraints: block_m multiple of 8, block_k a
multiple of 32 with block_k//32 words per a-block sublane row; on real TPUs
prefer block_k >= 512 so the packed lane dimension stays reasonably wide.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.compat import CompilerParams as _CompilerParams
from repro.core.packing import PACK


def _block_popcount_dot(a_words: jax.Array, w_words: jax.Array) -> jax.Array:
    """(bm, bk32) x (bk32, bn) packed words -> (bm, bn) int32 XOR-popcount sum."""
    x = jnp.bitwise_xor(a_words.astype(jnp.uint32)[:, :, None],
                        w_words.astype(jnp.uint32)[None, :, :])
    return jnp.sum(jax.lax.population_count(x).astype(jnp.int32), axis=1)


def _xnor_kernel(a_ref, w_ref, o_ref, acc_ref, *, nk: int, k_total: int):
    """Grid (i, j, k): accumulate popcounts into acc; emit K - 2*acc at k end."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += _block_popcount_dot(a_ref[...], w_ref[...])

    @pl.when(k == nk - 1)
    def _flush():
        o_ref[...] = (k_total - 2 * acc_ref[...]).astype(o_ref.dtype)


def _xnor_scaled_kernel(a_ref, w_ref, s_ref, o_ref, acc_ref, *, nk: int,
                        k_total: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += _block_popcount_dot(a_ref[...], w_ref[...])

    @pl.when(k == nk - 1)
    def _flush():
        dot = (k_total - 2 * acc_ref[...]).astype(jnp.float32)
        o_ref[...] = (dot * s_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


def xnor_matmul_pallas(
    a_packed: jax.Array,
    w_packed: jax.Array,
    scale: jax.Array | None = None,
    *,
    k_total: int,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 512,
    out_dtype=None,
    interpret: bool = False,
) -> jax.Array:
    """Blocked XNOR-popcount matmul. Shapes must divide the block sizes
    (the jit wrapper in ``ops.py`` pads arbitrary shapes first)."""
    m, k32 = a_packed.shape
    k32w, n = w_packed.shape
    if k32 != k32w:
        raise ValueError(f"packed K mismatch: a has {k32} words, w has {k32w}")
    if block_k % PACK:
        raise ValueError("block_k must be a multiple of 32")
    bk32 = block_k // PACK
    if m % block_m or n % block_n or k32 % bk32:
        raise ValueError(
            f"packed shape ({m},{k32})x({k32w},{n}) not divisible by blocks "
            f"({block_m},{bk32},{block_n}); use ops.xnor_matmul")
    if out_dtype is None:
        out_dtype = jnp.int32 if scale is None else jnp.float32

    nk = k32 // bk32
    grid = (m // block_m, n // block_n, nk)
    a_spec = pl.BlockSpec((block_m, bk32), lambda i, j, k: (i, k))
    w_spec = pl.BlockSpec((bk32, block_n), lambda i, j, k: (k, j))
    o_spec = pl.BlockSpec((block_m, block_n), lambda i, j, k: (i, j))
    scratch = [pltpu.VMEM((block_m, block_n), jnp.int32)]

    if scale is None:
        kern = functools.partial(_xnor_kernel, nk=nk, k_total=k_total)
        in_specs = [a_spec, w_spec]
        args = (a_packed, w_packed)
    else:
        kern = functools.partial(_xnor_scaled_kernel, nk=nk, k_total=k_total)
        s_spec = pl.BlockSpec((1, block_n), lambda i, j, k: (0, j))
        in_specs = [a_spec, w_spec, s_spec]
        args = (a_packed, w_packed, scale.reshape(1, n))

    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=in_specs,
        out_specs=o_spec,
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=scratch,
        interpret=interpret,
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
    )(*args)


def _sign_pack_kernel(x_ref, o_ref, *, bk: int):
    """(bm, bk) float -> (bm, bk//32) int32: Eq. (1) sign bit, packed lanes."""
    bm = x_ref.shape[0]
    ones = (x_ref[...] > 0).astype(jnp.uint32)
    bits = ones.reshape(bm, bk // PACK, PACK)
    shifts = jnp.arange(PACK, dtype=jnp.uint32)[None, None, :]
    o_ref[...] = jnp.sum(bits << shifts, axis=2, dtype=jnp.uint32).astype(
        jnp.int32)


def sign_pack_pallas(
    x: jax.Array,
    *,
    block_m: int = 128,
    block_k: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """Fused sign-binarize + bitpack: (M, K) -> (M, K//32) int32.
    M % block_m == 0, K % block_k == 0, block_k % 32 == 0 (ops.py pads)."""
    m, kdim = x.shape
    if m % block_m or kdim % block_k or block_k % PACK:
        raise ValueError(f"bad blocks ({block_m},{block_k}) for shape {(m, kdim)}")
    grid = (m // block_m, kdim // block_k)
    x_spec = pl.BlockSpec((block_m, block_k), lambda i, j: (i, j))
    o_spec = pl.BlockSpec((block_m, block_k // PACK), lambda i, j: (i, j))
    return pl.pallas_call(
        functools.partial(_sign_pack_kernel, bk=block_k),
        grid=grid,
        in_specs=[x_spec],
        out_specs=o_spec,
        out_shape=jax.ShapeDtypeStruct((m, kdim // PACK), jnp.int32),
        interpret=interpret,
    )(x)
