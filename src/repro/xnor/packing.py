"""Bitpacking of binary activations along the contraction (last) axis.

``core.packing`` stores weights ``(K, N) -> (K//32, N)``: packed along the
*leading* axis so the MXU-facing unpack stays lane-contiguous. Activations
contract along their *last* axis, so here ``(M, K) -> (M, K//32)``: bit ``b``
of word ``[m, j]`` holds the sign of ``x[m, j*32 + b]`` (+1 -> 1, <=0 -> 0 —
the Eq. (1) convention, identical to ``core.packing.pack_bits``).

With both operands packed this way, word ``a[m, j]`` and word ``w[j, n]``
cover the same 32 contraction positions, so the binary dot product is

    dot[m, n] = K - 2 * sum_j popcount(a[m, j] XOR w[j, n])

(an agreeing bit pair contributes +1, a disagreeing pair -1; XOR counts the
disagreements). Padding both sides with 0-bits is self-cancelling: padded
positions XOR to 0, contribute nothing to the popcount, and ``K`` in the
formula is the *true* contraction length.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.packing import PACK


def pad_features(x: jax.Array) -> jax.Array:
    """Pads the last axis up to a multiple of 32 with zeros (sign bit 0)."""
    k = x.shape[-1]
    rem = (-k) % PACK
    if rem == 0:
        return x
    pad = [(0, 0)] * x.ndim
    pad[-1] = (0, rem)
    return jnp.pad(x, pad)


def pack_activations(x: jax.Array) -> jax.Array:
    """Sign-binarizes and packs ``(..., K) -> (..., K//32) int32``.

    Sign convention: x > 0 -> bit 1, x <= 0 -> bit 0 (Eq. 1). K must be a
    multiple of 32 (use :func:`pad_features` first for ragged K)."""
    k = x.shape[-1]
    if k % PACK != 0:
        raise ValueError(f"last dim {k} not a multiple of {PACK}; use pad_features")
    bits = (x > 0).astype(jnp.uint32)
    bits = bits.reshape(x.shape[:-1] + (k // PACK, PACK))
    shifts = jnp.arange(PACK, dtype=jnp.uint32)
    words = jnp.sum(bits << shifts, axis=-1, dtype=jnp.uint32)
    return words.astype(jnp.int32)


def unpack_activations(words: jax.Array, dtype=jnp.float32) -> jax.Array:
    """Inverse of :func:`pack_activations`: ``(..., K//32) int32 -> (..., K)`` ±1."""
    w = words.astype(jnp.uint32)
    shifts = jnp.arange(PACK, dtype=jnp.uint32)
    bits = (w[..., None] >> shifts) & jnp.uint32(1)
    pm1 = jnp.where(bits == 1, 1.0, -1.0).astype(dtype)
    return pm1.reshape(words.shape[:-1] + (words.shape[-1] * PACK,))


def popcount(words: jax.Array) -> jax.Array:
    """Per-word population count, exact, any integer dtype."""
    return jax.lax.population_count(words.astype(jnp.uint32)).astype(jnp.int32)


def activation_nbytes(shape: tuple[int, ...], dtype_bytes: int = 2) -> int:
    """HBM bytes of a dense ``dtype_bytes``-wide activation tensor."""
    return int(np.prod(shape)) * dtype_bytes


def packed_activation_nbytes(shape: tuple[int, ...]) -> int:
    """HBM bytes of the bitpacked form of a ``(..., K)`` activation tensor."""
    lead = int(np.prod(shape[:-1])) if len(shape) > 1 else 1
    return lead * ((shape[-1] + PACK - 1) // PACK) * 4
