"""Public jit'd wrappers around the XNOR-popcount Pallas kernels.

Same contract as ``kernels/ops.py``: handle arbitrary shapes (pad to block
multiples, slice back), flatten leading batch dims, pick interpret mode
automatically off-TPU, and fall back to the jnp oracles for shapes too small
to block. Padding everywhere uses 0-bits, which self-cancel in the popcount
formula (see ``xnor.packing``), so no output correction is ever needed.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.compat import ceil_to as _ceil_to, on_tpu as _on_tpu
from repro.core.packing import PACK
from repro.xnor import ref
from repro.xnor.kernel import sign_pack_pallas, xnor_matmul_pallas
from repro.xnor.packing import pad_features


@functools.partial(jax.jit, static_argnames=("block_m", "block_k", "use_pallas"))
def sign_and_pack(
    x: jax.Array,
    *,
    block_m: int = 128,
    block_k: int = 512,
    use_pallas: bool = True,
) -> jax.Array:
    """Fused sign-binarize (Eq. 1) + bitpack: ``(..., K) -> (..., ceil(K/32))``.

    The full-width activation never leaves the kernel unpacked; only the
    packed int32 words are written back (16x fewer bytes than bf16)."""
    *lead, kdim = x.shape
    k32 = (kdim + PACK - 1) // PACK
    x2 = pad_features(x.reshape(-1, kdim))
    m = x2.shape[0]
    if not use_pallas or m * kdim < block_m * block_k:
        return ref.sign_pack_ref(x2).reshape(*lead, k32)
    bm = min(block_m, _ceil_to(m, 8))
    mp, kp = _ceil_to(m, bm), _ceil_to(x2.shape[1], block_k)
    xp = jnp.pad(x2, ((0, mp - m), (0, kp - x2.shape[1])))
    packed = sign_pack_pallas(xp, block_m=bm, block_k=block_k,
                              interpret=not _on_tpu())
    return packed[:m, :k32].reshape(*lead, k32)


def xnor_matmul_packed(
    a_packed: jax.Array,
    w_packed: jax.Array,
    scale: jax.Array | None = None,
    *,
    k: int,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 512,
    out_dtype=None,
    use_pallas: bool = True,
    allow_extra_words: bool = False,
) -> jax.Array:
    """Popcount matmul over pre-packed operands: a (..., K32), w (K32, N).

    ``k`` is the true contraction length (static). ``allow_extra_words``
    permits K32 > ceil(k/32), for layouts whose surplus positions are 0-bit
    on both operand sides and so self-cancel in the popcount (the conv
    engine's per-tap channel padding); leave it off for the plain FC layout,
    where a word-count mismatch is always a caller bug."""
    return _xnor_matmul_packed(a_packed, w_packed, scale, k=k,
                               block_m=block_m, block_n=block_n,
                               block_k=block_k, out_dtype=out_dtype,
                               use_pallas=use_pallas,
                               allow_extra_words=allow_extra_words)


@functools.partial(
    jax.jit, static_argnames=("k", "block_m", "block_n", "block_k",
                              "out_dtype", "use_pallas", "allow_extra_words"))
def _xnor_matmul_packed(
    a_packed: jax.Array,
    w_packed: jax.Array,
    scale: jax.Array | None = None,
    *,
    k: int,
    block_m: int,
    block_n: int,
    block_k: int,
    out_dtype,
    use_pallas: bool,
    allow_extra_words: bool = False,
) -> jax.Array:
    *lead, k32 = a_packed.shape
    k32w, n = w_packed.shape
    if k32 != k32w:
        raise ValueError(f"packed K mismatch: a has {k32} words, w has {k32w}")
    needed = (k + PACK - 1) // PACK
    if (k32 < needed) if allow_extra_words else (k32 != needed):
        raise ValueError(f"k={k} inconsistent with {k32} packed words")
    a2 = a_packed.reshape(-1, k32)
    m = a2.shape[0]
    if not use_pallas or m * n * k < block_m * block_n * block_k:
        out = ref.xnor_matmul_ref(a2, w_packed, k, scale, out_dtype=out_dtype)
        return out.reshape(*lead, n)

    bm = min(block_m, _ceil_to(m, 8))
    bk32 = block_k // PACK
    mp, np_, kp32 = _ceil_to(m, bm), _ceil_to(n, block_n), _ceil_to(k32, bk32)
    ap = jnp.pad(a2, ((0, mp - m), (0, kp32 - k32)))
    wp = jnp.pad(w_packed, ((0, kp32 - k32), (0, np_ - n)))
    sp = None if scale is None else jnp.pad(scale, (0, np_ - n))
    out = xnor_matmul_pallas(
        ap, wp, sp, k_total=k,
        block_m=bm, block_n=block_n, block_k=block_k,
        out_dtype=out_dtype, interpret=not _on_tpu(),
    )
    return out[:m, :n].reshape(*lead, n)


def xnor_matmul(
    x: jax.Array,
    w_packed: jax.Array,
    scale: jax.Array | None = None,
    *,
    k: int | None = None,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 512,
    out_dtype=None,
    use_pallas: bool = True,
) -> jax.Array:
    """End-to-end fully-binary linear: sign->pack ``x``, then popcount matmul.

    ``x`` is a real-valued (or already ±1) activation of shape (..., K);
    ``w_packed`` is a ``core.packing``-layout (ceil(K/32), N) int32 weight.
    Exactly equals ``sign(x) @ sign(w)`` (integers, no rounding)."""
    kdim = k if k is not None else x.shape[-1]
    if x.shape[-1] != kdim:
        raise ValueError(f"x K={x.shape[-1]} != declared k={kdim}")
    a = sign_and_pack(x, block_m=block_m, block_k=block_k,
                      use_pallas=use_pallas)
    return xnor_matmul_packed(a, w_packed, scale, k=kdim,
                              block_m=block_m, block_n=block_n,
                              block_k=block_k, out_dtype=out_dtype,
                              use_pallas=use_pallas)
