"""Fully-binary XNOR-popcount compute engine.

The paper's headline FPGA speedup replaces multiply-accumulate with XNOR +
popcount over *fully binary* operands. The existing ``repro.kernels`` path
binarizes only weights (activations stay bf16/f32 and the MXU does the dot);
this subsystem binarizes activations too, so the dot product becomes integer
bit arithmetic and activations move through HBM bitpacked — 16x fewer
activation bytes than bf16, 32x fewer than f32.

Modules
  packing   activation-side bitpacking along the contraction (last) axis
  kernel    Pallas kernels: fused sign->pack, XNOR-popcount matmul
  ref       pure-jnp oracles (exact integer ground truth)
  ops       jit'd public wrappers with padding + backend dispatch
  conv/     binary 2-D convolution lowered onto the popcount GEMM: fused
            patch-extraction kernel, SAME-padding border correction, oracles
            (``xnor_conv2d``, ``sign_and_pack_patches``, ``pack_conv_kernel``)
"""
from repro.xnor.ops import sign_and_pack, xnor_matmul, xnor_matmul_packed
from repro.xnor.packing import (pack_activations, unpack_activations,
                                activation_nbytes, packed_activation_nbytes)
from repro.xnor.conv import (pack_conv_kernel, sign_and_pack_patches,
                             xnor_conv2d)  # noqa: E402  (needs xnor.ops)

__all__ = [
    "sign_and_pack", "xnor_matmul", "xnor_matmul_packed",
    "pack_activations", "unpack_activations",
    "activation_nbytes", "packed_activation_nbytes",
    "xnor_conv2d", "sign_and_pack_patches", "pack_conv_kernel",
]
