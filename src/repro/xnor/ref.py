"""Pure-jnp oracles for the XNOR-popcount engine (exact integer ground truth).

Straight-line jnp with no blocking, mirroring ``kernels/ref.py``: used by the
parity tests and as the portable fallback on shapes too small to block. All
three views of the binary dot product are exactly equal (integer arithmetic,
no rounding):

  * ``xnor_matmul_ref``  — popcount over packed operands (what the kernel does)
  * ``sign_matmul_ref``  — ``sign(x) @ sign(w)`` in f32 (the semantic spec)
  * the Pallas kernel in ``xnor.kernel``
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.xnor import packing as apack


def sign_pack_ref(x: jax.Array) -> jax.Array:
    """Fused sign-binarize (Eq. 1) + bitpack along the last axis."""
    return apack.pack_activations(apack.pad_features(x))


def xnor_matmul_ref(
    a_packed: jax.Array,
    w_packed: jax.Array,
    k: int,
    scale: jax.Array | None = None,
    out_dtype=None,
) -> jax.Array:
    """``dot[m, n] = k - 2 * sum_j popcount(a[m, j] ^ w[j, n])``.

    ``a_packed``: (..., K32) int32, ``w_packed``: (K32, N) int32, ``k``: the
    true contraction length (0-bit padding on both sides self-cancels).
    ``out_dtype`` defaults to int32, or f32 when a scale is applied."""
    if a_packed.shape[-1] != w_packed.shape[0]:
        raise ValueError(
            f"packed K mismatch: a has {a_packed.shape[-1]} words, "
            f"w has {w_packed.shape[0]}")
    if out_dtype is None:
        out_dtype = jnp.int32 if scale is None else jnp.float32
    x = jnp.bitwise_xor(a_packed[..., :, None].astype(jnp.uint32),
                        w_packed.astype(jnp.uint32))        # (..., K32, N)
    pc = jax.lax.population_count(x).astype(jnp.int32)
    dot = k - 2 * jnp.sum(pc, axis=-2)
    if scale is not None:
        dot = dot.astype(jnp.float32) * scale.astype(jnp.float32)
    return dot.astype(out_dtype)


def sign_matmul_ref(x: jax.Array, w: jax.Array) -> jax.Array:
    """The semantic spec: ``sign(x) @ sign(w)`` computed densely in f32."""
    xs = jnp.where(x > 0, 1.0, -1.0).astype(jnp.float32)
    ws = jnp.where(w > 0, 1.0, -1.0).astype(jnp.float32)
    return jnp.dot(xs, ws, preferred_element_type=jnp.float32)


def xnor_forward_ref(x: jax.Array, w_packed: jax.Array, k: int,
                     scale: jax.Array | None = None) -> jax.Array:
    """End-to-end oracle: sign->pack the activations, then popcount matmul.

    ``w_packed`` covers ``ceil(k/32)`` words (``core.packing`` layout)."""
    return xnor_matmul_ref(sign_pack_ref(x), w_packed, k, scale)
