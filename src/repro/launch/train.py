"""End-to-end training driver (the paper's "host controller").

Runs real training of any ``--arch`` at any scale that fits the local
devices: the paper models (mnist_fc, vgg16_cifar10) with the paper's recipe
(SGD momentum 0.9, eta0 1e-3, Eq.-4 decay, batch-norm, batch 4), or the LM
architectures (smoke or full configs) with next-token loss on the synthetic
token stream. Fault tolerance is on by default: async checkpoints +
auto-resume; pass --fail-at to watch a simulated crash recover.

Examples:
  PYTHONPATH=src python -m repro.launch.train --arch mnist_fc \
      --binarize stoch --steps 500
  PYTHONPATH=src python -m repro.launch.train --arch mamba2-130m --smoke \
      --binarize det --steps 100 --batch 8 --seq 128
"""
from __future__ import annotations

import argparse
import json

import jax

from repro.configs import base as cb
from repro.core.policy import DEFAULT_POLICY, NONE_POLICY, BinarizePolicy
from repro.data import synthetic as syn
from repro.ft.failures import FailureInjector
from repro.models import mnist_fc, transformer as T, vgg
from repro.optim import schedules
from repro.optim.sgd import adamw, sgd_momentum
from repro.train import steps as ST
from repro.train.trainer import Trainer, TrainerConfig

def make_paper_policy(n_fc_layers: int) -> BinarizePolicy:
    """BNN convention (BinaryConnect lineage the paper follows): binarize
    hidden projections; the input layer (first conv / first FC) and the
    classifier head stay full precision. Binarizing the classifier feeds raw
    sign noise into the logits and stalls stochastic training."""
    last = n_fc_layers - 1
    return BinarizePolicy(
        include=(r".*(kernel)$",),
        exclude=(r"(layers|fc)/0/kernel", rf"(layers|fc)/{last}/kernel",
                 r".*bn.*", r"conv/0/kernel"),
    )


def build_paper_model(arch: str, args):
    if arch == "mnist_fc":
        from repro.configs import mnist_fc as C
        hidden = C.SMOKE_HIDDEN if args.smoke else C.HIDDEN
        tree = mnist_fc.init(jax.random.key(args.seed), hidden=hidden)
        apply_fn = mnist_fc.apply
        spec = syn.SyntheticSpec("mnist", n_train=60_000,
                                 batch_size=args.batch or C.BATCH_SIZE,
                                 seed=args.seed)
        recipe = C
    else:
        from repro.configs import vgg16_cifar10 as C
        wm = C.SMOKE_WIDTH_MULT if args.smoke else C.WIDTH_MULT
        tree = vgg.init(jax.random.key(args.seed), width_mult=wm)
        apply_fn = vgg.apply
        spec = syn.SyntheticSpec("cifar", n_train=50_000,
                                 batch_size=args.batch or C.BATCH_SIZE,
                                 seed=args.seed)
        recipe = C

    n_fc = (len(tree["params"]["layers"]) if arch == "mnist_fc"
            else len(tree["params"]["fc"]))
    policy = make_paper_policy(n_fc)
    sched = schedules.paper_eq4(recipe.LEARNING_RATE, spec.steps_per_epoch)
    opt = sgd_momentum(sched, momentum=recipe.MOMENTUM)
    loss_fn = ST.make_classifier_loss(apply_fn)
    step_fn = ST.make_train_step(
        loss_fn, opt, args.binarize,
        policy if args.binarize != "none" else NONE_POLICY,
        has_model_state=True, use_compression=args.compress)
    state = ST.init_train_state(tree["params"], opt, seed=args.seed,
                                model_state=tree["state"],
                                use_compression=args.compress)

    def batch_fn(step):
        x, y = syn.train_batch(spec, step)
        if arch == "mnist_fc":
            x = x.reshape(x.shape[0], -1)
        return {"x": x, "y": y}

    return state, step_fn, batch_fn


def build_lm(arch: str, args):
    cfg = cb.get_config(arch, smoke=args.smoke)
    params = T.init_lm(cfg, jax.random.key(args.seed))
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"{cfg.name}: {n_params/1e6:.1f}M params "
          f"(smoke={args.smoke}, binarize={args.binarize})")
    opt = (adamw(schedules.cosine(args.lr, 20, args.steps))
           if args.optimizer == "adamw"
           else sgd_momentum(schedules.constant(args.lr)))
    loss_fn = ST.make_lm_loss(cfg)
    step_fn = ST.make_train_step(
        loss_fn, opt, args.binarize,
        DEFAULT_POLICY if args.binarize != "none" else NONE_POLICY,
        microbatches=args.microbatches, use_compression=args.compress)
    state = ST.init_train_state(params, opt, seed=args.seed,
                                use_compression=args.compress)
    spec = syn.SyntheticSpec("lm", n_train=1 << 30, batch_size=args.batch,
                             seq_len=args.seq, vocab_size=cfg.vocab_size,
                             seed=args.seed)

    def batch_fn(step):
        return {"tokens": syn.lm_tokens(spec, step)}

    return state, step_fn, batch_fn


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--binarize", default="det", choices=["none", "det", "stoch"])
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--optimizer", default="sgd", choices=["sgd", "adamw"])
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--compress", action="store_true",
                    help="1-bit gradient compression with error feedback")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--fail-at", type=int, nargs="*", default=[],
                    help="inject simulated failures at these steps")
    ap.add_argument("--history-out", default="")
    args = ap.parse_args()

    arch = cb.canonical_arch(args.arch)
    if arch in ("mnist_fc", "vgg16_cifar10"):
        state, step_fn, batch_fn = build_paper_model(arch, args)
    else:
        state, step_fn, batch_fn = build_lm(arch, args)

    trainer = Trainer(
        TrainerConfig(total_steps=args.steps,
                      checkpoint_dir=f"{args.ckpt_dir}/{arch}_{args.binarize}",
                      checkpoint_every=args.ckpt_every),
        step_fn, batch_fn, state,
        failure_injector=FailureInjector(tuple(args.fail_at)) if args.fail_at
        else None)
    history = trainer.run()
    last = history[-1] if history else {}
    print(f"done: {len(history)} logged steps, "
          f"recoveries={trainer.recoveries}, final={json.dumps(last)}")
    if args.history_out:
        trainer.save_history(args.history_out)


if __name__ == "__main__":
    main()
