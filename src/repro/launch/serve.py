"""Serving driver: batched inference with optional packed binary weights.

Demonstrates the paper's inference claim end-to-end: the same model served
with dense master weights vs bitpacked binary weights (+BWN scale), with
per-request TTFT/latency stats and the weight-bytes reduction printed (the
TPU analogue of Table I's inference-time rows). Token archs run *step-level
continuous batching* (``serve.engine.stream_serve``): a persistent
slot-addressed KV cache, per-step slot refill, per-request ``max_new``, and
tok/s derived from tokens actually recorded. The paper's classifiers
(mnist_fc, vgg16_cifar10) run fixed-batch image inference — ``--binarize
xnor`` serves them fully binary (XnorLinear FC + XnorConv blocks 2-5 for
VGG).

Per-layer dispatch is compiled into an explicit execution plan
(``repro.engine``): ``--plan-report`` prints the backend/reason/bytes table,
``--plan out.json`` dumps the manifest (round-trips through
``ExecutionPlan.load``), ``--plan-from in.json`` serves a previously saved
plan, and ``--override path=backend`` forces layers onto a named backend.

Token archs also serve *mesh-sharded*: ``--mesh data,model --mesh-shape
2,4`` places packed weights (out-channel dim TP over "model"), activations
(ShardCtx constraints) and the slot-addressed decode cache (slots over
"data") on an 8-device mesh, per the plan's sharding column. Greedy
streams are bit-identical to single-device serving.

Stochastic *ensemble* serving (``repro.stoch``): ``--ensemble K`` (with
``--packed --binarize stoch``) draws K independent packed replicas of every
stochastic layer, decodes from the ensemble-mean logits, and reports replica
vote agreement / logit variance per request; ``--abstain-threshold`` flags
low-agreement requests. Works for both the token archs (resident replica
cache in the streaming loop) and the classifiers (vmapped batch forward).

Chunked prefill + prefix reuse (single-sample serving): ``--prefill-chunk
C`` admits prompts C tokens at a time through the fused decode+prefill
step — arriving prompts no longer stall live decode slots — and
``--prefix-cache N`` adds an N-entry LRU prompt-prefix KV cache so
requests sharing a prefix (``--shared-prefix P`` on synthetic workloads)
splice cached rows and skip prefill chunks. Streams stay bit-identical to
whole-prompt admission (tests/test_serve_conformance.py).

Examples:
  PYTHONPATH=src python -m repro.launch.serve --arch starcoder2-3b --smoke \
      --packed --requests 16 --prompt-len 32 --max-new 16
  PYTHONPATH=src python -m repro.launch.serve --arch starcoder2-3b --smoke \
      --packed --prefill-chunk 8 --prefix-cache 32 --shared-prefix 16
  PYTHONPATH=src python -m repro.launch.serve --arch mnist-fc --smoke \
      --packed --binarize stoch --ensemble 8 --abstain-threshold 0.6
  PYTHONPATH=src python -m repro.launch.serve --arch vgg16-cifar10 --smoke \
      --packed --binarize xnor --requests 32 --slots 8 --plan-report
  XLA_FLAGS=--xla_force_host_platform_device_count=4 \
  PYTHONPATH=src python -m repro.launch.serve --arch starcoder2-3b --smoke \
      --packed --mesh data,model --mesh-shape 2,2 --requests 8
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import base as cb
from repro.core.policy import DEFAULT_POLICY
from repro.engine import (ExecutionPlan, compile_plan, format_plan_table,
                          plan_report)
from repro.models import transformer as T
from repro.serve.batcher import SlotBatcher
from repro.serve.engine import ServeEngine, packed_param_bytes, stream_serve


def wants_plan(args) -> bool:
    return bool(args.packed or args.plan or args.plan_from
                or args.plan_report or args.override or args.analyze)


def make_serve_mesh(args):
    """Builds the serving mesh from --mesh/--mesh-shape (None when unset).

    ``--mesh data,model`` names the axes; ``--mesh-shape 2,4`` gives the
    per-axis device counts (default: all local devices on the last —
    "model" — axis). On CPU, force a multi-device host with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N``."""
    if not args.mesh:
        if args.mesh_shape:
            raise SystemExit("--mesh-shape requires --mesh (axis names)")
        return None
    axes = tuple(a.strip() for a in args.mesh.split(",") if a.strip())
    if args.mesh_shape:
        shape = tuple(int(s) for s in args.mesh_shape.split(","))
    else:
        shape = (1,) * (len(axes) - 1) + (jax.device_count(),)
    if len(shape) != len(axes):
        raise SystemExit(f"--mesh has {len(axes)} axes but --mesh-shape "
                         f"has {len(shape)} entries")
    try:
        # AttributeError: jax < 0.4.35 has no jax.make_mesh
        mesh = jax.make_mesh(shape, axes)
    except (ValueError, AssertionError, AttributeError) as e:
        raise SystemExit(
            f"cannot build mesh {dict(zip(axes, shape))} over "
            f"{jax.device_count()} visible device(s): {e} — on CPU, set "
            f"XLA_FLAGS=--xla_force_host_platform_device_count=N (and "
            f"jax >= 0.4.35 for jax.make_mesh)") from None
    print(f"mesh: {dict(zip(axes, shape))} over {mesh.devices.size} devices")
    return mesh


def mesh_axis_sizes(mesh) -> dict | None:
    """{axis: size} for plan_report's predicted-collective column."""
    if mesh is None:
        return None
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def make_plan(params, policy, args, mesh=None) -> ExecutionPlan:
    """Compile (or load) the execution plan and run the requested plan I/O.

    A loaded plan is authoritative: its recorded mode drives packing and
    the binary-activation forward, superseding ``--binarize``. With a
    ``mesh``, the compiled plan's sharding column is validated against it
    (axes the mesh cannot honour downgrade to replicated)."""
    if (args.plan_from or args.override) and not args.packed:
        raise SystemExit("--plan-from/--override change how weights are "
                         "packed; add --packed (use --plan/--plan-report "
                         "alone for a dry inspection)")
    if args.plan_from:
        if args.override:
            raise SystemExit("--override edits a plan at compile time; it "
                             "cannot be combined with --plan-from")
        plan = ExecutionPlan.load(args.plan_from)
        if plan.mode != args.binarize:
            print(f"plan {args.plan_from} was compiled with mode="
                  f"{plan.mode}; serving that (--binarize {args.binarize} "
                  f"ignored)")
    else:
        overrides = {}
        for kv in args.override:
            if "=" not in kv:
                raise SystemExit(
                    f"--override expects PATH=BACKEND (e.g. "
                    f"conv/3=binarized_dense), got {kv!r}")
            path, backend = kv.split("=", 1)
            overrides[path] = backend
        plan = compile_plan(params, policy, args.binarize,
                            overrides=overrides or None, mesh=mesh,
                            replica_axis=(args.replica_axis
                                          if args.ensemble > 1 else None))
    if args.ensemble > 1 and plan.replica_axis is None:
        # a loaded v2 manifest (or one compiled without ensembles) carries
        # no replica axis; adopt the CLI's
        plan.replica_axis = args.replica_axis
    if args.plan:
        print(f"plan manifest -> {plan.save(args.plan)}")
    if args.plan_report:
        print(format_plan_table(plan_report(
            plan, batch=args.slots, axis_sizes=mesh_axis_sizes(mesh))))
    if not args.packed:
        print("(--packed not set: serving dense master weights; the "
              "compiled plan is not applied)")
    return plan


def serve_classifier(arch: str, args) -> None:
    """Fixed-batch image-classification serving for the paper's nets."""
    from repro.data import synthetic as syn
    from repro.launch.train import make_paper_policy
    from repro.models import mnist_fc, vgg

    if arch == "mnist_fc":
        from repro.configs import mnist_fc as C
        hidden = C.SMOKE_HIDDEN if args.smoke else C.HIDDEN
        tree = mnist_fc.init(jax.random.key(args.seed), hidden=hidden)
        apply_fn, n_fc, kind = mnist_fc.apply, len(tree["params"]["layers"]), "mnist"
    else:
        from repro.configs import vgg16_cifar10 as C
        wm = C.SMOKE_WIDTH_MULT if args.smoke else C.WIDTH_MULT
        tree = vgg.init(jax.random.key(args.seed), width_mult=wm)
        apply_fn, n_fc, kind = vgg.apply, len(tree["params"]["fc"]), "cifar"

    params, mstate = tree["params"], tree["state"]
    binary_act = False
    ensemble_set = None
    if args.ensemble > 1 and not (args.packed and args.binarize == "stoch"
                                  or args.plan_from):
        raise SystemExit("--ensemble K samples K stochastic replicas: add "
                         "--packed --binarize stoch")
    analysis_findings = None
    if wants_plan(args):
        plan = make_plan(params, make_paper_policy(n_fc), args)
        if args.analyze:
            # classifier serving is fixed-batch single-device: the HLO /
            # retrace layers don't apply, so --analyze is plan lints only
            analysis_findings = plan.lint()
    if args.packed:
        if args.ensemble > 1:
            from repro.stoch import sample_replicas

            if plan.mode != "stoch":
                raise SystemExit(f"--ensemble needs a stochastic plan, got "
                                 f"mode={plan.mode} (--binarize stoch)")
            ensemble_set = sample_replicas(
                params, plan, jax.random.key(args.seed + 1), args.ensemble)
            params = ensemble_set.base
            dense_b, _ = packed_param_bytes(params)
            ens_b = ensemble_set.tree_nbytes()
            print(f"ensemble K={args.ensemble} (stoch): {dense_b/1e6:.1f}MB "
                  f"(bf16 dense, 1 copy) -> {ens_b/1e6:.1f}MB "
                  f"({args.ensemble} packed replicas, shared leaves once)")
        else:
            params = plan.pack(params, key=jax.random.key(args.seed + 1))
            dense_b, packed_b = packed_param_bytes(params)
            print(f"packed weights ({plan.mode}): {dense_b/1e6:.1f}MB (bf16 "
                  f"dense) -> {packed_b/1e6:.1f}MB "
                  f"({dense_b/max(packed_b,1):.1f}x smaller)")
        # the plan's mode (not the CLI flag) decides the sign-activation
        # forward, so a loaded manifest serves self-consistently
        binary_act = plan.mode == "xnor"

    if ensemble_set is not None:
        from repro.stoch import ensemble_forward

        rs = ensemble_set
        fwd = jax.jit(lambda x: ensemble_forward(
            rs, lambda t: apply_fn(t, mstate, x, training=False,
                                   binary_act=binary_act)[0]))
    else:
        fwd = jax.jit(lambda p, s, x: apply_fn(p, s, x, training=False,
                                               binary_act=binary_act)[0])
    metrics = None
    if args.metrics_out:
        from repro.obs import MetricsRegistry

        metrics = MetricsRegistry()
    spec = syn.SyntheticSpec(kind, n_train=max(args.requests, args.slots),
                             batch_size=args.slots, seed=args.seed)
    t0, done, lat = time.perf_counter(), 0, []
    agrees, n_abstained = [], 0
    for step in range(-(-args.requests // args.slots)):
        x, _ = syn.train_batch(spec, step)
        if arch == "mnist_fc":
            x = x.reshape(x.shape[0], -1)
        t1 = time.perf_counter()
        take = min(args.slots, args.requests - done)
        if ensemble_set is not None:
            es = fwd(x)
            preds = jax.numpy.argmax(es.mean_logits, axis=-1)
            jax.block_until_ready(preds)
            agr = np.asarray(es.agreement)[:take]   # drop ragged-batch pad
            agrees.append(agr)
            if args.abstain_threshold is not None:
                n_abstained += int((agr < args.abstain_threshold).sum())
        else:
            preds = jax.numpy.argmax(fwd(params, mstate, x), axis=-1)
            jax.block_until_ready(preds)
        lat.append(time.perf_counter() - t1)
        done += take
    dt = time.perf_counter() - t0
    print(f"served {done} requests in {len(lat)} batches of {args.slots}, "
          f"{dt:.2f}s ({np.median(lat)*1e3:.1f} ms/batch median, "
          f"{done/dt:.1f} img/s)")
    if agrees:
        alla = np.concatenate(agrees)
        msg = (f"ensemble uncertainty: mean vote agreement {alla.mean():.3f}"
               f" (min {alla.min():.3f})")
        if args.abstain_threshold is not None:
            msg += (f"; abstained {n_abstained}/{done} at threshold "
                    f"{args.abstain_threshold}")
        print(msg)
    if metrics is not None:
        h = metrics.histogram("serve_batch_seconds",
                              "wall seconds per inference batch")
        for s in lat:
            h.observe(s)
        metrics.counter("serve_images_total", "images classified").inc(done)
        metrics.gauge("serve_img_per_s",
                      "images / serving wall seconds").set(done / dt)
        if agrees:
            ah = metrics.histogram(
                "serve_vote_agreement",
                "per-image ensemble replica vote agreement (0-1)")
            for a in np.concatenate(agrees):
                ah.observe(float(a))
            if args.abstain_threshold is not None:
                metrics.counter("serve_abstain_total",
                                "images below the abstain "
                                "threshold").inc(n_abstained)
        if args.metrics_out.endswith((".prom", ".txt")):
            with open(args.metrics_out, "w") as f:
                f.write(metrics.to_prometheus())
            print(f"metrics (prometheus) -> {args.metrics_out}")
        else:
            print(f"metrics -> {metrics.save(args.metrics_out)}")
    if analysis_findings is not None:
        from repro.analysis import format_findings, gate

        print(format_findings(analysis_findings,
                              title="static verifier (plan lints; "
                                    "docs/ANALYSIS.md):"))
        if gate(analysis_findings):
            raise SystemExit(1)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--packed", action="store_true")
    ap.add_argument("--binarize", default="det",
                    choices=["det", "stoch", "xnor"])
    ap.add_argument("--plan", default="",
                    help="dump the compiled execution-plan manifest to this "
                         "JSON path")
    ap.add_argument("--plan-from", default="",
                    help="load (instead of compiling) the execution plan "
                         "from a saved manifest")
    ap.add_argument("--plan-report", action="store_true",
                    help="print the per-layer backend/reason/bytes table")
    ap.add_argument("--override", action="append", default=[],
                    metavar="PATH=BACKEND",
                    help="force a layer (path or '/'-prefix) onto a backend, "
                         "e.g. conv/3=binarized_dense (repeatable)")
    ap.add_argument("--ensemble", type=int, default=1, metavar="K",
                    help="serve a K-replica stochastic ensemble (requires "
                         "--packed --binarize stoch): tokens decode from "
                         "the ensemble-mean logits and every request "
                         "reports vote agreement / logit variance")
    ap.add_argument("--abstain-threshold", type=float, default=None,
                    help="flag a request as abstained when its replica "
                         "vote agreement drops below this (needs "
                         "--ensemble >= 2)")
    ap.add_argument("--replica-axis", default="data",
                    choices=["data", "model"],
                    help="mesh axis the ensemble replica dim shards over "
                         "(recorded in the plan manifest, v3)")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16,
                    help="per-request max_new cap (the decode cache is "
                         "sized for prompt_len + max_new positions)")
    ap.add_argument("--max-new-skew", type=int, default=0,
                    help="randomize each request's max_new down by up to "
                         "this many tokens (exercises per-step slot refill)")
    ap.add_argument("--prefill-chunk", type=int, default=0, metavar="C",
                    help="admit prompts C tokens at a time through the "
                         "fused decode+prefill step instead of stalling "
                         "every live slot on a whole-prompt prefill "
                         "(0 = whole-prompt; token archs, single-sample "
                         "serving only)")
    ap.add_argument("--prefix-cache", type=int, default=0, metavar="N",
                    help="enable the prompt-prefix KV cache with an N-entry "
                         "LRU budget (0 = off): requests sharing a prompt "
                         "prefix splice the cached rows and skip those "
                         "prefill chunks; implies chunked admission")
    ap.add_argument("--shared-prefix", type=int, default=0, metavar="P",
                    help="give every generated request the same first P "
                         "prompt tokens (demonstrates --prefix-cache hits "
                         "on synthetic workloads)")
    ap.add_argument("--mesh", default="",
                    help="serve tensor-parallel on a device mesh: comma-"
                         "separated axis names, e.g. 'data,model' (token "
                         "archs only)")
    ap.add_argument("--mesh-shape", default="",
                    help="per-axis device counts for --mesh, e.g. '2,4' "
                         "(default: all devices on the last axis)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--trace", default="", metavar="OUT.json",
                    help="record a Chrome trace of the serving loop "
                         "(span per step: refill/prefill/sample/record/"
                         "decode, dispatch vs device time) and write it "
                         "here — open in Perfetto; token archs only")
    ap.add_argument("--no-trace-fence", action="store_true",
                    help="with --trace: skip block_until_ready fencing "
                         "(dispatch-only spans; does not serialize the "
                         "async pipeline)")
    ap.add_argument("--metrics-out", default="", metavar="OUT.json",
                    help="write serving metrics (tok/s, TTFT, per-step "
                         "latency p50/p95/p99, queue depth, slot "
                         "occupancy, ensemble agreement/abstains) here; "
                         "a .prom/.txt suffix selects Prometheus text "
                         "exposition instead of JSON")
    ap.add_argument("--audit-collectives", action="store_true",
                    help="print the static per-step collective audit of "
                         "the jitted decode_step/prefill_into (exact "
                         "count + operand bytes per collective kind, "
                         "from the compiled HLO; token archs only)")
    ap.add_argument("--analyze", action="store_true",
                    help="run the static verifier (repro.analysis): plan "
                         "lints over the compiled plan, compiled-HLO "
                         "lints (donation/upcasts/host transfers; token "
                         "archs), and the retrace sentinel over the "
                         "serving loop — exits nonzero on error findings "
                         "(docs/ANALYSIS.md)")
    args = ap.parse_args()

    arch = cb.canonical_arch(args.arch)
    if (args.prefill_chunk or args.prefix_cache) and args.ensemble > 1:
        raise SystemExit("--prefill-chunk/--prefix-cache are single-sample "
                         "serving features; K-replica ensemble serving "
                         "prefills whole prompts")
    if arch in ("mnist_fc", "vgg16_cifar10"):
        if args.mesh:
            raise SystemExit("--mesh serving covers the token archs; the "
                             "classifier path is fixed-batch single-device")
        if args.prefill_chunk or args.prefix_cache:
            raise SystemExit("--prefill-chunk/--prefix-cache chunk the "
                             "token-arch prompt admission; the classifier "
                             "path has no prompts")
        if args.trace or args.audit_collectives:
            raise SystemExit("--trace/--audit-collectives instrument the "
                             "step-level token serving loop; the classifier "
                             "path is fixed-batch (use --metrics-out)")
        serve_classifier(arch, args)
        return
    cfg = cb.get_config(arch, smoke=args.smoke)
    if cfg.frontend:
        raise SystemExit(f"{arch} uses a stubbed frontend; serve a token arch")
    mesh = make_serve_mesh(args)
    params = T.init_lm(cfg, jax.random.key(args.seed))
    plan = None
    ensemble_set = None
    if args.ensemble > 1 and not (args.packed and args.binarize == "stoch"
                                  or args.plan_from):
        raise SystemExit("--ensemble K samples K stochastic replicas: add "
                         "--packed --binarize stoch")
    if wants_plan(args):
        plan = make_plan(params, DEFAULT_POLICY, args, mesh=mesh)
    if args.packed:
        if args.ensemble > 1:
            from repro.stoch import sample_replicas

            if plan.mode != "stoch":
                raise SystemExit(f"--ensemble needs a stochastic plan, got "
                                 f"mode={plan.mode} (--binarize stoch)")
            # same key the single-sample pack uses, so replica 0 — and the
            # whole K=1 ensemble — is bit-identical to --packed alone
            ensemble_set = sample_replicas(
                params, plan, jax.random.key(args.seed + 1), args.ensemble)
            params = ensemble_set.base
            dense_b, _ = packed_param_bytes(params)
            ens_b = ensemble_set.tree_nbytes()
            print(f"ensemble K={args.ensemble} (stoch): {dense_b/1e6:.1f}MB "
                  f"(bf16 dense, 1 copy) -> {ens_b/1e6:.1f}MB "
                  f"({args.ensemble} packed replicas, shared leaves once)")
        else:
            params = plan.pack(params, key=jax.random.key(args.seed + 1))
            dense_b, packed_b = packed_param_bytes(params)
            print(f"packed weights: {dense_b/1e6:.1f}MB (bf16 dense) -> "
                  f"{packed_b/1e6:.1f}MB "
                  f"({dense_b/max(packed_b,1):.1f}x smaller)")

    # mesh=None serves single-device; with a mesh the engine places the
    # (packed) tree per the plan's sharding column and shards decode slots
    # over "data" — greedy streams stay bit-identical either way. The plan
    # is placement input only, so it is forwarded only alongside a mesh.
    tracer = None
    if args.trace:
        from repro.obs import Tracer

        tracer = Tracer(fence=not args.no_trace_fence)
    metrics = None
    if args.metrics_out:
        from repro.obs import MetricsRegistry

        metrics = MetricsRegistry()
    engine = ServeEngine(
        cfg, None if ensemble_set is not None else params, mesh=mesh,
        plan=plan if (args.packed and mesh is not None) else None,
        ensemble=ensemble_set, abstain_threshold=args.abstain_threshold,
        tracer=tracer)
    if args.audit_collectives:
        from repro.obs import audit_engine, format_audit

        print("static per-step collective audit (compiled HLO, "
              "trip-count weighted):")
        print(format_audit(audit_engine(
            engine, n_slots=args.slots, prompt_len=args.prompt_len,
            max_new_cap=args.max_new)))
    findings, sentinel = [], None
    if args.analyze:
        from repro.analysis import RetraceSentinel, lint_engine

        findings += plan.lint(
            mesh_axes=mesh.axis_names if mesh is not None else None,
            axis_sizes=mesh_axis_sizes(mesh))
        findings += lint_engine(engine, n_slots=args.slots,
                                prompt_len=args.prompt_len,
                                max_new_cap=args.max_new)
        sentinel = RetraceSentinel(engine)
    prefix_cache = None
    if args.prefix_cache:
        from repro.serve import PrefixCache

        prefix_cache = PrefixCache(max_entries=args.prefix_cache)
    batcher = SlotBatcher(args.slots, args.prompt_len, tracer=tracer)
    rng = np.random.default_rng(args.seed)
    shared = (rng.integers(0, cfg.vocab_size,
                           min(args.shared_prefix, args.prompt_len))
              if args.shared_prefix else None)
    for i in range(args.requests):
        # per-request max_new: uniform in [max(1, max_new - skew), max_new]
        m = args.max_new - int(rng.integers(0, args.max_new_skew + 1))
        prompt = rng.integers(0, cfg.vocab_size, args.prompt_len)
        if shared is not None:
            prompt[:shared.shape[0]] = shared
        batcher.submit(prompt, max(1, m))

    t0 = time.perf_counter()
    steps = stream_serve(engine, batcher, max_new_cap=args.max_new,
                         metrics=metrics, sentinel=sentinel,
                         prefill_chunk=args.prefill_chunk,
                         prefix_cache=prefix_cache)
    dt = time.perf_counter() - t0
    done = batcher.completed
    # throughput from tokens actually recorded — never steps * batch, which
    # over-credits requests whose max_new is below the cap
    n_tokens = batcher.tokens_generated
    ttft = np.median([r.ttft for r in done]) if done else float("nan")
    lat = np.median([r.latency for r in done]) if done else float("nan")
    print(f"served {len(done)} requests in {steps} decode steps, {dt:.2f}s "
          f"({n_tokens} tokens, {n_tokens/dt:.1f} tok/s; median TTFT "
          f"{ttft*1e3:.1f} ms, median latency {lat*1e3:.1f} ms)")
    if prefix_cache is not None:
        s = prefix_cache.stats()
        print(f"prefix cache: {s['hits']} hits / {s['misses']} misses, "
              f"{s['tokens_skipped']} prompt tokens skipped, "
              f"{s['entries']} entries ({s['bytes']/1e6:.1f}MB), "
              f"{s['evictions']} evictions")
    if ensemble_set is not None and done:
        alla = np.array([a for r in done for a in r.agreement])
        n_abst = sum(1 for r in done if r.abstained)
        msg = (f"ensemble uncertainty: mean vote agreement "
               f"{alla.mean():.3f} (min {alla.min():.3f})")
        if args.abstain_threshold is not None:
            msg += (f"; abstained {n_abst}/{len(done)} requests at "
                    f"threshold {args.abstain_threshold}")
        print(msg)
    if metrics is not None:
        h = metrics["serve_step_seconds"].summary()
        if h.get("count"):
            print(f"step latency: p50 {h['p50'] * 1e3:.1f} ms, p95 "
                  f"{h['p95'] * 1e3:.1f} ms, p99 {h['p99'] * 1e3:.1f} ms "
                  f"over {h['count']} steps")
        if args.metrics_out.endswith((".prom", ".txt")):
            with open(args.metrics_out, "w") as f:
                f.write(metrics.to_prometheus())
            print(f"metrics (prometheus) -> {args.metrics_out}")
        else:
            print(f"metrics -> {metrics.save(args.metrics_out)}")
    if tracer is not None:
        from repro.obs import validate_trace

        path = tracer.save(args.trace)
        info = validate_trace(path)
        cov = ("n/a" if info["coverage"] is None
               else f"{info['coverage'] * 100:.1f}%")
        print(f"trace -> {path} ({info['spans']} spans, step coverage "
              f"{cov}; open in https://ui.perfetto.dev)")
    if args.analyze:
        from repro.analysis import format_findings, gate

        findings += sentinel.findings()
        print(sentinel.summary())
        print(format_findings(findings, title="static verifier "
                                              "(docs/ANALYSIS.md):"))
        if gate(findings):
            raise SystemExit(1)


if __name__ == "__main__":
    main()
