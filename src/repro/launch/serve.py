"""Serving driver: batched generation with optional packed binary weights.

Demonstrates the paper's inference claim end-to-end: the same model served
with dense master weights vs bitpacked binary weights (+BWN scale), with
per-request latency stats and the weight-bytes reduction printed (the TPU
analogue of Table I's inference-time rows).

Example:
  PYTHONPATH=src python -m repro.launch.serve --arch starcoder2-3b --smoke \
      --packed --requests 16 --prompt-len 32 --max-new 16
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import base as cb
from repro.core.policy import DEFAULT_POLICY
from repro.models import transformer as T
from repro.serve.batcher import SlotBatcher
from repro.serve.engine import ServeEngine, pack_params, packed_param_bytes


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--packed", action="store_true")
    ap.add_argument("--binarize", default="det",
                    choices=["det", "stoch", "xnor"])
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    arch = cb.canonical_arch(args.arch)
    cfg = cb.get_config(arch, smoke=args.smoke)
    if cfg.frontend:
        raise SystemExit(f"{arch} uses a stubbed frontend; serve a token arch")
    params = T.init_lm(cfg, jax.random.key(args.seed))
    if args.packed:
        dense_b, packed_b = 0, 0
        params = pack_params(params, DEFAULT_POLICY, args.binarize,
                             key=jax.random.key(args.seed + 1))
        dense_b, packed_b = packed_param_bytes(params)
        print(f"packed weights: {dense_b/1e6:.1f}MB (bf16 dense) -> "
              f"{packed_b/1e6:.1f}MB ({dense_b/max(packed_b,1):.1f}x smaller)")

    engine = ServeEngine(cfg, params)
    batcher = SlotBatcher(args.slots, args.prompt_len)
    rng = np.random.default_rng(args.seed)
    for _ in range(args.requests):
        batcher.submit(rng.integers(0, cfg.vocab_size, args.prompt_len),
                       args.max_new)

    t0 = time.perf_counter()
    n_tokens = 0
    rounds = 0
    while not batcher.idle:
        batcher.refill()
        prompts = jax.numpy.asarray(batcher.prompts())
        result = engine.generate(prompts, args.max_new)
        toks = np.asarray(result.tokens)
        for step_tok in toks.T:
            batcher.record(step_tok)
        n_tokens += int(batcher.active_mask().sum()) * args.max_new
        rounds += 1
    batcher.refill()  # collect the final round's completions
    dt = time.perf_counter() - t0
    done = len(batcher.completed)
    print(f"served {done} requests in {rounds} rounds, {dt:.2f}s "
          f"({dt/max(done,1)*1e3:.1f} ms/request, "
          f"{args.max_new*done/dt:.1f} tok/s)")


if __name__ == "__main__":
    main()
