"""Serving driver: batched inference with optional packed binary weights.

Demonstrates the paper's inference claim end-to-end: the same model served
with dense master weights vs bitpacked binary weights (+BWN scale), with
per-request latency stats and the weight-bytes reduction printed (the TPU
analogue of Table I's inference-time rows). Token archs run continuous
slot-batched generation; the paper's classifiers (mnist_fc, vgg16_cifar10)
run fixed-batch image inference — ``--binarize xnor`` serves them fully
binary (XnorLinear FC + XnorConv blocks 2-5 for VGG).

Examples:
  PYTHONPATH=src python -m repro.launch.serve --arch starcoder2-3b --smoke \
      --packed --requests 16 --prompt-len 32 --max-new 16
  PYTHONPATH=src python -m repro.launch.serve --arch vgg16-cifar10 --smoke \
      --packed --binarize xnor --requests 32 --slots 8
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import base as cb
from repro.core.policy import DEFAULT_POLICY
from repro.models import transformer as T
from repro.serve.batcher import SlotBatcher
from repro.serve.engine import ServeEngine, pack_params, packed_param_bytes


def serve_classifier(arch: str, args) -> None:
    """Fixed-batch image-classification serving for the paper's nets."""
    from repro.data import synthetic as syn
    from repro.launch.train import make_paper_policy
    from repro.models import mnist_fc, vgg

    if arch == "mnist_fc":
        from repro.configs import mnist_fc as C
        hidden = C.SMOKE_HIDDEN if args.smoke else C.HIDDEN
        tree = mnist_fc.init(jax.random.key(args.seed), hidden=hidden)
        apply_fn, n_fc, kind = mnist_fc.apply, len(tree["params"]["layers"]), "mnist"
    else:
        from repro.configs import vgg16_cifar10 as C
        wm = C.SMOKE_WIDTH_MULT if args.smoke else C.WIDTH_MULT
        tree = vgg.init(jax.random.key(args.seed), width_mult=wm)
        apply_fn, n_fc, kind = vgg.apply, len(tree["params"]["fc"]), "cifar"

    params, mstate = tree["params"], tree["state"]
    binary_act = False
    if args.packed:
        params = pack_params(params, make_paper_policy(n_fc), args.binarize,
                             key=jax.random.key(args.seed + 1))
        dense_b, packed_b = packed_param_bytes(params)
        binary_act = args.binarize == "xnor"
        print(f"packed weights ({args.binarize}): {dense_b/1e6:.1f}MB (bf16 "
              f"dense) -> {packed_b/1e6:.1f}MB "
              f"({dense_b/max(packed_b,1):.1f}x smaller)")

    fwd = jax.jit(lambda p, s, x: apply_fn(p, s, x, training=False,
                                           binary_act=binary_act)[0])
    spec = syn.SyntheticSpec(kind, n_train=max(args.requests, args.slots),
                             batch_size=args.slots, seed=args.seed)
    t0, done, lat = time.perf_counter(), 0, []
    for step in range(-(-args.requests // args.slots)):
        x, _ = syn.train_batch(spec, step)
        if arch == "mnist_fc":
            x = x.reshape(x.shape[0], -1)
        t1 = time.perf_counter()
        preds = jax.numpy.argmax(fwd(params, mstate, x), axis=-1)
        jax.block_until_ready(preds)
        lat.append(time.perf_counter() - t1)
        done += min(args.slots, args.requests - done)
    dt = time.perf_counter() - t0
    print(f"served {done} requests in {len(lat)} batches of {args.slots}, "
          f"{dt:.2f}s ({np.median(lat)*1e3:.1f} ms/batch median, "
          f"{done/dt:.1f} img/s)")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--packed", action="store_true")
    ap.add_argument("--binarize", default="det",
                    choices=["det", "stoch", "xnor"])
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    arch = cb.canonical_arch(args.arch)
    if arch in ("mnist_fc", "vgg16_cifar10"):
        serve_classifier(arch, args)
        return
    cfg = cb.get_config(arch, smoke=args.smoke)
    if cfg.frontend:
        raise SystemExit(f"{arch} uses a stubbed frontend; serve a token arch")
    params = T.init_lm(cfg, jax.random.key(args.seed))
    if args.packed:
        dense_b, packed_b = 0, 0
        params = pack_params(params, DEFAULT_POLICY, args.binarize,
                             key=jax.random.key(args.seed + 1))
        dense_b, packed_b = packed_param_bytes(params)
        print(f"packed weights: {dense_b/1e6:.1f}MB (bf16 dense) -> "
              f"{packed_b/1e6:.1f}MB ({dense_b/max(packed_b,1):.1f}x smaller)")

    engine = ServeEngine(cfg, params)
    batcher = SlotBatcher(args.slots, args.prompt_len)
    rng = np.random.default_rng(args.seed)
    for _ in range(args.requests):
        batcher.submit(rng.integers(0, cfg.vocab_size, args.prompt_len),
                       args.max_new)

    t0 = time.perf_counter()
    n_tokens = 0
    rounds = 0
    while not batcher.idle:
        batcher.refill()
        prompts = jax.numpy.asarray(batcher.prompts())
        result = engine.generate(prompts, args.max_new)
        toks = np.asarray(result.tokens)
        for step_tok in toks.T:
            batcher.record(step_tok)
        n_tokens += int(batcher.active_mask().sum()) * args.max_new
        rounds += 1
    batcher.refill()  # collect the final round's completions
    dt = time.perf_counter() - t0
    done = len(batcher.completed)
    print(f"served {done} requests in {rounds} rounds, {dt:.2f}s "
          f"({dt/max(done,1)*1e3:.1f} ms/request, "
          f"{args.max_new*done/dt:.1f} tok/s)")


if __name__ == "__main__":
    main()
