"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

THE proof of distribution coherence without hardware: for each assigned
architecture and input shape, the jitted ``train_step`` / ``serve_step`` is
lowered with ShapeDtypeStruct inputs against the production mesh (16x16
single-pod, 2x16x16 multi-pod), compiled ahead-of-time, and analyzed:

  * ``compiled.memory_analysis()``  — proves the cell fits per-device HBM,
  * ``compiled.cost_analysis()``    — XLA's own FLOPs/bytes (recorded as a
    cross-check; it undercounts scan bodies on the CPU backend),
  * ``core.hlo_analysis.analyze``   — trip-count-aware FLOPs / memory /
    collective bytes, the inputs to the §Roofline terms.

Results are cached as one JSON per cell under ``--out`` so the 80+ cells can
be (re)run incrementally; ``benchmarks/roofline_report.py`` renders the
table in EXPERIMENTS.md from them.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all \
      --mesh both --binarize det --out benchmarks/results/dryrun
"""
# The 512 placeholder devices MUST be configured before any jax import.
import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))

import argparse      # noqa: E402
import json          # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402

import jax           # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import base as cb                    # noqa: E402
from repro.core import hlo_analysis as H                # noqa: E402
from repro.core import roofline as R                    # noqa: E402
from repro.core.policy import DEFAULT_POLICY            # noqa: E402
from repro.distributed.sharding import ShardCtx, mesh_context, params_pspecs  # noqa: E402
from repro.launch import specs as SP                    # noqa: E402
from repro.launch.mesh import make_production_mesh      # noqa: E402
from repro.models import transformer as T               # noqa: E402
from repro.optim import schedules                       # noqa: E402
from repro.optim.sgd import sgd_momentum                # noqa: E402
from repro.train import steps as ST                     # noqa: E402

TRAIN_FSDP_THRESHOLD = 5e9     # f32 master + momentum on 16 GiB chips
SERVE_FSDP_THRESHOLD = 40e9    # bf16 params at TP=16 on 16 GiB chips


def _ns(mesh, tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree,
                        is_leaf=lambda x: isinstance(x, P))


def _train_model_flops(cfg, shape):
    return R.model_flops_train(cfg.param_count(active_only=True),
                               shape.global_batch * shape.seq_len)


def _serve_model_flops(cfg, shape, kind):
    n_tok = shape.global_batch * (shape.seq_len if kind == "prefill" else 1)
    return R.model_flops_infer(cfg.param_count(active_only=True), n_tok)


def lower_train(cfg, shape, mesh, binarize_mode, mu_bf16: bool = False):
    sh = ShardCtx(mesh)
    fsdp = cfg.param_count() > TRAIN_FSDP_THRESHOLD
    opt = sgd_momentum(schedules.constant(1e-3), momentum=0.9,
                       momentum_dtype=jnp.bfloat16 if mu_bf16 else None)
    loss_fn = ST.make_lm_loss(cfg, sh)
    step_fn = ST.make_train_step(loss_fn, opt, binarize_mode, DEFAULT_POLICY,
                                 microbatches=cfg.train_microbatches,
                                 compute_dtype=cfg.activation_dtype)

    state_shape = jax.eval_shape(
        lambda: ST.init_train_state(T.init_lm(cfg, jax.random.key(0)), opt))
    st_pspecs = SP.state_pspecs(state_shape["params"], mesh, fsdp)
    st_pspecs = SP.sanitize_pspecs(state_shape, st_pspecs, mesh)
    batch_shape = SP.input_specs(cfg, shape)
    b_pspecs = SP.sanitize_pspecs(batch_shape, SP.batch_pspecs(cfg, shape, mesh), mesh)

    jitted = jax.jit(
        step_fn,
        in_shardings=(_ns(mesh, st_pspecs), _ns(mesh, b_pspecs)),
        out_shardings=(_ns(mesh, st_pspecs), None),
        donate_argnums=0,
    )
    with mesh_context(mesh):
        lowered = jitted.lower(state_shape, batch_shape)
    return lowered, _train_model_flops(cfg, shape), {
        "fsdp": fsdp, "microbatches": cfg.train_microbatches}


def lower_serve(cfg, shape, mesh, packed: bool):
    sh = ShardCtx(mesh)
    params_shape = jax.eval_shape(
        lambda: jax.tree.map(
            lambda x: x.astype(cfg.activation_dtype)
            if x.dtype == jnp.float32 else x,
            T.init_lm(cfg, jax.random.key(0))))
    extra = {"packed": packed}
    if packed:
        from repro.kernels import ops as kops
        from repro.serve.engine import pack_params
        kops.set_use_pallas(False)  # lower the jnp reference body off-TPU
        params_shape = jax.eval_shape(
            lambda: pack_params(T.init_lm(cfg, jax.random.key(0)),
                                DEFAULT_POLICY, "det"))
        fsdp = False  # packed weights are ~16x smaller: TP-only fits
    else:
        fsdp = cfg.param_count() > SERVE_FSDP_THRESHOLD
    extra["fsdp"] = fsdp
    from repro.distributed.sharding import batch_axes
    p_pspecs = SP.sanitize_pspecs(
        params_shape,
        params_pspecs(params_shape, fsdp=fsdp, dp_axes=batch_axes(mesh)), mesh)
    b_shape = SP.input_specs(cfg, shape)
    b_pspecs = SP.sanitize_pspecs(b_shape, SP.batch_pspecs(cfg, shape, mesh),
                                  mesh)

    if shape.kind == "prefill":
        def step_fn(params, tokens):
            logits, cache = T.prefill(cfg, params, tokens, sh,
                                      max_len=shape.seq_len)
            return logits, cache

        cache_ps = SP.cache_pspecs(cfg, cb.ShapeSpec(
            shape.name, shape.seq_len, shape.global_batch, "decode"), mesh)
        out_shape = jax.eval_shape(step_fn, params_shape, b_shape["tokens"])
        cache_ps = SP.sanitize_pspecs(out_shape[1], cache_ps, mesh)
        jitted = jax.jit(
            step_fn,
            in_shardings=(_ns(mesh, p_pspecs), _ns(mesh, b_pspecs["tokens"])),
            out_shardings=(None, _ns(mesh, cache_ps)),
        )
        with mesh_context(mesh):
            lowered = jitted.lower(params_shape, b_shape["tokens"])
        return lowered, _serve_model_flops(cfg, shape, "prefill"), extra

    def step_fn(params, cache, tokens):
        return T.decode_step(cfg, params, cache, tokens, sh)

    jitted = jax.jit(
        step_fn,
        in_shardings=(_ns(mesh, p_pspecs), _ns(mesh, b_pspecs["cache"]),
                      _ns(mesh, b_pspecs["tokens"])),
        out_shardings=(None, _ns(mesh, b_pspecs["cache"])),
        donate_argnums=1,
    )
    with mesh_context(mesh):
        lowered = jitted.lower(params_shape, b_shape["cache"],
                               b_shape["tokens"])
    return lowered, _serve_model_flops(cfg, shape, "decode"), extra


def run_cell(arch: str, shape_name: str, mesh_name: str, binarize_mode: str,
             packed: bool = False, smoke: bool = False) -> dict:
    cfg = cb.get_config(arch, smoke=smoke)
    shape = cb.LM_SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=(mesh_name == "multi"))
    n_chips = mesh.devices.size

    t0 = time.time()
    if shape.kind == "train":
        lowered, model_flops, extra = lower_train(cfg, shape, mesh, binarize_mode)
    else:
        lowered, model_flops, extra = lower_serve(cfg, shape, mesh, packed)
    t1 = time.time()
    compiled = lowered.compile()
    t2 = time.time()

    ma = compiled.memory_analysis()
    mem = {
        "argument_gb": ma.argument_size_in_bytes / 1e9,
        "output_gb": ma.output_size_in_bytes / 1e9,
        "temp_gb": ma.temp_size_in_bytes / 1e9,
        "alias_gb": ma.alias_size_in_bytes / 1e9,
        "code_mb": ma.generated_code_size_in_bytes / 1e6,
    }
    mem["peak_gb"] = (mem["argument_gb"] + mem["output_gb"] + mem["temp_gb"]
                      - mem["alias_gb"])
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):  # jax<0.5 returns a per-device list
        ca = ca[0] if ca else {}
    cost = H.analyze(compiled.as_text())
    terms = R.from_hlo_cost(cost, n_chips, model_flops=model_flops,
                            hbm_bytes_per_device=mem["peak_gb"] * 1e9)
    return {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "kind": shape.kind, "binarize": binarize_mode, **extra,
        "chips": n_chips,
        "lower_s": t1 - t0, "compile_s": t2 - t1,
        "memory": mem,
        "xla_cost_analysis": {"flops": ca.get("flops"),
                              "bytes": ca.get("bytes accessed")},
        "hlo": cost.as_dict(),
        "roofline": terms.as_dict(),
    }


def cell_filename(arch, shape, mesh, binarize, packed):
    suffix = "__packed" if packed else ""
    return f"{arch}__{shape}__{mesh}__{binarize}{suffix}.json"


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--binarize", default="det", choices=["none", "det", "stoch"])
    ap.add_argument("--packed", action="store_true",
                    help="serve with bitpacked binary weights")
    ap.add_argument("--out", default="benchmarks/results/dryrun")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="use reduced configs (debug only)")
    args = ap.parse_args()

    lm_archs = [a for a in cb.ARCH_IDS if a not in ("mnist_fc", "vgg16_cifar10")]
    archs = lm_archs if args.arch == "all" else [cb.canonical_arch(args.arch)]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    os.makedirs(args.out, exist_ok=True)

    n_ok = n_skip = n_fail = 0
    for arch in archs:
        cfg = cb.get_config(arch, smoke=args.smoke)
        shape_names = (list(cb.shapes_for(cfg)) if args.shape == "all"
                       else [args.shape])
        for shape_name in shape_names:
            if shape_name not in cb.shapes_for(cfg):
                print(f"SKIP {arch} x {shape_name}: unsupported "
                      f"(full attention at 500k) — see DESIGN.md")
                continue
            if args.packed and cb.LM_SHAPES[shape_name].kind == "train":
                continue
            for mesh_name in meshes:
                fname = os.path.join(args.out, cell_filename(
                    arch, shape_name, mesh_name, args.binarize, args.packed))
                if os.path.exists(fname) and not args.force:
                    n_skip += 1
                    continue
                try:
                    rec = run_cell(arch, shape_name, mesh_name,
                                   args.binarize, args.packed, args.smoke)
                    with open(fname, "w") as f:
                        json.dump(rec, f, indent=1)
                    r = rec["roofline"]
                    print(f"OK   {arch} x {shape_name} x {mesh_name}: "
                          f"compile={rec['compile_s']:.1f}s "
                          f"peak={rec['memory']['peak_gb']:.2f}GB/dev "
                          f"dominant={r['dominant']} "
                          f"bound={r['bound_time_s']*1e3:.2f}ms "
                          f"mfu_bound={r['mfu_bound'] and round(r['mfu_bound'], 3)}")
                    n_ok += 1
                except Exception:
                    n_fail += 1
                    print(f"FAIL {arch} x {shape_name} x {mesh_name}")
                    traceback.print_exc()
    print(f"\ndry-run: {n_ok} ok, {n_skip} cached, {n_fail} failed")
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
