"""Production meshes.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state — smoke tests see 1 device; only
``launch/dryrun.py`` sets the 512-placeholder-device XLA flag.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (256 chips) or 2x16x16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(n_data: int = 2, n_model: int = 4):
    """Small mesh for in-test dry-runs (subprocess with 8 host devices)."""
    return jax.make_mesh((n_data, n_model), ("data", "model"))


def chips(mesh) -> int:
    return mesh.devices.size
