"""ShapeDtypeStruct stand-ins for every model input (no device allocation).

``input_specs(cfg, shape)`` returns the exact argument pytree the lowered
step function takes for that (architecture x input-shape) cell:

* train:   {"tokens": (B, S+1) int32}  — or, for stubbed-frontend archs,
           {"tokens": (B, S, D) act-dtype embeddings, "labels": (B, S) int32}
* prefill: (B, S) tokens / (B, S, D) embeddings
* decode:  a populated decode cache for ``seq_len`` context + one new token.

Also provides the state/batch PartitionSpec trees used by the launchers.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeSpec
from repro.distributed.sharding import batch_axes, params_pspecs
from repro.models import transformer as T


def _token_spec(cfg: ModelConfig, batch: int, seq: int):
    if cfg.frontend:
        return jax.ShapeDtypeStruct((batch, seq, cfg.d_model),
                                    cfg.activation_dtype)
    return jax.ShapeDtypeStruct((batch, seq), jnp.int32)


def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    b, s = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        if cfg.frontend:
            return {"tokens": _token_spec(cfg, b, s),
                    "labels": jax.ShapeDtypeStruct((b, s), jnp.int32)}
        return {"tokens": jax.ShapeDtypeStruct((b, s + 1), jnp.int32)}
    if shape.kind == "prefill":
        return {"tokens": _token_spec(cfg, b, s)}
    # decode: one new token against a seq_len cache
    cache = jax.eval_shape(lambda: T.init_cache(cfg, b, s))
    return {"cache": cache, "tokens": _token_spec(cfg, b, 1)}


# ---------------------------------------------------------------------------
# PartitionSpecs
# ---------------------------------------------------------------------------

def batch_pspecs(cfg: ModelConfig, shape: ShapeSpec, mesh) -> Any:
    dp = batch_axes(mesh)
    tok = P(dp, None, None) if cfg.frontend else P(dp, None)
    if shape.kind == "train":
        if cfg.frontend:
            return {"tokens": tok, "labels": P(dp, None)}
        return {"tokens": tok}
    if shape.kind == "prefill":
        return {"tokens": tok}
    return {"cache": cache_pspecs(cfg, shape, mesh), "tokens": tok}


def cache_pspecs(cfg: ModelConfig, shape: ShapeSpec, mesh) -> dict:
    """KV cache: batch over data, sequence over model (flash-decoding);
    SSM state: batch over data, heads over model when divisible."""
    dp = batch_axes(mesh)
    b = shape.global_batch
    n_model = mesh.shape["model"]
    dpb = dp if b % _axis_size(mesh, dp) == 0 else None
    specs: dict[str, Any] = {"pos": P(dpb)}

    def kv_spec(ndim):
        # (L, B, S, KV, hd) or hybrid (P, B, S, KV, hd)
        return P(None, dpb, "model", None, None)

    def ssm_spec(ndim):
        heads_ok = cfg.ssm_heads % n_model == 0
        m = "model" if heads_ok else None
        if ndim == 5:    # (L, B, H, hp, N)
            return P(None, dpb, m, None, None)
        return P(None, None, dpb, m, None, None)  # hybrid (P, nm, B, H, hp, N)

    def conv_spec(ndim):
        if ndim == 4:    # (L, B, W-1, conv_dim)
            return P(None, dpb, None, "model")
        return P(None, None, dpb, None, "model")   # hybrid

    if cfg.family == "ssm":
        specs["ssm"] = ssm_spec(5)
        specs["conv"] = conv_spec(4)
    elif cfg.is_hybrid:
        specs["k"] = kv_spec(5)
        specs["v"] = kv_spec(5)
        specs["ssm"] = ssm_spec(6)
        specs["conv"] = conv_spec(5)
    else:
        specs["k"] = kv_spec(5)
        specs["v"] = kv_spec(5)
    return specs


def sanitize_pspecs(shapes, pspecs, mesh):
    """Drops mesh axes whose size does not divide the corresponding dim.

    Keeps every divisible sharding; anything else becomes replicated on that
    dim (XLA would otherwise reject explicit in/out shardings — e.g. the
    mamba2 in_proj output dim 2*d_inner + 2*N + H = 3352, or batch=1 cells).
    """

    def fix(sds, spec):
        if not isinstance(spec, P):
            return spec
        dims = getattr(sds, "shape", ())
        new = []
        for i, ax in enumerate(spec):
            if ax is None or i >= len(dims):
                new.append(None)
                continue
            size = _axis_size(mesh, ax)
            new.append(ax if size and dims[i] % size == 0 else None)
        return P(*new)

    return jax.tree.map(fix, shapes, pspecs,
                        is_leaf=lambda x: isinstance(x, P))


def _axis_size(mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        return mesh.shape[axes]
    out = 1
    for a in axes:
        out *= mesh.shape[a]
    return max(out, 1)


def state_pspecs(params_shape, mesh, fsdp: bool) -> dict:
    """Train-state PartitionSpecs: params + matching optimizer slots."""
    pspec = params_pspecs(params_shape, fsdp=fsdp, dp_axes=batch_axes(mesh))
    return {
        "params": pspec,
        "opt": {"mu": pspec},
        "step": P(),
        "key": P(),
    }


def fsdp_threshold_hit(cfg: ModelConfig, threshold: float = 8e9) -> bool:
    return cfg.param_count() > threshold
