"""H2O-Danube-3-4B [dense]: 24L d_model=3840 32H (GQA kv=8) d_ff=10240
vocab=32000 — llama+mistral mix, sliding-window attention
[arXiv:2401.16818; unverified]. SWA makes decode O(window) => runs
long_500k."""
import dataclasses
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="h2o-danube-3-4b", family="dense",
    n_layers=24, d_model=3840, n_heads=32, n_kv_heads=8,
    d_ff=10240, vocab_size=32000, head_dim=120,
    sliding_window=4096, mlp_type="glu",
    supports_long_context=True,
    train_microbatches=2,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, head_dim=32,
    d_ff=384, vocab_size=512, sliding_window=64, remat="none", dtype="float32")
