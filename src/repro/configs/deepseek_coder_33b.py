"""DeepSeek-Coder-33B [dense]: 62L d_model=7168 56H (GQA kv=8) d_ff=19200
vocab=32256 — llama-arch [arXiv:2401.14196; hf]."""
import dataclasses
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-coder-33b", family="dense",
    n_layers=62, d_model=7168, n_heads=56, n_kv_heads=8,
    d_ff=19200, vocab_size=32256, head_dim=128, mlp_type="glu",
    train_microbatches=4,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, head_dim=32,
    d_ff=384, vocab_size=512, remat="none", dtype="float32")
