"""Config system: model/arch configs, input shapes, and the registry.

Every assigned architecture gets a ``src/repro/configs/<id>.py`` exporting
``CONFIG`` (full-size, exact assigned hyperparameters) and ``SMOKE`` (reduced
same-family config for CPU smoke tests). ``--arch <id>`` in the launchers
resolves through :func:`get_config`.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Optional

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    """One assigned (input-shape) cell."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


# The assigned LM shape set (identical across the 10 archs).
LM_SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 128
    # attention
    rope_theta: float = 10_000.0
    qkv_bias: bool = False
    sliding_window: Optional[int] = None
    # mlp
    mlp_type: str = "glu"        # "glu" (SwiGLU) | "gelu" (2-matmul)
    # moe
    n_experts: int = 0
    experts_per_token: int = 0
    moe_every: int = 1           # MoE FFN on layers where i % moe_every == moe_offset
    moe_offset: int = 0
    capacity_factor: float = 1.25
    # ssm / hybrid
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv_width: int = 4
    attn_period: int = 0         # hybrid: 1 attention layer per period (jamba: 8)
    # frontend
    frontend: Optional[str] = None   # None | "patch" | "frames" (stubbed embeds)
    # numerics / memory
    dtype: str = "bfloat16"
    remat: str = "full"          # "none" | "full" | "dots"
    train_microbatches: int = 1  # gradient-accumulation steps in the
                                 # production train step (activation memory
                                 # divider; global batch unchanged)
    # which shapes this arch runs; long_500k only for sub-quadratic attention
    supports_long_context: bool = False
    tie_embeddings: bool = False

    # -- derived ----------------------------------------------------------
    @property
    def activation_dtype(self):
        return jnp.dtype(self.dtype)

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def is_hybrid(self) -> bool:
        return self.family == "hybrid"

    @property
    def is_ssm_only(self) -> bool:
        return self.family == "ssm"

    @property
    def has_attention(self) -> bool:
        return not self.is_ssm_only

    def moe_layer(self, layer_idx: int) -> bool:
        if self.n_experts == 0:
            return False
        return layer_idx % self.moe_every == self.moe_offset

    # -- parameter counting (for MODEL_FLOPS and memory budgeting) --------
    def _mlp_params(self, d_ff: int) -> int:
        if self.mlp_type == "glu":
            return 3 * self.d_model * d_ff
        return 2 * self.d_model * d_ff

    def _attn_params(self) -> int:
        return self.d_model * (self.q_dim + 2 * self.kv_dim) + self.q_dim * self.d_model

    def _ssm_params(self) -> int:
        di, n, h = self.d_inner, self.ssm_state, self.ssm_heads
        in_proj = self.d_model * (2 * di + 2 * n + h)   # z, x, B, C, dt
        out_proj = di * self.d_model
        conv = self.ssm_conv_width * di
        other = h * 2 + di                              # A_log, dt_bias, D
        return in_proj + out_proj + conv + other

    def param_count(self, active_only: bool = False) -> int:
        """Total (or active-per-token) parameter count."""
        emb = self.vocab_size * self.d_model
        head = 0 if self.tie_embeddings else self.d_model * self.vocab_size
        total = emb + head + 2 * self.d_model  # final norm (+eps slack)
        for i in range(self.n_layers):
            is_attn = self._layer_is_attention(i)
            if is_attn:
                total += self._attn_params()
            else:
                total += self._ssm_params()
            total += 2 * self.d_model  # per-layer norms
            if self.is_ssm_only:
                continue  # mamba blocks have no separate FFN
            if self.moe_layer(i):
                e = self.experts_per_token if active_only else self.n_experts
                total += e * self._mlp_params(self.d_ff)
                total += self.d_model * self.n_experts  # router (always dense)
            else:
                total += self._mlp_params(self.d_ff)
        return total

    def _layer_is_attention(self, i: int) -> bool:
        if self.is_ssm_only:
            return False
        if not self.is_hybrid:
            return True
        # hybrid: one attention layer per period, placed mid-period
        return (i % self.attn_period) == self.attn_period // 2

    def n_attn_layers(self) -> int:
        return sum(self._layer_is_attention(i) for i in range(self.n_layers))


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

ARCH_IDS = (
    "starcoder2_3b",
    "qwen2_5_32b",
    "h2o_danube_3_4b",
    "deepseek_coder_33b",
    "moonshot_v1_16b_a3b",
    "grok_1_314b",
    "musicgen_large",
    "internvl2_76b",
    "jamba_1_5_large",
    "mamba2_130m",
    # paper-native models
    "mnist_fc",
    "vgg16_cifar10",
)

_ALIASES = {
    "starcoder2-3b": "starcoder2_3b",
    "qwen2.5-32b": "qwen2_5_32b",
    "h2o-danube-3-4b": "h2o_danube_3_4b",
    "deepseek-coder-33b": "deepseek_coder_33b",
    "moonshot-v1-16b-a3b": "moonshot_v1_16b_a3b",
    "grok-1-314b": "grok_1_314b",
    "musicgen-large": "musicgen_large",
    "internvl2-76b": "internvl2_76b",
    "jamba-1.5-large-398b": "jamba_1_5_large",
    "jamba-1.5-large": "jamba_1_5_large",
    "mamba2-130m": "mamba2_130m",
}


def canonical_arch(name: str) -> str:
    name = name.strip()
    return _ALIASES.get(name, name.replace("-", "_").replace(".", "_"))


def get_config(arch: str, smoke: bool = False) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{canonical_arch(arch)}")
    return mod.SMOKE if smoke else mod.CONFIG


def shapes_for(cfg: ModelConfig) -> dict[str, ShapeSpec]:
    """The assigned shape cells this arch runs (long_500k gated)."""
    out = dict(LM_SHAPES)
    if not cfg.supports_long_context:
        out.pop("long_500k")
    return out
