"""Jamba-1.5-Large-398B [hybrid]: 72L d_model=8192 64H (GQA kv=8) d_ff=24576,
MoE 16e top-2 — Mamba+attention 1:7 interleave [arXiv:2403.19887; hf].
Sub-quadratic (Mamba carries the context; 9 attention layers) => runs
long_500k."""
import dataclasses
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large", family="hybrid",
    n_layers=72, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=24576, vocab_size=65536, head_dim=128, mlp_type="glu",
    n_experts=16, experts_per_token=2, moe_every=2, moe_offset=1,
    ssm_state=128, ssm_expand=2, ssm_head_dim=64,
    attn_period=8,
    supports_long_context=True,
    train_microbatches=8,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=8, attn_period=4, d_model=128, n_heads=4, n_kv_heads=2,
    head_dim=32, d_ff=256, vocab_size=512, n_experts=4, experts_per_token=2,
    ssm_state=16, ssm_head_dim=16, capacity_factor=8.0, remat="none", dtype="float32")
