"""MusicGen-Large [audio]: 48L d_model=2048 32H (kv=32 => MHA) d_ff=8192
vocab=2048 — decoder-only over EnCodec tokens [arXiv:2306.05284; hf].
Frontend (EnCodec) is a stub: input_specs feeds precomputed frame embeddings."""
import dataclasses
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large", family="audio",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=32,
    d_ff=8192, vocab_size=2048, head_dim=64, mlp_type="gelu",
    frontend="frames",
    train_microbatches=2,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=128, n_heads=4, n_kv_heads=4, head_dim=32,
    d_ff=256, vocab_size=128, remat="none", dtype="float32")
