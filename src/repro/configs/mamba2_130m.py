"""Mamba2-130M [ssm]: 24L d_model=768 (attention-free) vocab=50280,
ssm_state=128 — SSD state-space duality [arXiv:2405.21060; unverified].
O(1) decode state => runs long_500k."""
import dataclasses
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-130m", family="ssm",
    n_layers=24, d_model=768, n_heads=0, n_kv_heads=0,
    d_ff=0, vocab_size=50280, head_dim=0,
    ssm_state=128, ssm_expand=2, ssm_head_dim=64,
    supports_long_context=True, tie_embeddings=True,
    train_microbatches=4,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=128, vocab_size=512, ssm_state=16,
    ssm_head_dim=16, remat="none", dtype="float32")
