"""Qwen2.5-32B [dense]: 64L d_model=5120 40H (GQA kv=8) d_ff=27648
vocab=152064 — GQA, QKV bias [hf:Qwen/Qwen2.5-0.5B; hf]."""
import dataclasses
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-32b", family="dense",
    n_layers=64, d_model=5120, n_heads=40, n_kv_heads=8,
    d_ff=27648, vocab_size=152064, head_dim=128,
    qkv_bias=True, rope_theta=1e6, mlp_type="glu",
    train_microbatches=4,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, head_dim=32,
    d_ff=384, vocab_size=512, remat="none", dtype="float32")
