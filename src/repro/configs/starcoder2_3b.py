"""StarCoder2-3B [dense]: 30L d_model=3072 24H (GQA kv=2) d_ff=12288
vocab=49152 — GQA, RoPE [arXiv:2402.19173; hf]."""
import dataclasses
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-3b", family="dense",
    n_layers=30, d_model=3072, n_heads=24, n_kv_heads=2,
    d_ff=12288, vocab_size=49152, head_dim=128,
    mlp_type="gelu",  # starcoder2 uses a 2-matmul GELU MLP (d_ff = 4*d)
    train_microbatches=2,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, head_dim=32,
    d_ff=512, vocab_size=512, remat="none", dtype="float32")
