"""The paper's permutation-invariant FC network for MNIST (784-2048x3-10)."""
HIDDEN = (2048, 2048, 2048)
SMOKE_HIDDEN = (128, 128)
# Paper training recipe (section III-A):
BATCH_SIZE = 4          # fixed by the DE1-SoC resource budget in the paper
LEARNING_RATE = 1e-3    # eta[0]
MOMENTUM = 0.9
EPOCHS = 200
