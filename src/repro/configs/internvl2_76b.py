"""InternVL2-76B [vlm]: 80L d_model=8192 64H (GQA kv=8) d_ff=28672
vocab=128256 — InternViT + LLM backbone [arXiv:2404.16821; unverified].
The ViT tower is a stub: input_specs feeds precomputed patch embeddings."""
import dataclasses
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-76b", family="vlm",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=28672, vocab_size=128256, head_dim=128, mlp_type="glu",
    frontend="patch",
    train_microbatches=8,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, head_dim=32,
    d_ff=384, vocab_size=512, remat="none", dtype="float32")
