"""Grok-1-314B [moe]: 64L d_model=6144 48H (GQA kv=8) d_ff=32768
vocab=131072, MoE 8e top-2 [hf:xai-org/grok-1; unverified]."""
import dataclasses
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="grok-1-314b", family="moe",
    n_layers=64, d_model=6144, n_heads=48, n_kv_heads=8,
    d_ff=32768, vocab_size=131072, head_dim=128, mlp_type="gelu",
    n_experts=8, experts_per_token=2,
    train_microbatches=8,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, head_dim=32,
    d_ff=256, vocab_size=512, n_experts=4, experts_per_token=2,
    capacity_factor=8.0, remat="none", dtype="float32")
