"""Moonlight-16B-A3B [moe]: 48L d_model=2048 16H (GQA kv=16) d_ff=1408
vocab=163840, MoE 64e top-6 [hf:moonshotai/Moonlight-16B-A3B; hf]."""
import dataclasses
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b", family="moe",
    n_layers=48, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=1408, vocab_size=163840, head_dim=128, mlp_type="glu",
    n_experts=64, experts_per_token=6,
    train_microbatches=4,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=128, n_heads=4, n_kv_heads=4, head_dim=32,
    d_ff=96, vocab_size=512, n_experts=8, experts_per_token=2,
    capacity_factor=8.0, remat="none", dtype="float32")
