"""Learning-rate schedules, including the paper's Eq. (4) adaptive decay.

Eq. (4):  eta[epoch] = eta[epoch-1] * 0.01 ** (epoch / 100)

which in closed form is  eta[E] = eta[0] * 0.01 ** (sum_{e=1..E} e / 100)
                               = eta[0] * 0.01 ** (E * (E + 1) / 200).
"""
from __future__ import annotations

import jax.numpy as jnp


def paper_eq4(eta0: float, steps_per_epoch: int):
    """The paper's adaptive decaying learning rate, evaluated per step."""

    def schedule(step):
        epoch = (step // max(steps_per_epoch, 1)).astype(jnp.float32)
        exponent = epoch * (epoch + 1.0) / 200.0
        return jnp.asarray(eta0, jnp.float32) * jnp.power(0.01, exponent)

    return schedule


def constant(lr: float):
    def schedule(step):
        del step
        return jnp.asarray(lr, jnp.float32)

    return schedule


def cosine(lr: float, warmup: int, total: int, final_frac: float = 0.1):
    def schedule(step):
        step = step.astype(jnp.float32)
        warm = lr * jnp.minimum(step / max(warmup, 1), 1.0)
        t = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = final_frac * lr + (1 - final_frac) * lr * 0.5 * (1 + jnp.cos(jnp.pi * t))
        return jnp.where(step < warmup, warm, cos)

    return schedule
