"""1-bit gradient compression with error feedback (signSGD-EF).

The beyond-paper, on-theme distributed-optimization trick: the paper
binarizes *weights* to kill the FPGA's multiplier bottleneck; at pod scale
the analogous bottleneck is the data-parallel gradient all-reduce, so we
binarize the *gradients* crossing the interconnect. Each worker sends
``sign(g + e)`` (1 bit/element, 16-32x less ICI traffic) plus one f32 scale
(the mean |g + e| — unbiased magnitude), and keeps the quantization residual
``e`` as error feedback so the compression error is re-injected next step
(Karimireddy et al. 2019 — EF makes signSGD converge like SGD).

In the SPMD program the "collective" is expressed by compressing before and
decompressing after the (mean) all-reduce that pjit inserts for
data-parallel gradients; the compressed representation is what crosses the
ICI when the update runs under shard_map (see distributed tests). The
transform itself is pure and backend-agnostic.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp


def compress(g: jax.Array, err: jax.Array) -> tuple[jax.Array, jax.Array, jax.Array]:
    """g, err -> (sign bits as ±1 int8, scale f32 scalar, new_err)."""
    corrected = g.astype(jnp.float32) + err.astype(jnp.float32)
    scale = jnp.mean(jnp.abs(corrected))
    sign = jnp.where(corrected >= 0, jnp.int8(1), jnp.int8(-1))
    decompressed = scale * sign.astype(jnp.float32)
    new_err = corrected - decompressed
    return sign, scale, new_err


def decompress(sign: jax.Array, scale: jax.Array) -> jax.Array:
    return scale * sign.astype(jnp.float32)


def init_error(params) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress_tree(grads, err_tree):
    """Applies EF 1-bit compression leaf-wise.

    Returns (compressed_grads_f32, new_err_tree). The compressed grads are
    returned already decompressed to f32 (rank-preserving) so they drop into
    any optimizer; the int8 + scalar pair is what a bandwidth-accounting
    model charges to the interconnect (16x fewer bits than bf16)."""
    signs_scales = jax.tree.map(compress, grads, err_tree)
    is_t = lambda t: isinstance(t, tuple) and len(t) == 3
    dec = jax.tree.map(lambda t: decompress(t[0], t[1]), signs_scales, is_leaf=is_t)
    new_err = jax.tree.map(lambda t: t[2], signs_scales, is_leaf=is_t)
    return dec, new_err


def compressed_bytes(params) -> int:
    """ICI bytes per step for the compressed gradients (1 bit/elt + scalar)."""
    total = 0
    for leaf in jax.tree.leaves(params):
        total += (leaf.size + 7) // 8 + 4
    return total
