"""Optimizers, built from scratch (no optax): SGD+momentum (the paper's
choice) and AdamW, both as pure (init, update) pairs over pytrees.

The BinaryConnect weight clip (Alg. 1 step 4) is applied by the train step
after the optimizer update, via ``core.binarize.clip_tree`` — keeping the
optimizers generic.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any, jax.Array], tuple[Any, Any]]
    # update(grads, opt_state, params, step) -> (new_params, new_opt_state)


def sgd_momentum(schedule, momentum: float = 0.9,
                 weight_decay: float = 0.0,
                 momentum_dtype=None) -> Optimizer:
    """SGD with (heavy-ball) momentum + the paper's schedule.

    ``momentum_dtype``: keep the momentum slot in a reduced dtype
    (bf16 halves optimizer memory — the lever that fits Alg.-1 training of
    314-398B models on a single 256-chip pod; see EXPERIMENTS §Perf).
    Default None = same dtype as the (f32 master) params, paper-faithful."""

    def init(params):
        if momentum_dtype is None:
            return {"mu": jax.tree.map(jnp.zeros_like, params)}
        return {"mu": jax.tree.map(
            lambda p: jnp.zeros(p.shape, momentum_dtype), params)}

    def update(grads, state, params, step):
        lr = schedule(step)

        def upd(g, m, p):
            g = g.astype(jnp.float32)
            if weight_decay:
                g = g + weight_decay * p.astype(jnp.float32)
            m_new = momentum * m.astype(jnp.float32) + g
            p_new = p.astype(jnp.float32) - lr * m_new
            return p_new.astype(p.dtype), m_new.astype(m.dtype)

        flat = jax.tree.map(upd, grads, state["mu"], params)
        new_params = jax.tree.map(lambda t: t[0], flat,
                                  is_leaf=lambda t: isinstance(t, tuple))
        new_mu = jax.tree.map(lambda t: t[1], flat,
                              is_leaf=lambda t: isinstance(t, tuple))
        return new_params, {"mu": new_mu}

    return Optimizer(init, update)


def adamw(schedule, b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        return {
            "m": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
            "v": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
        }

    def update(grads, state, params, step):
        lr = schedule(step)
        t = step.astype(jnp.float32) + 1.0
        c1 = 1.0 - jnp.power(b1, t)
        c2 = 1.0 - jnp.power(b2, t)

        def upd(g, m, v, p):
            g = g.astype(jnp.float32)
            m_new = b1 * m + (1 - b1) * g
            v_new = b2 * v + (1 - b2) * jnp.square(g)
            upd_dir = (m_new / c1) / (jnp.sqrt(v_new / c2) + eps)
            p_new = p.astype(jnp.float32) - lr * (upd_dir + weight_decay * p.astype(jnp.float32))
            return p_new.astype(p.dtype), m_new, v_new

        flat = jax.tree.map(upd, grads, state["m"], state["v"], params)
        is_t = lambda t: isinstance(t, tuple)
        return (jax.tree.map(lambda t: t[0], flat, is_leaf=is_t),
                {"m": jax.tree.map(lambda t: t[1], flat, is_leaf=is_t),
                 "v": jax.tree.map(lambda t: t[2], flat, is_leaf=is_t)})

    return Optimizer(init, update)


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    factor = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * factor).astype(g.dtype),
                        grads), norm
