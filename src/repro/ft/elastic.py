"""Elastic scaling: re-mesh a checkpoint onto a different device count.

When a pod loses hosts (or gains them back), the job restarts with a
different device count. Parameters/optimizer state are *logical* arrays —
the checkpoint stores them unsharded (host-side), so elastic restart is:

  1. build the largest valid mesh from the surviving devices
     (:func:`best_mesh_shape`),
  2. restore the checkpoint through the template,
  3. ``jax.device_put`` each leaf with its PartitionSpec resolved against
     the *new* mesh (:func:`reshard`).

The data pipeline needs no adjustment (batches are step-indexed), and the
global batch is preserved by raising ``microbatches`` when fewer chips must
fit the same tokens (``adjust_microbatching``).
"""
from __future__ import annotations

import math

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def best_mesh_shape(n_devices: int, model_parallel: int,
                    axis_names=("data", "model")) -> tuple[int, ...]:
    """Largest (data, model) grid for n_devices, keeping TP if possible."""
    tp = math.gcd(n_devices, model_parallel)
    while tp > 1 and n_devices % tp:
        tp //= 2
    return (n_devices // max(tp, 1), max(tp, 1))


def make_elastic_mesh(model_parallel: int, devices=None) -> Mesh:
    devices = devices if devices is not None else jax.devices()
    shape = best_mesh_shape(len(devices), model_parallel)
    import numpy as np

    return Mesh(np.asarray(devices).reshape(shape), ("data", "model"))


def reshard(tree, pspecs, mesh: Mesh):
    """Places a host-side pytree onto ``mesh`` under ``pspecs``."""

    def put(leaf, spec):
        spec = spec if isinstance(spec, P) else P()
        # drop axes that exceed the leaf rank or don't divide its dims
        usable = []
        for i, ax in enumerate(spec):
            if ax is None:
                usable.append(None)
                continue
            size = mesh.shape[ax] if isinstance(ax, str) else \
                math.prod(mesh.shape[a] for a in ax)
            if i < leaf.ndim and leaf.shape[i] % size == 0:
                usable.append(ax)
            else:
                usable.append(None)
        return jax.device_put(leaf, NamedSharding(mesh, P(*usable)))

    return jax.tree.map(put, tree, pspecs,
                        is_leaf=lambda x: isinstance(x, P))


def adjust_microbatching(global_batch: int, old_devices: int,
                         new_devices: int, old_microbatches: int = 1) -> int:
    """Keep the global batch (and thus the loss trajectory) constant when
    the device count shrinks: scale gradient-accumulation steps up."""
    if new_devices >= old_devices:
        return old_microbatches
    factor = -(-old_devices // new_devices)  # ceil
    return old_microbatches * factor
