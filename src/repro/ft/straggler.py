"""Straggler detection and mitigation.

In lockstep SPMD, one slow host delays every collective. The framework's
mitigations, in order of escalation:

1. **Prefetch** (data/pipeline.Prefetcher): host-side batch generation never
   blocks the device — transient input-pipeline stalls are absorbed.
2. **Skip-ahead** (data/pipeline.skip_ahead): a worker that falls behind
   after a local stall can jump to the fleet's step counter with no peer
   coordination, because batches are pure functions of their index.
3. **Detection -> eviction**: ``StragglerMonitor`` keeps a rolling step-time
   distribution; a host whose step time exceeds ``threshold`` x median for
   ``patience`` consecutive steps is flagged for eviction, after which the
   job restarts on the surviving hosts via ft.elastic (checkpoint-reshard).
"""
from __future__ import annotations

import collections
import dataclasses
import statistics
from typing import Deque, Optional


@dataclasses.dataclass
class StragglerMonitor:
    window: int = 50
    threshold: float = 3.0
    patience: int = 5

    def __post_init__(self):
        self._times: Deque[float] = collections.deque(maxlen=self.window)
        self._consecutive = 0

    def record(self, step_time_s: float) -> None:
        self._times.append(step_time_s)

    @property
    def median(self) -> Optional[float]:
        if len(self._times) < max(5, self.window // 5):
            return None
        return statistics.median(self._times)

    def is_straggling(self, step_time_s: float) -> bool:
        """Call per step with the *local* step time; returns True once the
        slow-step streak exceeds patience (=> evict / re-mesh)."""
        med = self.median
        self.record(step_time_s)
        if med is None:
            return False
        if step_time_s > self.threshold * med:
            self._consecutive += 1
        else:
            self._consecutive = 0
        return self._consecutive >= self.patience
