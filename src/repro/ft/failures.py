"""Failure injection for fault-tolerance tests.

At pod scale the failure modes are: host crash (process dies), device error
(XLA raises), and network partition (collective hangs -> job restart by the
cluster manager). All three surface to the training loop as "the step raised
and in-memory state is gone"; the recovery contract is identical — restart
from the last committed checkpoint and replay the deterministic data stream.
``FailureInjector`` simulates that contract in-process.
"""
from __future__ import annotations

import dataclasses


class InjectedFailure(RuntimeError):
    """Simulated host/device failure."""


@dataclasses.dataclass
class FailureInjector:
    """Raises InjectedFailure at the given steps (each fires once)."""

    fail_at_steps: tuple[int, ...] = ()

    def __post_init__(self):
        self._pending = set(self.fail_at_steps)

    def check(self, step: int) -> None:
        if step in self._pending:
            self._pending.discard(step)
            raise InjectedFailure(f"injected failure at step {step}")
