"""Training loop with checkpoint/restart fault tolerance.

The loop owns: the jitted train step, the checkpoint manager (async saves
every ``checkpoint_every`` steps), the deterministic step-indexed data
stream, metric logging, and the recovery path — any exception classified as
a *failure* (InjectedFailure here; device/collective errors in production)
triggers restore-from-latest-committed and replay. Because batches are pure
functions of the step index and all step randomness is folded from
(key, step), the post-recovery trajectory is bit-identical to an uninterrupted
run (asserted in tests/test_fault_tolerance.py).
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Any, Callable, Optional

import jax

from repro.checkpoint.manager import CheckpointManager
from repro.ft.failures import FailureInjector, InjectedFailure


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int
    checkpoint_dir: str
    checkpoint_every: int = 100
    keep_checkpoints: int = 3
    log_every: int = 10
    async_checkpoint: bool = True
    max_recoveries: int = 10


class Trainer:
    def __init__(
        self,
        tcfg: TrainerConfig,
        step_fn: Callable,                    # (state, batch) -> (state, metrics)
        batch_fn: Callable[[int], Any],       # step index -> batch
        init_state: Any,
        failure_injector: Optional[FailureInjector] = None,
        jit: bool = True,
    ):
        self.tcfg = tcfg
        self.step_fn = jax.jit(step_fn, donate_argnums=0) if jit else step_fn
        self.batch_fn = batch_fn
        self._template = jax.tree.map(lambda x: x, init_state)  # structure copy
        self.state = init_state
        self.ckpt = CheckpointManager(tcfg.checkpoint_dir,
                                      keep=tcfg.keep_checkpoints,
                                      async_save=tcfg.async_checkpoint)
        self.injector = failure_injector
        self.history: list[dict] = []
        self.recoveries = 0

    # -- recovery -----------------------------------------------------------
    def _restore_latest(self) -> int:
        latest = self.ckpt.latest_step()
        assert latest is not None, "run() always commits a step-0 checkpoint"
        self.state = self.ckpt.restore(self._template)
        return latest

    def current_step(self) -> int:
        return int(jax.device_get(self.state["step"]))

    # -- main loop ------------------------------------------------------------
    def run(self) -> list[dict]:
        step = self.current_step()
        if self.ckpt.latest_step() is None:
            # Commit the initial state synchronously: recovery is then always
            # restore-from-checkpoint, never "hope the init buffers survive"
            # (with donation they do not).
            self.ckpt.save(step, self.state, block=True)
        while step < self.tcfg.total_steps:
            try:
                step = self._run_from(step)
            except InjectedFailure as e:
                self.recoveries += 1
                if self.recoveries > self.tcfg.max_recoveries:
                    raise RuntimeError("recovery budget exhausted") from e
                self.ckpt.wait()
                step = self._restore_latest()
        self.ckpt.save(step, self.state, block=True)
        self.ckpt.wait()
        return self.history

    def _run_from(self, step: int) -> int:
        while step < self.tcfg.total_steps:
            if self.injector is not None:
                self.injector.check(step)
            batch = self.batch_fn(step)
            t0 = time.perf_counter()
            self.state, metrics = self.step_fn(self.state, batch)
            if (step % self.tcfg.log_every == 0
                    or step == self.tcfg.total_steps - 1):
                m = {k: float(jax.device_get(v)) for k, v in metrics.items()}
                m.update(step=step, wall_s=time.perf_counter() - t0)
                self.history.append(m)
            step += 1
            if step % self.tcfg.checkpoint_every == 0:
                self.ckpt.save(step, self.state)
        return step

    def save_history(self, path: str) -> None:
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        with open(path, "w") as f:
            json.dump(self.history, f, indent=1)
