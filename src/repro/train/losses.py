"""Loss functions: softmax cross-entropy (the paper's choice) for
classification and next-token LM variants."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def softmax_xent(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Mean cross-entropy. logits (..., C) f-any; labels (...) int."""
    logits = logits.astype(jnp.float32)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(nll)


def accuracy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    return jnp.mean((jnp.argmax(logits, axis=-1) == labels).astype(jnp.float32))
