"""Train/serve steps implementing the paper's Algorithm 1.

One step =
  1. ``w_b <- binarize(w_{t-1})``            (Eq. 1 or 2, STE-wrapped)
  2. forward + backward against ``w_b``      (gradients land on masters)
  3. optimizer update of the master weights  (SGD+momentum per the paper)
  4. ``w <- clip(w)``                        (masters stay in [-1, +1])

The step builders return pure functions suitable for ``jax.jit`` /
``pjit``; all randomness is derived from (state key, step) so steps are
reproducible and checkpoint-resumable. Optional microbatching (gradient
accumulation via ``lax.scan``) and 1-bit gradient compression with error
feedback hook in between (2) and (3).
"""
from __future__ import annotations

from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.core import binarize
from repro.core.binarize import BinarizeMode
from repro.optim import compression
from repro.optim.sgd import Optimizer, clip_by_global_norm
from repro.train.losses import accuracy, softmax_xent


def init_train_state(params, optimizer: Optimizer, seed: int = 0,
                     model_state: Any = None, use_compression: bool = False):
    state = {
        "params": params,
        "opt": optimizer.init(params),
        "step": jnp.zeros((), jnp.int32),
        "key": jax.random.key(seed),
    }
    if model_state is not None:
        state["model_state"] = model_state
    if use_compression:
        state["err"] = compression.init_error(params)
    return state


def _split_microbatches(batch, n: int):
    return jax.tree.map(lambda x: x.reshape((n, x.shape[0] // n) + x.shape[1:]),
                        batch)


def make_train_step(
    loss_fn: Callable,                   # loss_fn(params, batch [, model_state]) -> (loss, aux)
    optimizer: Optimizer,
    mode: BinarizeMode | str,
    policy,
    *,
    microbatches: int = 1,
    grad_clip: Optional[float] = None,
    use_compression: bool = False,
    has_model_state: bool = False,
    donate: bool = True,
    compute_dtype=None,
):
    """Builds the Alg.-1 train step. ``loss_fn`` must return
    ``(loss, aux_dict)`` — when ``has_model_state``, aux_dict must contain
    ``"model_state"`` (e.g. batch-norm running stats)."""
    mode = BinarizeMode.parse(mode)

    def step_fn(state, batch):
        step_key = jax.random.fold_in(state["key"], state["step"])

        def binarized_loss(params, mb):
            w_b = binarize.binarize_tree(params, mode, policy, step_key)   # Alg.1 (1)
            if compute_dtype is not None:
                # mixed precision: f32 masters, bf16 compute — halves the
                # materialized binarized-weight copies for 100B+ models
                w_b = jax.tree.map(
                    lambda x: x.astype(compute_dtype)
                    if hasattr(x, "dtype") and x.dtype == jnp.float32 else x,
                    w_b)
            if has_model_state:
                return loss_fn(w_b, mb, state["model_state"])
            return loss_fn(w_b, mb)

        grad_fn = jax.value_and_grad(binarized_loss, has_aux=True)

        if microbatches > 1:
            mbs = _split_microbatches(batch, microbatches)

            def accum(gsum, mb):
                (loss, aux), g = grad_fn(state["params"], mb)
                return jax.tree.map(jnp.add, gsum, g), (loss, aux)

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state["params"])
            gsum, (losses, auxs) = jax.lax.scan(accum, zeros, mbs)
            grads = jax.tree.map(lambda g: g / microbatches, gsum)
            loss = jnp.mean(losses)
            aux = jax.tree.map(lambda x: x[-1], auxs)  # last microbatch's aux
        else:
            (loss, aux), grads = grad_fn(state["params"], batch)    # Alg.1 (2)

        metrics = {"loss": loss}
        if grad_clip is not None:
            grads, gnorm = clip_by_global_norm(grads, grad_clip)
            metrics["grad_norm"] = gnorm

        new_state = dict(state)
        if use_compression:                                         # signSGD-EF
            grads, new_state["err"] = compression.compress_tree(
                grads, state["err"])

        params, opt = optimizer.update(                              # Alg.1 (3)
            grads, state["opt"], state["params"], state["step"])
        if mode is not BinarizeMode.NONE:
            params = binarize.clip_tree(params, policy)                     # Alg.1 (4)

        new_state.update(params=params, opt=opt, step=state["step"] + 1)
        if has_model_state:
            new_state["model_state"] = aux.pop("model_state")
        for k, v in aux.items():
            if isinstance(v, jax.Array) and v.ndim == 0:
                metrics[k] = v
        return new_state, metrics

    return step_fn


# ---------------------------------------------------------------------------
# Ready-made loss functions
# ---------------------------------------------------------------------------

def make_lm_loss(cfg, sh=None, lb_weight: float = 0.01):
    """Next-token loss for the LM decoder stacks."""
    from repro.models import transformer as T

    def loss_fn(params, batch):
        tokens = batch["tokens"]
        if tokens.dtype in (jnp.int32, jnp.int64):
            inputs, labels = tokens[:, :-1], tokens[:, 1:]
        else:  # stubbed frontend: embeds + explicit labels
            inputs, labels = tokens, batch["labels"]
        logits, aux = T.forward(cfg, params, inputs, sh)
        xent = softmax_xent(logits, labels)
        loss = xent + lb_weight * aux.get("lb_loss", 0.0)
        return loss, {"xent": xent, "lb_loss": aux.get("lb_loss", jnp.float32(0))}

    return loss_fn


def make_classifier_loss(apply_fn):
    """For the paper's FC/VGG models (batch-norm state threaded through)."""

    def loss_fn(params, batch, model_state):
        logits, new_state = apply_fn(params, model_state, batch["x"],
                                     training=True)
        loss = softmax_xent(logits, batch["y"])
        return loss, {"model_state": new_state,
                      "accuracy": accuracy(logits, batch["y"])}

    return loss_fn


def make_eval_fn(apply_fn):
    @jax.jit
    def eval_fn(params, model_state, x, y):
        logits, _ = apply_fn(params, model_state, x, training=False)
        return softmax_xent(logits, y), accuracy(logits, y)

    return eval_fn


def recalibrate_bn(apply_fn, params, model_state, batches, momentum_steps=None):
    """Re-estimates batch-norm running stats under a *fixed* parameter tree.

    Needed when evaluating a deterministically-binarized network whose
    training ran with *stochastic* binarization: training-time BN statistics
    were accumulated under per-step random sign draws and do not match the
    fixed-sign inference network (standard recalibration for quantized
    nets). ``batches`` is an iterable of input arrays."""
    fwd = jax.jit(lambda p, s, x: apply_fn(p, s, x, training=True)[1])
    for x in batches:
        model_state = fwd(params, model_state, x)
    return model_state
