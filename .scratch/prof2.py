import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
os.environ["JAX_PLATFORMS"] = "cpu"
import sys, time
sys.path.insert(0, "src")
import jax, jax.numpy as jnp, numpy as np
from repro.configs import base as cb
from repro.core.policy import DEFAULT_POLICY
from repro.engine import compile_plan
from repro.models import transformer as T
from repro.serve.engine import ServeEngine

mesh = jax.make_mesh((1, 2), ("data", "model"))
cfg = cb.get_config("starcoder2_3b", smoke=True)
params = T.init_lm(cfg, jax.random.key(0))
for mode in ("det", "xnor"):
    plan = compile_plan(params, DEFAULT_POLICY, mode, warn=False, mesh=mesh)
    packed = plan.pack(params, key=jax.random.key(1))
    eng = ServeEngine(cfg, packed, mesh=mesh, plan=plan)
    state = eng.init_decode(4, 8, 8)
    state = eng.prefill_into(state, 0, np.arange(8))
    tok = jnp.argmax(state.logits, axis=-1)
    for i in range(4):
        t0 = time.perf_counter()
        state = eng.decode_step(state, tok)
        jax.block_until_ready(state.logits)
        print(f"{mode} call {i}: {(time.perf_counter()-t0)*1e3:.1f}ms "
              f"tracing_cache={eng._decode._cache_size()}")
        tok = jnp.argmax(state.logits, axis=-1)
    # what sharding does the returned cache carry vs the placed one?
    st0 = eng.init_decode(4, 8, 8)
    for k in st0.cache:
        s_in = st0.cache[k].sharding.spec
        s_out = state.cache[k].sharding.spec
        if s_in != s_out:
            print(f"  {mode} cache[{k}]: in={s_in} out={s_out}")
    if state.logits.sharding.spec != st0.logits.sharding.spec:
        print(f"  {mode} logits: in={st0.logits.sharding.spec} out={state.logits.sharding.spec}")
