import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
os.environ["JAX_PLATFORMS"] = "cpu"
import sys, time
sys.path.insert(0, "src")
import jax, jax.numpy as jnp, numpy as np
from repro.configs import base as cb
from repro.core.policy import DEFAULT_POLICY
from repro.engine import compile_plan
from repro.models import transformer as T
from repro.serve.engine import ServeEngine

mesh = jax.make_mesh((1, 2), ("data", "model"))
cfg = cb.get_config("starcoder2_3b", smoke=True)
params = T.init_lm(cfg, jax.random.key(0))
for mode in ("det", "xnor"):
    plan = compile_plan(params, DEFAULT_POLICY, mode, warn=False, mesh=mesh)
    packed = plan.pack(params, key=jax.random.key(1))
    for name, eng in [("single", ServeEngine(cfg, packed)),
                      ("sharded", ServeEngine(cfg, packed, mesh=mesh, plan=plan))]:
        state = eng.init_decode(4, 8, 8)
        state = eng.prefill_into(state, 0, np.arange(8))
        tok = jnp.argmax(state.logits, axis=-1)
        state = eng.decode_step(state, tok)  # compile
        jax.block_until_ready(state.logits)
        t0 = time.perf_counter()
        for _ in range(20):
            tok = jnp.argmax(state.logits, axis=-1)
            state = eng.decode_step(state, tok)
        jax.block_until_ready(state.logits)
        dt = (time.perf_counter() - t0) / 20
        # chunked
        st2, toks = eng.decode_steps(state, 4)   # compile
        jax.block_until_ready(toks)
        t0 = time.perf_counter()
        for _ in range(5):
            st2, toks = eng.decode_steps(st2, 4)
        jax.block_until_ready(toks)
        dtc = (time.perf_counter() - t0) / 20
        print(f"{mode:5s} {name:8s} step={dt*1e3:7.2f}ms  chunked/step={dtc*1e3:7.2f}ms")
