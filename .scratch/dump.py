import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
os.environ["JAX_PLATFORMS"] = "cpu"
import sys, re
sys.path.insert(0, "src"); sys.path.insert(0, ".")
import jax
from benchmarks.check_collectives import (ARCH, MESH_SHAPE, MESH_AXES,
                                          SLOTS, PROMPT_LEN, MAX_NEW_CAP)
from repro.configs import base as cb
from repro.core.policy import DEFAULT_POLICY
from repro.engine import compile_plan
from repro.models import transformer as T
from repro.serve.engine import ServeEngine

mode = sys.argv[1] if len(sys.argv) > 1 else "det"
mesh = jax.make_mesh(MESH_SHAPE, MESH_AXES)
cfg = cb.get_config(ARCH, smoke=True)
params = T.init_lm(cfg, jax.random.key(0))
plan = compile_plan(params, DEFAULT_POLICY, mode, warn=False, mesh=mesh)
packed = plan.pack(params)
eng = ServeEngine(cfg, packed, mesh=mesh, plan=plan)
state = eng.init_decode(SLOTS, PROMPT_LEN, MAX_NEW_CAP)
import jax.numpy as jnp
tok = jnp.zeros((SLOTS, 1), jnp.int32)
with eng._mesh_ctx():
    txt = eng._decode.lower(eng.params, state.cache, tok).compile().as_text()
open(f".scratch/decode_{mode}.hlo", "w").write(txt)
for ln in txt.splitlines():
    s = ln.strip()
    if re.match(r"[%\w.-]+ = \S+ (all-gather|all-reduce|all-to-all|collective-permute)\(", s):
        print(s.split(" metadata")[0][:240])
