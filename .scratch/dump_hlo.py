import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
os.environ["JAX_PLATFORMS"] = "cpu"
import sys
sys.path.insert(0, "/root/repo/src"); sys.path.insert(0, "/root/repo")
import json
import jax, jax.numpy as jnp
from repro.configs import base as cb
from repro.core.policy import DEFAULT_POLICY
from repro.engine import compile_plan
from repro.models import transformer as T
from repro.obs.collectives import audit_engine, format_audit
from repro.serve.engine import ServeEngine

mesh = jax.make_mesh((2, 2), ("data", "model"))
cfg = cb.get_config("starcoder2_3b", smoke=True)
params = T.init_lm(cfg, jax.random.key(0))
mode = "det"
plan = compile_plan(params, DEFAULT_POLICY, mode, warn=False, mesh=mesh)
packed = plan.pack(params, key=jax.random.key(1))
engine = ServeEngine(cfg, packed, mesh=mesh, plan=plan)
state = engine.init_decode(4, 8, 8)
tok = jnp.argmax(state.logits, axis=-1).reshape(4, 1).astype(jnp.int32)
with engine._mesh_ctx():
    dec = engine._decode.lower(engine.params, state.cache, tok).compile()
text = dec.as_text()
open("/root/repo/.scratch/decode_det.hlo", "w").write(text)
audits = audit_engine(engine, n_slots=4, prompt_len=8, max_new_cap=8)
print(format_audit(audits))
