import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
os.environ["JAX_PLATFORMS"] = "cpu"
import sys, json
sys.path.insert(0, "src"); sys.path.insert(0, ".")
from benchmarks.check_collectives import _child
res = _child()
for mode in res:
    for entry in res[mode]:
        a = res[mode][entry]
        print(mode, entry, "total", sum(a["counts"].values()), a["counts"],
              "reshard", a["reshard_copies"])
print("RESULT " + json.dumps(res))
