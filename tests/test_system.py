"""End-to-end system test: the full production path on one device.

Train a binarized LM with the real Trainer (async checkpoints, injected
crash, auto-recovery), restore the final checkpoint, binarize+pack the
masters, and serve batched generation through the engine — asserting the
packed server reproduces the dense-binarized model's outputs.
"""
import tempfile

import jax
import numpy as np

from repro.configs import base as cb
from repro.core import binarize as B
from repro.core.policy import DEFAULT_POLICY
from repro.data import synthetic as syn
from repro.ft.failures import FailureInjector
from repro.models import transformer as T
from repro.optim import schedules
from repro.optim.sgd import sgd_momentum
from repro.serve.engine import ServeEngine, pack_params
from repro.train import steps as ST
from repro.train.trainer import Trainer, TrainerConfig


def test_train_crash_recover_pack_serve():
    cfg = cb.get_config("starcoder2_3b", smoke=True)
    params = T.init_lm(cfg, jax.random.key(0))
    opt = sgd_momentum(schedules.constant(5e-3), momentum=0.9)
    step = ST.make_train_step(ST.make_lm_loss(cfg), opt, "det",
                              DEFAULT_POLICY)
    state = ST.init_train_state(params, opt)
    spec = syn.SyntheticSpec("lm", n_train=1 << 20, batch_size=4,
                             seq_len=32, vocab_size=cfg.vocab_size)

    with tempfile.TemporaryDirectory() as d:
        trainer = Trainer(
            TrainerConfig(total_steps=30, checkpoint_dir=d,
                          checkpoint_every=10, log_every=5,
                          async_checkpoint=False),
            step, lambda i: {"tokens": syn.lm_tokens(spec, i)}, state,
            failure_injector=FailureInjector((13,)))
        history = trainer.run()
        assert trainer.recoveries == 1
        losses = [h["loss"] for h in history]
        assert losses[-1] < losses[0], losses  # it learned something
        final = trainer.ckpt.restore(trainer.state)
        assert int(jax.device_get(final["step"])) == 30

    # inference: dense det-binarized vs bitpacked must agree
    dense_b = B.binarize_tree(final["params"], "det", DEFAULT_POLICY)
    packed = pack_params(final["params"], DEFAULT_POLICY, "det",
                         with_scale=False)
    prompts = jax.random.randint(jax.random.key(9), (2, 8), 0, cfg.vocab_size)
    out_dense = ServeEngine(cfg, dense_b).generate(prompts, max_new=4)
    out_packed = ServeEngine(cfg, packed).generate(prompts, max_new=4)
    np.testing.assert_array_equal(np.asarray(out_dense.tokens),
                                  np.asarray(out_packed.tokens))
