"""XNOR-popcount engine: exact integer parity sweeps + integration.

Three-way parity (no tolerance — binary dot products are exact integers):
Pallas kernel (interpret) == jnp popcount oracle == sign(x) @ sign(w) in f32,
across MXU-aligned, ragged, odd-K (non-multiple-of-32) and tiny shapes.
Hypothesis-free by design so this module runs in minimal containers.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import packing as wpack
from repro.kernels import ops as kops
from repro.xnor import ops as xops
from repro.xnor import packing as apack
from repro.xnor import ref as xref
from repro.xnor.kernel import sign_pack_pallas, xnor_matmul_pallas

# (M, K, N): blocked, ragged-M/N, K multiple of 32 but not of block,
# odd K (31, 100: partial-word padding), tiny (ref fallback path).
PARITY_SHAPES = [
    (128, 512, 128), (256, 1024, 384), (200, 512, 100), (8, 512, 128),
    (128, 544, 128), (64, 31, 16), (129, 100, 65), (5, 7, 3),
]


def _operands(m, k, n, seed=0):
    kx, kw = jax.random.split(jax.random.key(seed + m * k + n))
    x = jax.random.normal(kx, (m, k), jnp.float32)
    w = jax.random.normal(kw, (k, n), jnp.float32)
    return x, w, kops.binarize_and_pack(w)


class TestActivationPacking:
    @pytest.mark.parametrize("m,k", [(1, 32), (4, 64), (7, 320), (33, 32 * 33)])
    def test_roundtrip(self, m, k):
        key = jax.random.key(m * 1000 + k)
        pm1 = jnp.where(jax.random.bernoulli(key, 0.5, (m, k)), 1.0, -1.0)
        packed = apack.pack_activations(pm1)
        assert packed.shape == (m, k // 32) and packed.dtype == jnp.int32
        np.testing.assert_array_equal(apack.unpack_activations(packed), pm1)

    def test_roundtrip_batched(self):
        pm1 = jnp.where(
            jax.random.bernoulli(jax.random.key(0), 0.5, (2, 3, 64)), 1.0, -1.0)
        np.testing.assert_array_equal(
            apack.unpack_activations(apack.pack_activations(pm1)), pm1)

    def test_pad_features(self):
        x = jnp.ones((4, 33))
        assert apack.pad_features(x).shape == (4, 64)
        # zero padding carries sign bit 0, i.e. packs identically to -1
        np.testing.assert_array_equal(
            apack.pack_activations(apack.pad_features(x)),
            apack.pack_activations(jnp.pad(x, ((0, 0), (0, 31)),
                                           constant_values=-1.0)))

    def test_sign_convention_matches_weight_packing(self):
        # activation packing (last axis) must agree bit-for-bit with
        # core.packing (first axis) on the same vector
        v = jax.random.normal(jax.random.key(1), (96,))
        a_bits = apack.pack_activations(v[None, :])[0]          # (3,)
        w_bits = wpack.pack_bits(jnp.where(v > 0, 1.0, -1.0)[:, None])[:, 0]
        np.testing.assert_array_equal(a_bits, w_bits)

    def test_byte_accounting(self):
        assert apack.packed_activation_nbytes((128, 4096)) == 128 * 128 * 4
        ratio = (apack.activation_nbytes((128, 4096), 2)
                 / apack.packed_activation_nbytes((128, 4096)))
        assert ratio == 16.0


class TestSignPack:
    @pytest.mark.parametrize("m,k", [(128, 512), (200, 544), (8, 31), (3, 100)])
    def test_matches_ref(self, m, k):
        x = jax.random.normal(jax.random.key(m + k), (m, k))
        np.testing.assert_array_equal(
            np.asarray(xops.sign_and_pack(x)), np.asarray(xref.sign_pack_ref(x)))

    def test_pallas_direct(self):
        x = jax.random.normal(jax.random.key(2), (128, 512))
        got = sign_pack_pallas(x, interpret=True)
        np.testing.assert_array_equal(np.asarray(got),
                                      np.asarray(xref.sign_pack_ref(x)))

    def test_zero_maps_to_minus_one(self):
        # Eq. (1): sign(0) = -1, i.e. bit 0
        packed = xops.sign_and_pack(jnp.zeros((1, 32)))
        assert int(packed[0, 0]) == 0


class TestXnorMatmulParity:
    """The acceptance sweep: kernel == oracle == dense sign-matmul, exactly."""

    @pytest.mark.parametrize("m,k,n", PARITY_SHAPES)
    def test_three_way_exact(self, m, k, n):
        x, w, wp = _operands(m, k, n)
        dense = np.asarray(xref.sign_matmul_ref(x, w))          # semantic spec
        oracle = np.asarray(xref.xnor_forward_ref(x, wp, k))    # jnp popcount
        kernel = np.asarray(xops.xnor_matmul(x, wp, k=k))       # Pallas path
        np.testing.assert_array_equal(oracle, dense.astype(np.int32))
        np.testing.assert_array_equal(kernel, dense.astype(np.int32))

    @pytest.mark.parametrize("m,k,n", [(128, 512, 128), (64, 100, 65)])
    def test_scaled(self, m, k, n):
        x, w, wp = _operands(m, k, n, seed=7)
        s = jax.random.uniform(jax.random.key(9), (n,), minval=0.5, maxval=2.0)
        got = np.asarray(xops.xnor_matmul(x, wp, s, k=k))
        want = np.asarray(xref.sign_matmul_ref(x, w)) * np.asarray(s)[None, :]
        np.testing.assert_allclose(got, want, rtol=1e-6)

    def test_prepacked_activations(self):
        x, w, wp = _operands(128, 512, 128, seed=3)
        a = xops.sign_and_pack(x)
        got = np.asarray(xops.xnor_matmul_packed(a, wp, k=512))
        np.testing.assert_array_equal(
            got, np.asarray(xref.sign_matmul_ref(x, w)).astype(np.int32))

    def test_pallas_direct_no_padding(self):
        x, w, wp = _operands(256, 1024, 256, seed=5)
        a = xops.sign_and_pack(x)
        got = xnor_matmul_pallas(a, wp, k_total=1024, block_k=256,
                                 interpret=True)
        np.testing.assert_array_equal(
            np.asarray(got),
            np.asarray(xref.sign_matmul_ref(x, w)).astype(np.int32))

    def test_batched_leading_dims(self):
        x = jax.random.normal(jax.random.key(11), (2, 64, 512))
        w = jax.random.normal(jax.random.key(12), (512, 128))
        wp = kops.binarize_and_pack(w)
        got = xops.xnor_matmul(x, wp)
        assert got.shape == (2, 64, 128)
        want = np.asarray(xref.sign_matmul_ref(
            x.reshape(-1, 512), w)).reshape(2, 64, 128)
        np.testing.assert_array_equal(np.asarray(got), want.astype(np.int32))

    def test_against_packed_weight_path(self):
        """Cross-engine: on ±1 activations the packed-weight MXU path and the
        XNOR path compute the same numbers (also exercises the binary_matmul
        Pallas kernel at a blocked shape)."""
        k = 512
        x = jnp.where(jax.random.bernoulli(jax.random.key(13), 0.5, (128, k)),
                      1.0, -1.0)
        w = jax.random.normal(jax.random.key(14), (k, 128))
        wp = kops.binarize_and_pack(w)
        via_mxu = np.asarray(kops.binary_matmul(x, wp, block_k=256))
        via_xnor = np.asarray(xops.xnor_matmul(x, wp, k=k))
        np.testing.assert_allclose(via_mxu, via_xnor.astype(np.float32),
                                   rtol=1e-4, atol=1e-3)


class TestModelIntegration:
    def test_mnist_xnor_forward_exact(self):
        """mode="xnor" pack + binary_act forward == manual sign-matmul math."""
        from repro.core.policy import DEFAULT_POLICY
        from repro.models import mnist_fc
        from repro.models.layers import XnorLinear
        from repro.serve.engine import pack_params

        tree = mnist_fc.init(jax.random.key(0), hidden=(128, 64), in_dim=784)
        packed = pack_params(tree["params"], DEFAULT_POLICY, "xnor")
        # 784 % 32 != 0 -> first layer stays dense; hidden+out become Xnor
        assert isinstance(packed["layers"][0]["kernel"], jax.Array)
        assert isinstance(packed["layers"][1]["kernel"], XnorLinear)
        assert isinstance(packed["layers"][2]["kernel"], XnorLinear)
        x = jax.random.normal(jax.random.key(1), (4, 784))
        logits, _ = mnist_fc.apply(packed, tree["state"], x, training=False,
                                   binary_act=True)
        assert logits.shape == (4, 10)
        assert np.isfinite(np.asarray(logits)).all()

    def test_vgg_head_split(self):
        from repro.core.policy import DEFAULT_POLICY
        from repro.models import vgg
        from repro.models.layers import PackedLinear, XnorLinear
        from repro.serve.engine import pack_params

        tree = vgg.init(jax.random.key(0), width_mult=0.125)
        packed = pack_params(tree["params"], DEFAULT_POLICY, "xnor")
        assert isinstance(packed["fc"][0]["kernel"], PackedLinear)
        assert isinstance(packed["fc"][1]["kernel"], XnorLinear)
        assert isinstance(packed["fc"][2]["kernel"], XnorLinear)
        x = jax.random.normal(jax.random.key(2), (2, 32, 32, 3))
        logits, _ = vgg.apply(packed, tree["state"], x, training=False,
                              binary_act=True)
        assert logits.shape == (2, 10)
        assert np.isfinite(np.asarray(logits)).all()

    def test_xnor_linear_layer_exact(self):
        """apply_linear on an XnorLinear == scale * (sign(x) @ sign(w))."""
        from repro.models.layers import XnorLinear, apply_linear

        k, n = 256, 64
        x = jax.random.normal(jax.random.key(3), (16, k))
        w = jax.random.normal(jax.random.key(4), (k, n))
        wp = kops.binarize_and_pack(w)
        s = jnp.mean(jnp.abs(w), axis=0)
        got = np.asarray(apply_linear(XnorLinear(wp, s, k), x))
        want = np.asarray(xref.sign_matmul_ref(x, w)) * np.asarray(s)[None, :]
        np.testing.assert_allclose(got, want, rtol=1e-5)

    def test_binary_act_training_gradients_flow(self):
        """The sign activation uses an STE, so binary_act training steps
        produce finite, nonzero gradients for early layers."""
        from repro.models import mnist_fc

        tree = mnist_fc.init(jax.random.key(0), hidden=(32, 32), in_dim=64)
        x = jax.random.normal(jax.random.key(1), (8, 64))
        y = jax.random.randint(jax.random.key(2), (8,), 0, 10)

        def loss(params):
            logits, _ = mnist_fc.apply(params, tree["state"], x,
                                       training=True, binary_act=True)
            lp = jax.nn.log_softmax(logits)
            return -jnp.mean(jnp.take_along_axis(lp, y[:, None], axis=1))

        g = jax.grad(loss)(tree["params"])
        g0 = np.asarray(g["layers"][0]["kernel"])
        assert np.isfinite(g0).all() and np.abs(g0).sum() > 0
