"""Observability: tracer span/event schema, disabled-tracer no-op
guarantees, histogram percentiles vs numpy, metrics export round-trips,
and the traced serving-loop integration (span coverage + ledger-derived
metrics)."""
import json
import time

import numpy as np
import pytest

from repro.obs.metrics import (Counter, Histogram, MetricsRegistry,
                               record_request_metrics)
from repro.obs.trace import _NULL_SPAN, NULL_TRACER, Tracer, validate_trace


class TestHistogram:
    def test_percentiles_match_numpy_quantiles(self):
        """The promised contract: percentile(q) is np.quantile's default
        linear interpolation, bit-for-bit."""
        rng = np.random.default_rng(0)
        xs = rng.gamma(2.0, 3.0, size=257)
        h = Histogram("h")
        for x in xs:
            h.observe(float(x))
        for q in (0, 25, 50, 90, 95, 99, 100):
            assert h.percentile(q) == pytest.approx(
                float(np.quantile(xs, q / 100.0)), rel=1e-12)
        s = h.summary()
        assert s["count"] == 257
        assert s["p50"] == h.percentile(50)
        assert s["p95"] == h.percentile(95)
        assert s["p99"] == h.percentile(99)
        assert s["min"] == float(xs.min()) and s["max"] == float(xs.max())

    def test_empty_histogram(self):
        h = Histogram("h")
        assert h.percentile(50) is None
        assert h.summary() == {"count": 0}
        assert h.sum == 0.0 and h.count == 0

    def test_counter_rejects_decrease(self):
        c = Counter("c")
        c.inc(2)
        with pytest.raises(ValueError):
            c.inc(-1)
        assert c.value == 2


class TestMetricsRegistry:
    def _populated(self):
        reg = MetricsRegistry()
        reg.counter("serve_tokens_total", "tokens").inc(42)
        reg.gauge("serve_tok_per_s", "throughput").set(316.5)
        h = reg.histogram("serve_step_seconds", "step wall")
        for v in (0.01, 0.02, 0.03, 0.05):
            h.observe(v)
        return reg

    def test_json_round_trip_is_lossless(self):
        reg = self._populated()
        blob = json.dumps(reg.to_json())           # must be JSON-able
        back = MetricsRegistry.from_json(json.loads(blob))
        assert back.to_json() == reg.to_json()
        assert back["serve_step_seconds"].samples == [0.01, 0.02, 0.03, 0.05]

    def test_save_round_trip(self, tmp_path):
        reg = self._populated()
        path = reg.save(str(tmp_path / "m.json"))
        with open(path) as f:
            assert MetricsRegistry.from_json(
                json.load(f)).to_json() == reg.to_json()

    def test_type_conflict_raises(self):
        reg = self._populated()
        with pytest.raises(TypeError):
            reg.gauge("serve_tokens_total")
        with pytest.raises(TypeError):
            reg.histogram("serve_tok_per_s")

    def test_get_or_create_returns_same_object(self):
        reg = MetricsRegistry()
        assert reg.counter("a") is reg.counter("a")
        assert "a" in reg and "b" not in reg

    def test_prometheus_text(self):
        text = self._populated().to_prometheus()
        assert "# TYPE serve_tokens_total counter" in text
        assert "serve_tokens_total 42" in text
        assert "# TYPE serve_tok_per_s gauge" in text
        assert "# TYPE serve_step_seconds summary" in text
        assert 'serve_step_seconds{quantile="0.5"}' in text
        assert "serve_step_seconds_sum 0.11" in text
        assert "serve_step_seconds_count 4" in text
        assert text.endswith("\n")


class TestTracerDisabled:
    def test_span_is_shared_null_singleton(self):
        """The hot-loop guarantee: a dormant tracer allocates nothing."""
        tr = Tracer(enabled=False)
        s = tr.span("decode_step", step=3)
        assert s is tr.span("other") is _NULL_SPAN
        assert NULL_TRACER.span("x") is _NULL_SPAN
        with s:
            pass
        tr.instant("submit", uid=0)
        assert tr.events == []

    def test_fence_passthrough_without_jax(self):
        """Disabled fence returns the value untouched (and never blocks)."""
        obj = object()
        assert NULL_TRACER.fence(obj) is obj
        assert Tracer(enabled=True, fence=False).fence(obj) is obj


class TestTracerEvents:
    def _traced(self):
        tr = Tracer(fence=False, pid=7)
        with tr.span("root", cap=4):
            with tr.span("child", k=1):
                time.sleep(0.002)
            with tr.span("child2"):
                time.sleep(0.001)
        tr.instant("mark", uid=9)
        return tr

    def test_chrome_trace_schema(self):
        trace = self._traced().to_json()
        info = validate_trace(trace)
        assert info["spans"] == 3
        assert info["root"] == "root"
        assert 0.0 < info["coverage"] <= 1.0
        spans = {e["name"]: e for e in trace["traceEvents"]
                 if e.get("ph") == "X"}
        assert spans["root"]["args"]["depth"] == 0
        assert spans["child"]["args"] == {"k": 1, "depth": 1}
        assert spans["child2"]["args"]["depth"] == 1
        for e in spans.values():
            assert e["cat"] == "serve" and e["pid"] == 7
            assert e["dur"] >= 0
        marks = [e for e in trace["traceEvents"] if e.get("ph") == "i"]
        assert len(marks) == 1 and marks[0]["args"] == {"uid": 9}

    def test_sleep_children_dominate_root(self):
        """The two sleeping children should cover nearly all of the root
        span — the same coverage computation the serving gate uses."""
        info = validate_trace(self._traced().to_json())
        assert info["coverage"] >= 0.9

    def test_save_and_file_validation(self, tmp_path):
        path = self._traced().save(str(tmp_path / "t.json"))
        info = validate_trace(path)
        assert info["spans"] == 3 and info["events"] == 5  # +1 meta, +1 mark

    def test_events_sorted_by_ts(self):
        tr = self._traced()
        ts = [e["ts"] for e in tr.to_json()["traceEvents"]
              if e.get("ph") != "M"]
        assert ts == sorted(ts)

    def test_validate_rejects_bad_traces(self):
        with pytest.raises(ValueError, match="traceEvents"):
            validate_trace({"events": []})
        with pytest.raises(ValueError, match="missing 'dur'"):
            validate_trace({"traceEvents": [
                {"name": "a", "ph": "X", "ts": 0, "pid": 1, "tid": 1}]})
        with pytest.raises(ValueError, match="monotonic"):
            validate_trace({"traceEvents": [
                {"name": "a", "ph": "X", "ts": 5, "dur": 1, "pid": 1,
                 "tid": 1},
                {"name": "b", "ph": "X", "ts": 2, "dur": 1, "pid": 1,
                 "tid": 1}]})
        with pytest.raises(ValueError, match="negative"):
            validate_trace({"traceEvents": [
                {"name": "a", "ph": "X", "ts": 0, "dur": -1, "pid": 1,
                 "tid": 1}]})


class TestTracedServing:
    """End-to-end: the traced + metered serving loop on the smoke model."""

    def _serve(self):
        import jax

        from repro.configs import base as cb
        from repro.models import transformer as T
        from repro.serve.batcher import SlotBatcher
        from repro.serve.engine import ServeEngine, stream_serve

        cfg = cb.get_config("starcoder2_3b", smoke=True)
        params = T.init_lm(cfg, jax.random.key(0))
        tracer = Tracer()
        engine = ServeEngine(cfg, params, tracer=tracer)
        batcher = SlotBatcher(2, 4, tracer=tracer)
        rng = np.random.default_rng(0)
        metrics = MetricsRegistry()
        for _ in range(4):
            batcher.submit(rng.integers(0, cfg.vocab_size, 4), 3)
        steps = stream_serve(engine, batcher, max_new_cap=3, metrics=metrics)
        return tracer, metrics, batcher, steps

    def test_trace_covers_serving_loop(self):
        tracer, metrics, batcher, steps = self._serve()
        info = validate_trace(tracer.to_json())
        assert info["root"] == "stream_serve"
        assert info["coverage"] >= 0.95   # the acceptance bar CI enforces
        names = {e["name"] for e in tracer.events}
        assert {"stream_serve", "init_decode", "step", "refill",
                "prefill_into", "decode_step", "dispatch", "device",
                "sample", "record", "submit", "slot_refill",
                "request_done"} <= names

        # ledger-derived metrics agree with the batcher ground truth
        assert metrics.counter("serve_steps_total").value == steps
        assert (metrics.counter("serve_tokens_total").value
                == batcher.tokens_generated == 12)
        assert metrics.counter("serve_requests_completed_total").value == 4
        assert metrics.counter("serve_prefills_total").value == 4
        assert metrics.histogram("serve_ttft_seconds").count == 4
        assert metrics.histogram("serve_step_seconds").count == steps
        assert metrics.gauge("serve_tok_per_s").value > 0
        occ = metrics.histogram("serve_slot_occupancy")
        assert occ.count == steps and max(occ.samples) <= 1.0


class TestRecordRequestMetrics:
    def test_folds_completed_ledger(self):
        from repro.serve.batcher import Request

        class FakeBatcher:
            completed = [
                Request(0, np.zeros(2, np.int32), 2, generated=[1, 2],
                        t_submit=0.0, t_first=0.5, t_done=1.5),
                Request(1, np.zeros(2, np.int32), 1, generated=[3],
                        truncated=True, t_submit=1.0, t_first=1.2,
                        t_done=1.2, agreement=[0.5], abstained=True),
            ]

        reg = MetricsRegistry()
        record_request_metrics(reg, FakeBatcher())
        assert reg.counter("serve_requests_completed_total").value == 2
        assert reg.counter("serve_tokens_total").value == 3
        assert reg.counter("serve_prompts_truncated_total").value == 1
        assert reg.counter("serve_abstain_total").value == 1
        assert reg.histogram("serve_ttft_seconds").samples \
            == pytest.approx([0.5, 0.2])
        assert reg.histogram("serve_request_latency_seconds").samples \
            == pytest.approx([1.5, 0.2])
        assert reg.histogram("serve_vote_agreement").samples == [0.5]
