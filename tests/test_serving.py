"""Serving path: packed-weight inference equivalence, engine generation,
slot batcher invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import base as cb
from repro.core import binarize as B
from repro.core.policy import DEFAULT_POLICY
from repro.models import transformer as T
from repro.models.layers import PackedLinear, apply_linear
from repro.serve.batcher import SlotBatcher
from repro.serve.engine import ServeEngine, pack_params, packed_param_bytes


class TestPackParams:
    def test_packed_equals_binarized_dense(self):
        """unscaled packed inference == dense inference on det-binarized
        weights (the Alg.-1 inference network), per arch template."""
        for arch in ("starcoder2_3b", "mamba2_130m"):
            cfg = cb.get_config(arch, smoke=True)
            params = T.init_lm(cfg, jax.random.key(0))
            toks = jax.random.randint(jax.random.key(1), (2, 16), 0,
                                      cfg.vocab_size)
            dense_b = B.binarize_tree(params, "det", DEFAULT_POLICY)
            logits_dense, _ = T.forward(cfg, dense_b, toks)
            packed = pack_params(params, DEFAULT_POLICY, "det",
                                 with_scale=False)
            logits_packed, _ = T.forward(cfg, packed, toks)
            np.testing.assert_allclose(
                np.asarray(logits_packed, np.float32),
                np.asarray(logits_dense, np.float32), rtol=5e-2, atol=5e-2)

    def test_packed_leaf_structure(self):
        cfg = cb.get_config("starcoder2_3b", smoke=True)
        params = T.init_lm(cfg, jax.random.key(0))
        packed = pack_params(params, DEFAULT_POLICY, "det")
        leaf = packed["layers"]["attn"]["w_qkv"]
        assert isinstance(leaf, PackedLinear)
        assert leaf.packed.dtype == jnp.int32
        # stacked layer dim preserved; K packed 32x
        assert leaf.packed.shape[0] == cfg.n_layers
        assert leaf.packed.shape[1] == cfg.d_model // 32
        # embeddings unpacked
        assert not isinstance(packed["embed"]["embedding"], PackedLinear)

    def test_bytes_reduction(self):
        cfg = cb.get_config("starcoder2_3b", smoke=True)
        params = T.init_lm(cfg, jax.random.key(0))
        packed = pack_params(params, DEFAULT_POLICY, "det", with_scale=False)
        dense, packed_b = packed_param_bytes(packed)
        assert dense / packed_b > 2.0  # smoke model is embedding-heavy

    def test_apply_linear_dispatch(self):
        w = jax.random.normal(jax.random.key(0), (64, 32))
        x = jax.random.normal(jax.random.key(1), (4, 64))
        from repro.kernels import ops
        pl = PackedLinear(ops.binarize_and_pack(w), None, 64)
        got = apply_linear(pl, x)
        want = x @ jnp.where(w > 0, 1.0, -1.0)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-2, atol=2e-2)

    def test_stochastic_packing_reproducible(self):
        cfg = cb.get_config("starcoder2_3b", smoke=True)
        params = T.init_lm(cfg, jax.random.key(0))
        a = pack_params(params, DEFAULT_POLICY, "stoch", key=jax.random.key(7))
        b = pack_params(params, DEFAULT_POLICY, "stoch", key=jax.random.key(7))
        np.testing.assert_array_equal(
            np.asarray(a["layers"]["attn"]["w_qkv"].packed),
            np.asarray(b["layers"]["attn"]["w_qkv"].packed))


class TestServeEngine:
    def test_greedy_generation_matches_stepwise_forward(self):
        cfg = cb.get_config("starcoder2_3b", smoke=True)
        params = T.init_lm(cfg, jax.random.key(0))
        engine = ServeEngine(cfg, params)
        prompts = jax.random.randint(jax.random.key(1), (2, 8), 0,
                                     cfg.vocab_size)
        out = engine.generate(prompts, max_new=4)
        assert out.tokens.shape == (2, 4)
        # oracle: greedy via repeated full forward
        seq = prompts
        for i in range(4):
            logits, _ = T.forward(cfg, params, seq)
            nxt = jnp.argmax(logits[:, -1], axis=-1)
            np.testing.assert_array_equal(np.asarray(nxt),
                                          np.asarray(out.tokens[:, i]))
            seq = jnp.concatenate([seq, nxt[:, None]], axis=1)


class TestSlotBatcher:
    def test_fills_and_completes(self):
        b = SlotBatcher(n_slots=2, prompt_len=4)
        for i in range(5):
            b.submit(np.full(4, i), max_new=3)
        rounds = 0
        while not b.idle:
            b.refill()
            for _ in range(3):
                b.record(np.arange(2))
            rounds += 1
        b.refill()
        assert len(b.completed) == 5
        assert rounds == 3  # ceil(5/2)
        assert all(len(r.generated) == 3 for r in b.completed)

    def test_left_pads_short_prompts(self):
        b = SlotBatcher(n_slots=1, prompt_len=6, pad_id=9)
        b.submit(np.array([1, 2]), max_new=1)
        b.refill()
        np.testing.assert_array_equal(b.prompts()[0],
                                      np.array([9, 9, 9, 9, 1, 2]))

    def test_refill_retires_and_reuses_slot_in_one_step(self):
        """A slot finishing while the queue is non-empty is retired AND
        refilled by the same refill() call — no idle round in between."""
        b = SlotBatcher(n_slots=2, prompt_len=2)
        for i in range(3):
            b.submit(np.full(2, i), max_new=1)
        b.refill()
        first = [r.uid for r in b.slots]
        for _ in range(1):
            b.record(np.arange(2))  # both slots finish this step
        changed = b.refill()
        # both finished slots retired; slot 0 immediately holds request 2
        assert [r.uid for r in b.completed] == first
        assert changed == [0]
        assert b.slots[0] is not None and b.slots[0].uid == 2
        assert b.slots[1] is None
        assert not b.idle

    def test_all_slots_empty_decodes_masked_padding(self):
        """With every slot empty, the batch decodes pure padding: the mask
        is all-False, prompts are all pad_id, and record() is a no-op."""
        b = SlotBatcher(n_slots=3, prompt_len=4, pad_id=7)
        b.submit(np.arange(4), max_new=1)
        b.refill()
        b.record(np.arange(3))
        b.refill()  # retires the only request; queue empty
        assert b.idle and len(b.completed) == 1
        np.testing.assert_array_equal(b.active_mask(),
                                      np.zeros(3, dtype=bool))
        np.testing.assert_array_equal(b.prompts(),
                                      np.full((3, 4), 7, np.int32))
        b.record(np.arange(3))  # decode output of an all-empty batch
        assert all(r is None for r in b.slots)
        assert len(b.completed[0].generated) == 1  # nothing appended
