"""Serving path: packed-weight inference equivalence, engine generation,
step-level continuous batching parity, slot batcher invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import base as cb
from repro.core import binarize as B
from repro.core.policy import DEFAULT_POLICY
from repro.models import transformer as T
from repro.models.layers import PackedLinear, XnorConv, XnorLinear, apply_linear
from repro.serve.batcher import SlotBatcher
from repro.serve.engine import (ServeEngine, pack_params, packed_param_bytes,
                                stream_serve)


class TestPackParams:
    def test_packed_equals_binarized_dense(self):
        """unscaled packed inference == dense inference on det-binarized
        weights (the Alg.-1 inference network), per arch template."""
        for arch in ("starcoder2_3b", "mamba2_130m"):
            cfg = cb.get_config(arch, smoke=True)
            params = T.init_lm(cfg, jax.random.key(0))
            toks = jax.random.randint(jax.random.key(1), (2, 16), 0,
                                      cfg.vocab_size)
            dense_b = B.binarize_tree(params, "det", DEFAULT_POLICY)
            logits_dense, _ = T.forward(cfg, dense_b, toks)
            packed = pack_params(params, DEFAULT_POLICY, "det",
                                 with_scale=False)
            logits_packed, _ = T.forward(cfg, packed, toks)
            np.testing.assert_allclose(
                np.asarray(logits_packed, np.float32),
                np.asarray(logits_dense, np.float32), rtol=5e-2, atol=5e-2)

    def test_packed_leaf_structure(self):
        cfg = cb.get_config("starcoder2_3b", smoke=True)
        params = T.init_lm(cfg, jax.random.key(0))
        packed = pack_params(params, DEFAULT_POLICY, "det")
        leaf = packed["layers"]["attn"]["w_qkv"]
        assert isinstance(leaf, PackedLinear)
        assert leaf.packed.dtype == jnp.int32
        # stacked layer dim preserved; K packed 32x
        assert leaf.packed.shape[0] == cfg.n_layers
        assert leaf.packed.shape[1] == cfg.d_model // 32
        # embeddings unpacked
        assert not isinstance(packed["embed"]["embedding"], PackedLinear)

    def test_bytes_reduction(self):
        cfg = cb.get_config("starcoder2_3b", smoke=True)
        params = T.init_lm(cfg, jax.random.key(0))
        packed = pack_params(params, DEFAULT_POLICY, "det", with_scale=False)
        dense, packed_b = packed_param_bytes(packed)
        assert dense / packed_b > 2.0  # smoke model is embedding-heavy

    def test_apply_linear_dispatch(self):
        w = jax.random.normal(jax.random.key(0), (64, 32))
        x = jax.random.normal(jax.random.key(1), (4, 64))
        from repro.kernels import ops
        pl = PackedLinear(ops.binarize_and_pack(w), None, 64)
        got = apply_linear(pl, x)
        want = x @ jnp.where(w > 0, 1.0, -1.0)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-2, atol=2e-2)

    def test_stochastic_packing_reproducible(self):
        cfg = cb.get_config("starcoder2_3b", smoke=True)
        params = T.init_lm(cfg, jax.random.key(0))
        a = pack_params(params, DEFAULT_POLICY, "stoch", key=jax.random.key(7))
        b = pack_params(params, DEFAULT_POLICY, "stoch", key=jax.random.key(7))
        np.testing.assert_array_equal(
            np.asarray(a["layers"]["attn"]["w_qkv"].packed),
            np.asarray(b["layers"]["attn"]["w_qkv"].packed))


class TestServeEngine:
    def test_greedy_generation_matches_stepwise_forward(self):
        cfg = cb.get_config("starcoder2_3b", smoke=True)
        params = T.init_lm(cfg, jax.random.key(0))
        engine = ServeEngine(cfg, params)
        prompts = jax.random.randint(jax.random.key(1), (2, 8), 0,
                                     cfg.vocab_size)
        out = engine.generate(prompts, max_new=4)
        assert out.tokens.shape == (2, 4)
        # oracle: greedy via repeated full forward
        seq = prompts
        for i in range(4):
            logits, _ = T.forward(cfg, params, seq)
            nxt = jnp.argmax(logits[:, -1], axis=-1)
            np.testing.assert_array_equal(np.asarray(nxt),
                                          np.asarray(out.tokens[:, i]))
            seq = jnp.concatenate([seq, nxt[:, None]], axis=1)


class TestContinuousDecode:
    """Step-level continuous batching: the persistent slot-addressed cache
    must reproduce one-shot generation bit-for-bit."""

    @pytest.mark.parametrize("arch", ["starcoder2_3b", "mamba2_130m",
                                      "jamba_1_5_large"])
    def test_prefill_into_matches_batched_prefill(self, arch):
        """init_decode + per-slot prefill_into builds exactly the cache (and
        first-token logits) a batched prefill would, for every cache family
        (uniform attn / ssm / hybrid)."""
        cfg = cb.get_config(arch, smoke=True)
        params = T.init_lm(cfg, jax.random.key(0))
        engine = ServeEngine(cfg, params)
        prompts = jax.random.randint(jax.random.key(1), (3, 8), 0,
                                     cfg.vocab_size)
        lg, cache = engine._prefill(params, prompts, 8 + 4)
        state = engine.init_decode(3, 8, 4)
        for s in (2, 0, 1):  # out of order: slot index is data, not shape
            state = engine.prefill_into(state, s, np.asarray(prompts[s]))
        np.testing.assert_array_equal(np.asarray(lg, np.float32),
                                      np.asarray(state.logits, np.float32))
        for k in cache:
            np.testing.assert_array_equal(
                np.asarray(cache[k], np.float32),
                np.asarray(state.cache[k], np.float32), err_msg=k)

    @pytest.mark.parametrize("arch", ["starcoder2_3b", "mamba2_130m"])
    def test_greedy_stream_bit_identical_to_one_shot(self, arch):
        """Greedy streams from the step-level loop == one-shot generate per
        request, through mid-stream slot refill (5 requests, 2 slots) and
        mixed per-request max_new."""
        cfg = cb.get_config(arch, smoke=True)
        params = T.init_lm(cfg, jax.random.key(0))
        engine = ServeEngine(cfg, params)
        rng = np.random.default_rng(0)
        max_news = [3, 5, 2, 4, 3]
        prompts = [rng.integers(0, cfg.vocab_size, 8) for _ in max_news]
        batcher = SlotBatcher(n_slots=2, prompt_len=8)
        for p, m in zip(prompts, max_news):
            batcher.submit(p, m)
        steps = stream_serve(engine, batcher)
        assert len(batcher.completed) == 5 and batcher.idle
        # this workload packs perfectly onto 2 slots (3+2+4 | 5+3), so the
        # scheduler must hit exactly ceil(sum/slots) emission steps — any
        # wasted or duplicated step breaks the equality
        assert steps == -(-sum(max_news) // 2)
        by_uid = {r.uid: r for r in batcher.completed}
        for uid, (p, m) in enumerate(zip(prompts, max_news)):
            assert len(by_uid[uid].generated) == m
            one = engine.generate(jnp.asarray(p, jnp.int32)[None], m)
            np.testing.assert_array_equal(
                np.asarray(by_uid[uid].generated),
                np.asarray(one.tokens)[0], err_msg=f"request {uid}")

    @pytest.mark.parametrize("arch", ["starcoder2_3b", "mamba2_130m"])
    def test_chunked_stream_bit_identical(self, arch):
        """``decode_chunk > 1`` (the multi-step on-device inner loop) emits
        the same streams AND the same step count as the one-token loop:
        clipping each chunk to ``batcher.min_remaining()`` keeps slot
        turnover on chunk boundaries, so refill timing never diverges."""
        cfg = cb.get_config(arch, smoke=True)
        params = T.init_lm(cfg, jax.random.key(0))
        engine = ServeEngine(cfg, params)

        def run(chunk):
            rng = np.random.default_rng(0)
            b = SlotBatcher(n_slots=2, prompt_len=8)
            for m in [3, 5, 2, 4, 3]:
                b.submit(rng.integers(0, cfg.vocab_size, 8), m)
            steps = stream_serve(engine, b, decode_chunk=chunk)
            return steps, {r.uid: list(r.generated) for r in b.completed}

        base = run(1)
        for chunk in (3, 64):   # mid-request boundary; chunk > total budget
            assert run(chunk) == base, f"decode_chunk={chunk}"

    def test_chunked_steady_state_has_no_implicit_transfers(self):
        """The whole point of the multi-step inner loop: a steady-state
        chunk crosses the host boundary exactly once, via an *explicit*
        ``jax.device_get`` of the token block. ``jax.transfer_guard
        ("disallow")`` turns any implicit transfer inside the chunk into an
        error, so this fails if a host round-trip sneaks back into the
        decode path (a ``float(...)``, an ``np.asarray`` on logits, a
        non-donated re-placement...)."""
        cfg = cb.get_config("starcoder2_3b", smoke=True)
        params = T.init_lm(cfg, jax.random.key(0))
        engine = ServeEngine(cfg, params)
        rng = np.random.default_rng(0)
        state = engine.init_decode(2, 8, 8)
        for s in (0, 1):  # prefill outside the guard: prompts are host data
            state = engine.prefill_into(
                state, s, rng.integers(0, cfg.vocab_size, 8))
        with jax.transfer_guard("disallow"):
            state, toks = engine.decode_steps(state, 4)
            chunk = jax.device_get(toks)       # the ONE allowed crossing
        assert chunk.shape == (2, 4)
        # and the chunk really advanced the decode state
        assert int(jax.device_get(state.cache["pos"])[0]) == 8 + 4

    def test_request_timing_ledger(self):
        cfg = cb.get_config("starcoder2_3b", smoke=True)
        params = T.init_lm(cfg, jax.random.key(0))
        engine = ServeEngine(cfg, params)
        batcher = SlotBatcher(n_slots=2, prompt_len=4)
        rng = np.random.default_rng(0)
        for _ in range(3):
            batcher.submit(rng.integers(0, cfg.vocab_size, 4), 2)
        stream_serve(engine, batcher)
        for r in batcher.completed:
            assert r.ttft is not None and r.ttft >= 0
            assert r.latency is not None and r.latency >= r.ttft

    def test_oversized_max_new_raises(self):
        cfg = cb.get_config("starcoder2_3b", smoke=True)
        params = T.init_lm(cfg, jax.random.key(0))
        engine = ServeEngine(cfg, params)
        batcher = SlotBatcher(n_slots=1, prompt_len=4)
        batcher.submit(np.arange(4), max_new=9)
        with pytest.raises(ValueError, match="max_new_cap"):
            stream_serve(engine, batcher, max_new_cap=4)


class TestServeCLI:
    def test_packed_cli_serves_without_mesh(self, monkeypatch, capsys):
        """Regression: the primary README serving path (--packed, no
        --mesh) must not forward the compiled plan to ServeEngine —
        plan= without mesh= is a placement error and raises."""
        import sys

        from repro.launch import serve as S

        monkeypatch.setattr(sys, "argv", [
            "serve", "--arch", "starcoder2-3b", "--smoke", "--packed",
            "--requests", "2", "--slots", "2", "--prompt-len", "4",
            "--max-new", "2"])
        S.main()
        out = capsys.readouterr().out
        assert "packed weights" in out
        assert "served 2 requests" in out


class TestServingAccounting:
    def test_tokens_generated_counts_recorded_tokens(self):
        """Regression for the round-loop counter bug: tok/s must come from
        tokens actually recorded — per-request max_new below the cap used
        to be over-credited (mask * global max_new), and slots completing
        within the round were dropped (mask read *after* record)."""
        b = SlotBatcher(n_slots=2, prompt_len=2)
        max_news = [1, 3, 2]
        for i, m in enumerate(max_news):
            b.submit(np.full(2, i), max_new=m)
        cap, legacy_count = 3, 0
        while not b.idle:
            b.refill()
            for _ in range(cap):          # the old round-based recording
                b.record(np.arange(2))
            legacy_count += int(b.active_mask().sum()) * cap
        b.refill()
        assert b.tokens_generated == sum(max_news) == 6
        assert sum(len(r.generated) for r in b.completed) == 6
        # the legacy formula reads the mask after the round completed every
        # slot, so it credits 0 — any steps-times-mask arithmetic is wrong
        assert legacy_count != b.tokens_generated

    def test_tokens_generated_includes_in_flight(self):
        b = SlotBatcher(n_slots=1, prompt_len=2)
        b.submit(np.zeros(2), max_new=4)
        b.refill()
        b.record(np.zeros(1))
        assert b.tokens_generated == 1  # mid-stream, not yet completed


class TestPackedParamBytes:
    def test_dense_baseline_is_true_master_bytes(self):
        """The dense side of the bytes report must equal the bf16 size of
        the *master* tree — K-padded packed layouts (xnor conv's per-tap
        channel padding when C % 32 != 0) must not inflate it."""
        from repro.launch.train import make_paper_policy
        from repro.models import vgg
        tree = vgg.init(jax.random.key(0), width_mult=0.125)
        params = tree["params"]
        assert params["conv"][1]["kernel"].shape[2] % 32 != 0  # K-padded
        packed = pack_params(params, make_paper_policy(len(params["fc"])),
                             "xnor")
        dense_b, packed_b = packed_param_bytes(packed)
        true_dense = sum(leaf.size * 2
                         for leaf in jax.tree_util.tree_leaves(params))
        assert dense_b == true_dense
        assert packed_b < dense_b

    def test_padded_word_layout_reports_master_shape(self):
        """A leaf whose packed array carries extra self-cancelling pad words
        (legal for per-tap layouts) still reports true-K dense bytes."""
        k, n, extra = 64, 8, 3
        packed = jnp.zeros((k // 32 + extra, n), jnp.int32)
        leaf = XnorLinear(packed, None, k)
        assert leaf.master_shape == (k, n)
        dense_b, packed_b = packed_param_bytes({"w": leaf})
        assert dense_b == k * n * 2                 # true master, no pad
        assert packed_b == packed.size * 4          # stored words, with pad

    def test_stacked_master_shape(self):
        pl = PackedLinear(jnp.zeros((5, 2, 64, 7), jnp.int32), None, 64)
        assert pl.master_shape == (5, 2, 64, 7)
        xc = XnorConv(jnp.zeros((9, 4), jnp.int32), None, (3, 3), 20)
        assert xc.master_shape == (3, 3, 20, 4)


class TestTemperedLogprobs:
    def test_logprobs_under_sampled_distribution(self):
        """With temperature > 0, reported logprobs are under the tempered
        softmax(logits / T) the token was drawn from (teacher-forced
        recompute through the full forward pass)."""
        cfg = cb.get_config("starcoder2_3b", smoke=True)
        params = T.init_lm(cfg, jax.random.key(0))
        engine = ServeEngine(cfg, params)
        prompts = jax.random.randint(jax.random.key(1), (2, 6), 0,
                                     cfg.vocab_size)
        temp = 0.7
        out = engine.generate(prompts, max_new=3, temperature=temp,
                              key=jax.random.key(2))
        seq = prompts
        for i in range(3):
            logits, _ = T.forward(cfg, params, seq)
            lp = jax.nn.log_softmax(
                logits[:, -1].astype(jnp.float32) / temp, axis=-1)
            want = jnp.take_along_axis(lp, out.tokens[:, i][:, None],
                                       axis=-1)[:, 0]
            np.testing.assert_allclose(np.asarray(out.logprobs[:, i]),
                                       np.asarray(want), rtol=2e-3, atol=2e-3)
            seq = jnp.concatenate([seq, out.tokens[:, i][:, None]], axis=1)

    def test_greedy_logprobs_untempered(self):
        cfg = cb.get_config("starcoder2_3b", smoke=True)
        params = T.init_lm(cfg, jax.random.key(0))
        engine = ServeEngine(cfg, params)
        prompts = jax.random.randint(jax.random.key(1), (1, 6), 0,
                                     cfg.vocab_size)
        out = engine.generate(prompts, max_new=1)
        logits, _ = T.forward(cfg, params, prompts)
        lp = jax.nn.log_softmax(logits[:, -1].astype(jnp.float32), axis=-1)
        want = jnp.take_along_axis(lp, out.tokens[:, 0][:, None], axis=-1)[:, 0]
        np.testing.assert_allclose(np.asarray(out.logprobs[:, 0]),
                                   np.asarray(want), rtol=2e-3, atol=2e-3)


class TestSlotBatcher:
    def test_fills_and_completes(self):
        b = SlotBatcher(n_slots=2, prompt_len=4)
        for i in range(5):
            b.submit(np.full(4, i), max_new=3)
        rounds = 0
        while not b.idle:
            b.refill()
            for _ in range(3):
                b.record(np.arange(2))
            rounds += 1
        b.refill()
        assert len(b.completed) == 5
        assert rounds == 3  # ceil(5/2)
        assert all(len(r.generated) == 3 for r in b.completed)

    def test_left_pads_short_prompts(self):
        b = SlotBatcher(n_slots=1, prompt_len=6, pad_id=9)
        b.submit(np.array([1, 2]), max_new=1)
        b.refill()
        np.testing.assert_array_equal(b.prompts()[0],
                                      np.array([9, 9, 9, 9, 1, 2]))
        assert not b.slots[0].truncated

    def test_truncates_long_prompts_to_suffix(self):
        """A prompt longer than the slot width keeps its LAST prompt_len
        tokens (what the next token conditions on), not the first, and the
        request records that it was truncated."""
        b = SlotBatcher(n_slots=1, prompt_len=4)
        b.submit(np.arange(10), max_new=1)
        b.refill()
        np.testing.assert_array_equal(b.prompts()[0], np.array([6, 7, 8, 9]))
        assert b.slots[0].truncated

    def test_refill_retires_and_reuses_slot_in_one_step(self):
        """A slot finishing while the queue is non-empty is retired AND
        refilled by the same refill() call — no idle round in between."""
        b = SlotBatcher(n_slots=2, prompt_len=2)
        for i in range(3):
            b.submit(np.full(2, i), max_new=1)
        b.refill()
        first = [r.uid for r in b.slots]
        for _ in range(1):
            b.record(np.arange(2))  # both slots finish this step
        changed = b.refill()
        # both finished slots retired; slot 0 immediately holds request 2
        assert [r.uid for r in b.completed] == first
        assert changed == [0]
        assert b.slots[0] is not None and b.slots[0].uid == 2
        assert b.slots[1] is None
        assert not b.idle

    def test_all_slots_empty_decodes_masked_padding(self):
        """With every slot empty, the batch decodes pure padding: the mask
        is all-False, prompts are all pad_id, and record() is a no-op."""
        b = SlotBatcher(n_slots=3, prompt_len=4, pad_id=7)
        b.submit(np.arange(4), max_new=1)
        b.refill()
        b.record(np.arange(3))
        b.refill()  # retires the only request; queue empty
        assert b.idle and len(b.completed) == 1
        np.testing.assert_array_equal(b.active_mask(),
                                      np.zeros(3, dtype=bool))
        np.testing.assert_array_equal(b.prompts(),
                                      np.full((3, 4), 7, np.int32))
        b.record(np.arange(3))  # decode output of an all-empty batch
        assert all(r is None for r in b.slots)
        assert len(b.completed[0].generated) == 1  # nothing appended

    def test_prefilling_slots_excluded_from_ledger(self):
        """A slot marked prefilling is occupied (not refilled, not idle)
        but invisible to record / active_mask / min_remaining until
        mark_ready — so its t_first can only ever stamp on a *generated*
        token."""
        b = SlotBatcher(n_slots=2, prompt_len=4)
        b.submit(np.arange(4), max_new=2)
        b.submit(np.arange(4), max_new=5)
        b.refill()
        b.mark_prefilling(1)
        assert b.active_mask().tolist() == [True, False]
        assert b.min_remaining() == 2       # slot 1's budget of 5 ignored
        assert not b.idle
        b.record(np.array([7, 9]))
        assert b.slots[0].generated == [7]
        assert b.slots[1].generated == []   # no decode garbage
        assert b.slots[1].t_first is None
        b.mark_ready(1)
        assert b.active_mask().tolist() == [True, True]
        assert b.min_remaining() == 1
        b.record(np.array([3, 4]))
        assert b.slots[1].generated == [4]
        assert b.slots[1].t_first is not None


def _check_schedule(n_slots, prompt_len, ops):
    """Drive a SlotBatcher through an arbitrary submit/refill/record/
    prefill-toggle schedule and assert the ledger invariants after every
    step: ``tokens_generated`` equals tokens actually recorded, timestamps
    are ordered ``t_submit <= t_first <= t_done``, ``t_done`` implies the
    full ``max_new`` budget, and truncation keeps the prompt SUFFIX."""
    rng = np.random.default_rng(1234)
    b = SlotBatcher(n_slots, prompt_len)
    submitted = {}
    recorded = 0
    for op in ops:
        kind = op[0]
        if kind == "submit":
            plen, max_new = op[1], op[2]
            prompt = rng.integers(0, 100, plen).astype(np.int32)
            uid = b.submit(prompt, max_new)
            submitted[uid] = (prompt, max_new)
        elif kind == "refill":
            b.refill()
        elif kind == "record":
            active = b.active_mask()
            b.record(rng.integers(0, 100, n_slots))
            recorded += int(active.sum())
        elif kind == "prefill_toggle":
            slot = op[1] % n_slots
            if slot in b.prefilling:
                b.mark_ready(slot)
            elif b.slots[slot] is not None and not b.slots[slot].done:
                b.mark_prefilling(slot)
        assert b.tokens_generated == recorded
    b.refill()
    live = [r for r in b.slots if r is not None]
    for r in b.completed + live + list(b.queue):
        prompt, max_new = submitted[r.uid]
        assert len(r.generated) <= max_new
        if r.t_first is not None:
            assert r.t_submit <= r.t_first
        if r.t_done is not None:
            assert r.t_first is not None and r.t_first <= r.t_done
            assert len(r.generated) == max_new
        if len(prompt) >= b.prompt_len:
            np.testing.assert_array_equal(r.prompt,
                                          prompt[-b.prompt_len:])
            assert r.truncated == (len(prompt) > b.prompt_len)
        else:
            np.testing.assert_array_equal(
                r.prompt[b.prompt_len - len(prompt):], prompt)
            assert not r.truncated
            assert (r.prompt[:b.prompt_len - len(prompt)] ==
                    b.pad_id).all()


try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


if HAVE_HYPOTHESIS:
    # The @given/@settings decorators need hypothesis at class-definition
    # time, so the property class only exists where it is installed; the
    # seeded sweep below exercises the identical checker everywhere.
    class TestSlotBatcherProperties:
        """Property-based ledger invariants: hypothesis explores the
        submit/refill/record/prefill-toggle schedule space."""

        @settings(max_examples=60, deadline=None)
        @given(n_slots=st.integers(1, 4), prompt_len=st.integers(1, 8),
               ops=st.lists(st.one_of(
                   st.tuples(st.just("submit"), st.integers(1, 12),
                             st.integers(1, 6)),
                   st.tuples(st.just("refill")),
                   st.tuples(st.just("record")),
                   st.tuples(st.just("prefill_toggle"),
                             st.integers(0, 7))),
                   max_size=60))
        def test_ledger_invariants(self, n_slots, prompt_len, ops):
            _check_schedule(n_slots, prompt_len, ops)


class TestSlotBatcherRandomSchedules:
    def test_ledger_invariants_random(self):
        """Seeded sweep over 40 random schedules through the same
        invariant checker as the hypothesis properties, so the invariants
        run in tier-1 even where hypothesis is unavailable."""
        rng = np.random.default_rng(7)
        kinds = ["submit", "refill", "record", "record", "prefill_toggle"]
        for _ in range(40):
            n_slots = int(rng.integers(1, 5))
            prompt_len = int(rng.integers(1, 9))
            ops = []
            for _ in range(int(rng.integers(5, 60))):
                k = kinds[int(rng.integers(0, len(kinds)))]
                if k == "submit":
                    ops.append(("submit", int(rng.integers(1, 13)),
                                int(rng.integers(1, 7))))
                elif k == "prefill_toggle":
                    ops.append(("prefill_toggle", int(rng.integers(0, 8))))
                else:
                    ops.append((k,))
            _check_schedule(n_slots, prompt_len, ops)


class TestChunkedPrefillServing:
    def test_ttft_stamps_on_first_generated_token(self):
        """TTFT regression under chunked prefill: with prompts spanning
        three chunks, ``t_first`` must stamp when the first GENERATED
        token lands — never while prefill chunks are completing — and no
        prefill-step garbage may land in the ledger. Streams stay
        bit-identical to one-shot generate."""
        cfg = cb.get_config("starcoder2_3b", smoke=True)
        params = T.init_lm(cfg, jax.random.key(0))
        engine = ServeEngine(cfg, params)
        prompt_len, chunk = 9, 3  # ceil(9/3) = 3 chunks per prompt
        rng = np.random.default_rng(0)
        prompts = [rng.integers(1, cfg.vocab_size, prompt_len)
                   for _ in range(3)]
        b = SlotBatcher(n_slots=2, prompt_len=prompt_len)
        for p in prompts:
            b.submit(p, 4)
        stream_serve(engine, b, max_new_cap=4, prefill_chunk=chunk)
        assert b.idle and len(b.completed) == 3
        for r in b.completed:
            assert len(r.generated) == 4     # exactly max_new, no garbage
            assert r.t_first is not None and r.t_done is not None
            assert r.t_submit <= r.t_first <= r.t_done
            one = engine.generate(
                jnp.asarray(prompts[r.uid], jnp.int32)[None], 4)
            np.testing.assert_array_equal(np.asarray(r.generated),
                                          np.asarray(one.tokens)[0])
        # request 2 waited for a slot: its first token cannot precede the
        # earlier admissions' (prefill chunks never stamp t_first)
        t = {r.uid: r.t_first for r in b.completed}
        assert t[2] >= max(t[0], t[1])
