"""Fault tolerance: checkpoint roundtrip/atomicity, crash-recovery with
bit-exact replay, elastic re-mesh, straggler policy, data determinism."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.core.policy import BinarizePolicy
from repro.data import pipeline, synthetic as syn
from repro.ft.elastic import adjust_microbatching, best_mesh_shape
from repro.ft.failures import FailureInjector
from repro.ft.straggler import StragglerMonitor
from repro.models import mnist_fc
from repro.optim import schedules
from repro.optim.sgd import sgd_momentum
from repro.train import steps as ST
from repro.train.trainer import Trainer, TrainerConfig

POLICY = BinarizePolicy(include=(r".*kernel$",), exclude=(r"layers/0/kernel",))


def _state_and_step(mode="det", seed=0):
    tree = mnist_fc.init(jax.random.key(seed), hidden=(32, 32))
    opt = sgd_momentum(schedules.constant(0.05))
    step = ST.make_train_step(ST.make_classifier_loss(mnist_fc.apply),
                              opt, mode, POLICY, has_model_state=True)
    state = ST.init_train_state(tree["params"], opt, seed=seed,
                                model_state=tree["state"])
    return state, step


def _batch_fn(spec):
    def fn(step):
        x, y = syn.train_batch(spec, step)
        return {"x": x.reshape(x.shape[0], -1), "y": y}
    return fn


class TestCheckpointManager:
    def test_roundtrip_exact(self, tmp_path):
        state, _ = _state_and_step()
        mgr = CheckpointManager(str(tmp_path), async_save=False)
        mgr.save(7, state)
        restored = mgr.restore(state)
        for a, b in zip(jax.tree.leaves(jax.tree.map(
                lambda x: x, state)), jax.tree.leaves(restored)):
            if jax.dtypes.issubdtype(a.dtype, jax.dtypes.prng_key):
                a, b = jax.random.key_data(a), jax.random.key_data(b)
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_keep_k_gc(self, tmp_path):
        state, _ = _state_and_step()
        mgr = CheckpointManager(str(tmp_path), keep=2, async_save=False)
        for s in (1, 2, 3, 4):
            mgr.save(s, state)
        assert mgr.all_steps() == [3, 4]

    def test_uncommitted_ignored(self, tmp_path):
        state, _ = _state_and_step()
        mgr = CheckpointManager(str(tmp_path), async_save=False)
        mgr.save(1, state)
        # simulate a crash mid-write: directory without COMMITTED marker
        os.makedirs(tmp_path / "step_0000000002")
        assert mgr.latest_step() == 1

    def test_async_save(self, tmp_path):
        state, _ = _state_and_step()
        mgr = CheckpointManager(str(tmp_path), async_save=True)
        mgr.save(5, state)
        mgr.wait()
        assert mgr.latest_step() == 5

    def test_shape_mismatch_fails_loudly(self, tmp_path):
        state, _ = _state_and_step()
        mgr = CheckpointManager(str(tmp_path), async_save=False)
        mgr.save(1, state)
        bad, _ = _state_and_step()
        bad["params"]["layers"][0]["kernel"] = jnp.zeros((7, 7))
        with pytest.raises(ValueError):
            mgr.restore(bad)


class TestCrashRecovery:
    def test_recovery_is_bit_exact(self, tmp_path):
        """A crash + restore must reproduce the uninterrupted trajectory,
        because batches and step RNG are pure functions of the step index."""
        spec = syn.SyntheticSpec("mnist", n_train=640, batch_size=32)

        def run(fail_at, ckdir):
            state, step = _state_and_step()
            trainer = Trainer(
                TrainerConfig(total_steps=30, checkpoint_dir=str(ckdir),
                              checkpoint_every=10, log_every=1,
                              async_checkpoint=False),
                step, _batch_fn(spec), state,
                failure_injector=FailureInjector(fail_at))
            trainer.run()
            return trainer

        t_clean = run((), tmp_path / "clean")
        t_crash = run((17, 23), tmp_path / "crash")
        assert t_crash.recoveries == 2
        final_clean = t_clean.ckpt.restore(t_clean.state)
        final_crash = t_crash.ckpt.restore(t_crash.state)
        for a, b in zip(jax.tree.leaves(final_clean["params"]),
                        jax.tree.leaves(final_crash["params"])):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        losses_clean = [h["loss"] for h in t_clean.history]
        # crash run re-logs replayed steps; compare the last entries
        losses_crash = [h["loss"] for h in t_crash.history][-len(losses_clean):]
        np.testing.assert_allclose(losses_crash[-5:], losses_clean[-5:])

    def test_recovery_budget(self, tmp_path):
        spec = syn.SyntheticSpec("mnist", n_train=640, batch_size=32)
        state, step = _state_and_step()
        trainer = Trainer(
            TrainerConfig(total_steps=10, checkpoint_dir=str(tmp_path),
                          max_recoveries=2, async_checkpoint=False),
            step, _batch_fn(spec), state,
            failure_injector=FailureInjector((3, 3, 3, 3)))
        # failure at step 3 fires once per arming; single entry => recovers
        trainer.run()
        assert trainer.recoveries == 1


class TestElastic:
    def test_best_mesh_shape(self):
        assert best_mesh_shape(256, 16) == (16, 16)
        assert best_mesh_shape(192, 16) == (12, 16)
        assert best_mesh_shape(7, 16) == (7, 1)

    def test_adjust_microbatching(self):
        assert adjust_microbatching(256, 256, 128, 1) == 2
        assert adjust_microbatching(256, 256, 256, 1) == 1
        assert adjust_microbatching(256, 256, 96, 1) == 3

    def test_reshard_roundtrip_single_device(self):
        from jax.sharding import PartitionSpec as P
        from repro.ft.elastic import make_elastic_mesh, reshard

        mesh = make_elastic_mesh(model_parallel=1)
        tree = {"w": jnp.arange(16.0).reshape(4, 4)}
        specs = {"w": P(None, "model")}
        out = reshard(tree, specs, mesh)
        np.testing.assert_array_equal(np.asarray(out["w"]),
                                      np.asarray(tree["w"]))


class TestStraggler:
    def test_detection(self):
        mon = StragglerMonitor(window=20, threshold=2.0, patience=3)
        for _ in range(20):
            assert not mon.is_straggling(1.0)
        flags = [mon.is_straggling(5.0) for _ in range(3)]
        assert flags == [False, False, True]

    def test_recovers_after_transient(self):
        mon = StragglerMonitor(window=20, threshold=2.0, patience=3)
        for _ in range(20):
            mon.is_straggling(1.0)
        mon.is_straggling(5.0)
        assert not mon.is_straggling(1.0)  # streak reset

    def test_skip_ahead(self):
        assert pipeline.skip_ahead(10, 15) == 15
        assert pipeline.skip_ahead(10, 5) == 10
        assert pipeline.skip_ahead(0, 10**9, max_skip=100) == 100


class TestDataPipeline:
    def test_batches_are_step_pure(self):
        spec = syn.SyntheticSpec("lm", n_train=1000, batch_size=4,
                                 seq_len=16, vocab_size=97)
        a = syn.lm_tokens(spec, 42)
        b = syn.lm_tokens(spec, 42)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        c = syn.lm_tokens(spec, 43)
        assert not np.array_equal(np.asarray(a), np.asarray(c))

    def test_prefetcher_order_and_close(self):
        fetched = []
        pf = pipeline.Prefetcher(lambda i: i * i, start_step=3, depth=2)
        it = iter(pf)
        for _ in range(4):
            step, val = next(it)
            fetched.append((step, val))
        pf.close()
        assert fetched == [(3, 9), (4, 16), (5, 25), (6, 36)]

    def test_host_slice(self):
        s = pipeline.host_slice(64, process_index=2, process_count=8)
        assert (s.start, s.stop) == (16, 24)

    def test_labels_in_range(self):
        spec = syn.SyntheticSpec("mnist", n_train=100, batch_size=16)
        x, y = syn.train_batch(spec, 0)
        assert x.shape == (16, 784) and y.shape == (16,)
        assert (np.asarray(y) >= 0).all() and (np.asarray(y) < 10).all()
        assert (np.asarray(x) >= 0).all() and (np.asarray(x) <= 1).all()
