"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs the jnp oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis; "
    "tests/test_xnor.py covers the kernels without it")
st = pytest.importorskip("hypothesis.strategies")

from repro.core import packing as P
from repro.kernels import ops, ref
from repro.kernels.binary_matmul import binary_matmul_pallas
from repro.kernels.stoch_binarize import binarize_pack_pallas


class TestPacking:
    @hypothesis.given(st.integers(1, 8), st.integers(1, 33))
    @hypothesis.settings(max_examples=20, deadline=None)
    def test_roundtrip(self, k32, n):
        key = jax.random.key(k32 * 100 + n)
        pm1 = jnp.where(jax.random.bernoulli(key, 0.5, (k32 * 32, n)), 1., -1.)
        np.testing.assert_array_equal(P.unpack_bits(P.pack_bits(pm1)), pm1)

    def test_pad_to_pack(self):
        w = jnp.ones((33, 4))
        wp = P.pad_to_pack(w)
        assert wp.shape == (64, 4)
        np.testing.assert_array_equal(wp[33:], -jnp.ones((31, 4)))

    def test_compression_ratio(self):
        assert P.compression_ratio((1024, 1024), dtype_bytes=2) == 16.0
        assert P.compression_ratio((1024, 1024), dtype_bytes=4) == 32.0


# (M, K, N) sweeps: MXU-aligned, ragged, tiny.
MATMUL_SHAPES = [
    (128, 512, 128), (256, 1024, 384), (200, 512, 100), (8, 512, 128),
    (128, 544, 128),  # K not multiple of block but multiple of 32
]


class TestBinaryMatmulKernel:
    @pytest.mark.parametrize("m,k,n", MATMUL_SHAPES)
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_oracle(self, m, k, n, dtype):
        kx, kw = jax.random.split(jax.random.key(m * k + n))
        x = jax.random.normal(kx, (m, k), jnp.float32).astype(dtype)
        wp = ops.binarize_and_pack(jax.random.normal(kw, (k, n)))
        # ops picks compute dtype from the input (f32 in / f32 compute);
        # compare the oracle under the same compute dtype
        cd = jnp.float32 if dtype == jnp.float32 else jnp.bfloat16
        got = ops.binary_matmul(x, wp, block_k=256)
        want = ref.binary_matmul_ref(x, wp, compute_dtype=cd)
        # f32 kernel accumulates per K-block: summation-order noise ~1e-4
        tol = 1e-3 if dtype == jnp.float32 else 3e-2
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=tol, atol=tol)

    def test_matches_dense_matmul(self):
        x = jax.random.normal(jax.random.key(0), (256, 512))
        w = jax.random.normal(jax.random.key(1), (512, 256))
        wp = ops.binarize_and_pack(w)
        dense = x @ jnp.where(w > 0, 1., -1.)
        got = ops.binary_matmul(x, wp, block_k=256)
        np.testing.assert_allclose(np.asarray(got),
                                   np.asarray(dense, np.float32),
                                   rtol=1e-4, atol=1e-3)

    def test_scaled(self):
        x = jax.random.normal(jax.random.key(2), (128, 512))
        wp = ops.binarize_and_pack(jax.random.normal(jax.random.key(3), (512, 128)))
        s = jax.random.uniform(jax.random.key(4), (128,), minval=0.5, maxval=2.0)
        got = ops.binary_matmul(x, wp, s, block_k=256)
        want = ref.binary_matmul_ref(x, wp, s, compute_dtype=jnp.float32)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-3)

    def test_batched_leading_dims(self):
        x = jax.random.normal(jax.random.key(5), (4, 32, 512))
        wp = ops.binarize_and_pack(jax.random.normal(jax.random.key(6), (512, 64)))
        got = ops.binary_matmul(x, wp)
        assert got.shape == (4, 32, 64)
        want = ref.binary_matmul_ref(
            x.reshape(-1, 512), wp,
            compute_dtype=jnp.float32).reshape(4, 32, 64)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-3)

    def test_block_spec_direct(self):
        """Direct pallas_call with exact blocks (no padding path)."""
        x = jax.random.normal(jax.random.key(7), (256, 1024))
        wp = ops.binarize_and_pack(jax.random.normal(jax.random.key(8), (1024, 256)))
        got = binary_matmul_pallas(x, wp, block_m=128, block_n=128,
                                   block_k=256, interpret=True)
        want = ref.binary_matmul_ref(x, wp)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=3e-2, atol=3e-2)


class TestBinarizePackKernel:
    @pytest.mark.parametrize("k,n", [(256, 256), (512, 384), (300, 100)])
    def test_det_matches_oracle(self, k, n):
        w = jax.random.normal(jax.random.key(k + n), (k, n))
        got = ops.binarize_and_pack(w, stochastic=False)
        want = ref.det_binarize_pack_ref(P.pad_to_pack(w))[:, :n]
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_stoch_matches_oracle_same_bits(self):
        w = jax.random.normal(jax.random.key(0), (512, 256))
        key = jax.random.key(42)
        got = ops.binarize_and_pack(w, key, stochastic=True)
        bits = jax.random.bits(key, (512, 256), jnp.uint32)
        want = ref.stoch_binarize_pack_ref(w, bits)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_stoch_distribution(self):
        w = jnp.full((512, 512), 0.5)  # P(+1) = 0.75
        packed = ops.binarize_and_pack(w, jax.random.key(1), stochastic=True)
        frac = float((P.unpack_bits(packed) > 0).mean())
        assert abs(frac - 0.75) < 0.01

    def test_det_pallas_direct(self):
        w = jax.random.normal(jax.random.key(2), (512, 512))
        got = binarize_pack_pallas(w, stochastic=False, interpret=True)
        want = ref.det_binarize_pack_ref(w)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_roundtrip_through_matmul(self):
        """Pack with the kernel, multiply with the kernel: end-to-end."""
        w = jax.random.normal(jax.random.key(3), (512, 128))
        x = jax.random.normal(jax.random.key(4), (64, 512))
        wp = ops.binarize_and_pack(w)
        got = ops.binary_matmul(x, wp, block_k=256)
        want = x @ jnp.where(w > 0, 1., -1.)
        np.testing.assert_allclose(np.asarray(got),
                                   np.asarray(want, np.float32),
                                   rtol=1e-4, atol=1e-3)
