"""Unit + property tests for the paper's core technique (Eq. 1-3, Alg. 1)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis")
hnp = pytest.importorskip("hypothesis.extra.numpy")
st = pytest.importorskip("hypothesis.strategies")

from repro.core import binarize as B
from repro.core.policy import DEFAULT_POLICY, NONE_POLICY, BinarizePolicy

floats = hnp.arrays(np.float32, hnp.array_shapes(min_dims=1, max_dims=3,
                                                 max_side=16),
                    elements=st.floats(-4, 4, width=32))


class TestHardSigmoid:
    def test_eq3_values(self):
        # sigma(x) = clip((x+1)/2, 0, 1)
        xs = jnp.array([-3.0, -1.0, -0.5, 0.0, 0.5, 1.0, 3.0])
        expect = jnp.array([0.0, 0.0, 0.25, 0.5, 0.75, 1.0, 1.0])
        np.testing.assert_allclose(B.hard_sigmoid(xs), expect)

    @hypothesis.given(floats)
    def test_range(self, w):
        s = np.asarray(B.hard_sigmoid(jnp.asarray(w)))
        assert (s >= 0).all() and (s <= 1).all()


class TestDeterministic:
    def test_eq1_sign_convention(self):
        # w <= 0 -> -1 (including exactly 0), else +1
        w = jnp.array([-2.0, -0.0, 0.0, 1e-9, 2.0])
        np.testing.assert_array_equal(
            B.deterministic_binarize(w), jnp.array([-1, -1, -1, 1, 1.0]))

    @hypothesis.given(floats)
    def test_values_are_pm1(self, w):
        wb = np.asarray(B.deterministic_binarize(jnp.asarray(w)))
        assert set(np.unique(wb)).issubset({-1.0, 1.0})

    @hypothesis.given(floats)
    def test_idempotent(self, w):
        wb = B.deterministic_binarize(jnp.asarray(w))
        np.testing.assert_array_equal(B.deterministic_binarize(wb), wb)


class TestStochastic:
    def test_eq2_probability(self):
        # empirical P(+1) ~= hard_sigmoid(w)
        for wval in (-0.8, -0.2, 0.0, 0.4, 0.9):
            w = jnp.full((200_000,), wval)
            wb = B.stochastic_binarize(w, jax.random.key(0))
            p_hat = float((wb > 0).mean())
            assert abs(p_hat - float(B.hard_sigmoid(wval))) < 0.01, wval

    def test_saturation_is_deterministic(self):
        w = jnp.array([-1.0, -5.0, 1.0, 5.0])
        wb = B.stochastic_binarize(w, jax.random.key(1))
        np.testing.assert_array_equal(wb, jnp.array([-1.0, -1.0, 1.0, 1.0]))

    def test_reproducible_given_key(self):
        w = jax.random.normal(jax.random.key(2), (128,))
        a = B.stochastic_binarize(w, jax.random.key(3))
        b = B.stochastic_binarize(w, jax.random.key(3))
        np.testing.assert_array_equal(a, b)


class TestSTE:
    def test_gradient_passes_through(self):
        w = jax.random.normal(jax.random.key(0), (32, 16))
        coef = jax.random.normal(jax.random.key(1), (32, 16))

        def loss(w):
            return jnp.sum(B.binarize(w, "det") * coef)

        np.testing.assert_allclose(jax.grad(loss)(w), coef, rtol=1e-6)

    def test_stochastic_ste(self):
        w = jax.random.normal(jax.random.key(0), (64,))

        def loss(w):
            return jnp.sum(B.binarize(w, "stoch", jax.random.key(5)) ** 2
                           + 3.0 * B.binarize(w, "stoch", jax.random.key(5)))

        g = jax.grad(loss)(w)
        wb = B.binarize(w, "stoch", jax.random.key(5))
        np.testing.assert_allclose(g, 2 * wb + 3.0, rtol=1e-5)

    def test_forward_value_is_binary(self):
        w = jax.random.normal(jax.random.key(0), (8, 8))
        wb = np.asarray(B.binarize(w, "det"))
        assert set(np.unique(wb)).issubset({-1.0, 1.0})


class TestClip:
    @hypothesis.given(floats)
    def test_bounds(self, w):
        c = np.asarray(B.clip_weights(jnp.asarray(w)))
        assert (c >= -1).all() and (c <= 1).all()

    def test_identity_inside(self):
        w = jnp.array([-0.99, 0.0, 0.5])
        np.testing.assert_array_equal(B.clip_weights(w), w)


class TestTreeAPI:
    def _params(self):
        return {
            "layers": {"attn": {"w_qkv": jnp.ones((4, 8)) * 0.3,
                                "b_qkv": jnp.ones((8,)) * 0.3},
                       "ln1": {"scale": jnp.ones((4,)) * 0.3}},
            "embed": {"embedding": jnp.ones((16, 4)) * 0.3},
        }

    def test_policy_selection(self):
        p = self._params()
        sel = DEFAULT_POLICY.selected_paths(p)
        assert sel == ["layers/attn/w_qkv"]

    def test_binarize_tree_respects_policy(self):
        p = self._params()
        out = B.binarize_tree(p, "det", DEFAULT_POLICY)
        np.testing.assert_array_equal(out["layers"]["attn"]["w_qkv"],
                                      jnp.ones((4, 8)))
        np.testing.assert_array_equal(out["layers"]["ln1"]["scale"],
                                      p["layers"]["ln1"]["scale"])
        np.testing.assert_array_equal(out["embed"]["embedding"],
                                      p["embed"]["embedding"])

    def test_none_mode_is_identity(self):
        p = self._params()
        out = B.binarize_tree(p, "none", DEFAULT_POLICY)
        assert out is p

    def test_clip_tree(self):
        p = {"layers": {"attn": {"w_qkv": jnp.array([[-3.0, 0.5, 3.0]])}},
             "embed": {"embedding": jnp.array([[5.0]])}}
        out = B.clip_tree(p, DEFAULT_POLICY)
        np.testing.assert_array_equal(out["layers"]["attn"]["w_qkv"],
                                      jnp.array([[-1.0, 0.5, 1.0]]))
        # embeddings are not clipped (not selected)
        np.testing.assert_array_equal(out["embed"]["embedding"],
                                      jnp.array([[5.0]]))

    def test_stochastic_tree_needs_key(self):
        with pytest.raises(ValueError):
            B.binarize_tree(self._params(), "stoch", DEFAULT_POLICY)


class TestPolicy:
    def test_none_policy(self):
        assert not NONE_POLICY.selects("layers/attn/w_qkv")

    def test_custom_policy(self):
        pol = BinarizePolicy(include=(r".*kernel$",),
                             exclude=(r"first/kernel",))
        assert pol.selects("second/kernel")
        assert not pol.selects("first/kernel")
        assert not pol.selects("second/bias")
