"""Training-substrate tests: Alg. 1 end-to-end learning, optimizers, the
paper's Eq.-4 schedule, gradient compression, microbatching."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.policy import BinarizePolicy, NONE_POLICY
from repro.data import synthetic as syn
from repro.models import mnist_fc
from repro.optim import compression, schedules
from repro.optim.sgd import adamw, clip_by_global_norm, global_norm, sgd_momentum
from repro.train import steps as ST

# BNN convention: first and last (classifier) layers stay full precision.
POLICY = BinarizePolicy(include=(r".*kernel$",),
                        exclude=(r"layers/0/kernel", r"layers/2/kernel"))


def _setup(mode, hidden=(64, 64), batch=64, use_compression=False,
           microbatches=1):
    tree = mnist_fc.init(jax.random.key(0), hidden=hidden)
    opt = sgd_momentum(schedules.constant(0.05), momentum=0.9)
    loss_fn = ST.make_classifier_loss(mnist_fc.apply)
    step = ST.make_train_step(loss_fn, opt, mode,
                              POLICY if mode != "none" else NONE_POLICY,
                              has_model_state=True,
                              use_compression=use_compression,
                              microbatches=microbatches)
    state = ST.init_train_state(tree["params"], opt, model_state=tree["state"],
                                use_compression=use_compression)
    spec = syn.SyntheticSpec("mnist", n_train=6000, batch_size=batch)
    return jax.jit(step), state, spec


@pytest.mark.parametrize("mode", ["none", "det", "stoch"])
def test_learns_synthetic_mnist(mode):
    """The paper's core claim at unit scale: binarized (det & stoch) nets
    train to high accuracy, closely tracking the unregularized net."""
    step, state, spec = _setup(mode)
    for i in range(150):
        x, y = syn.train_batch(spec, i)
        state, metrics = step(state, {"x": x.reshape(x.shape[0], -1), "y": y})
    from repro.train.steps import make_eval_fn
    from repro.core import binarize as B

    eval_fn = make_eval_fn(mnist_fc.apply)
    params = state["params"]
    model_state = state["model_state"]
    if mode != "none":  # inference runs on binarized weights (Alg. 1)
        params = B.binarize_tree(params, "det", POLICY)
    if mode == "stoch":  # BN stats were accumulated under random sign draws
        cal = [syn.train_batch(spec, 10_000 + j)[0].reshape(-1, 784)
               for j in range(20)]
        model_state = ST.recalibrate_bn(mnist_fc.apply, params, model_state, cal)
    x, y = syn.eval_batch(spec)
    _, acc = eval_fn(params, model_state, x.reshape(x.shape[0], -1), y)
    assert float(acc) > 0.9, f"{mode}: accuracy {float(acc)}"


def test_masters_clipped_and_binary_values_used():
    step, state, spec = _setup("det")
    x, y = syn.train_batch(spec, 0)
    state, _ = step(state, {"x": x.reshape(x.shape[0], -1), "y": y})
    w = state["params"]["layers"][1]["kernel"]
    assert float(jnp.abs(w).max()) <= 1.0  # Alg. 1 step 4


def test_eq4_schedule_closed_form():
    sched = schedules.paper_eq4(1e-3, steps_per_epoch=10)
    # eta[E] = eta0 * 0.01 ** (E(E+1)/200)
    for epoch in (0, 1, 5, 20):
        got = float(sched(jnp.asarray(epoch * 10, jnp.int32)))
        want = 1e-3 * 0.01 ** (epoch * (epoch + 1) / 200)
        np.testing.assert_allclose(got, want, rtol=1e-5)


def test_eq4_monotone_decay():
    sched = schedules.paper_eq4(1e-3, steps_per_epoch=5)
    vals = [float(sched(jnp.asarray(s, jnp.int32))) for s in range(0, 100, 5)]
    assert all(a >= b for a, b in zip(vals, vals[1:]))
    assert vals[0] == pytest.approx(1e-3)


def test_microbatch_equals_full_batch():
    """Gradient accumulation must reproduce the large-batch trajectory.

    Uses an LM model: per-token normalization makes the loss mean-decomposable
    across microbatches. (BatchNorm models genuinely differ under
    accumulation — per-microbatch statistics — so the FC net is not a valid
    oracle here.)"""
    from repro.configs import base as cb
    from repro.core.policy import DEFAULT_POLICY
    from repro.models import transformer as T

    cfg = cb.get_config("starcoder2_3b", smoke=True)
    params = T.init_lm(cfg, jax.random.key(0))
    opt = sgd_momentum(schedules.constant(0.05), momentum=0.9)
    batch = {"tokens": jax.random.randint(jax.random.key(1), (8, 33), 0,
                                          cfg.vocab_size)}

    outs = []
    for mb in (1, 4):
        step = jax.jit(ST.make_train_step(ST.make_lm_loss(cfg), opt, "det",
                                          DEFAULT_POLICY, microbatches=mb))
        state = ST.init_train_state(jax.tree.map(jnp.copy, params), opt)
        s, _ = step(state, batch)
        outs.append(s["params"])
    for a, b in zip(jax.tree.leaves(outs[0]), jax.tree.leaves(outs[1])):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=1e-4, atol=2e-5)


class TestCompression:
    def test_error_feedback_identity(self):
        """decompressed + error == corrected gradient (lossless bookkeeping)."""
        g = jax.random.normal(jax.random.key(0), (256,))
        e = jax.random.normal(jax.random.key(1), (256,)) * 0.1
        sign, scale, new_err = compression.compress(g, e)
        recon = compression.decompress(sign, scale)
        np.testing.assert_allclose(np.asarray(recon + new_err),
                                   np.asarray(g + e), rtol=1e-5, atol=1e-6)

    def test_sign_bits(self):
        g = jnp.array([1.0, -2.0, 0.0, 3.0])
        sign, scale, _ = compression.compress(g, jnp.zeros(4))
        np.testing.assert_array_equal(sign, jnp.array([1, -1, 1, 1], jnp.int8))

    def test_compressed_bytes_16x(self):
        params = {"w": jnp.zeros((1024, 1024))}
        cb = compression.compressed_bytes(params)
        dense = 1024 * 1024 * 2  # bf16
        assert dense / cb > 15.0

    def test_training_with_compression_learns(self):
        step, state, spec = _setup("det", use_compression=True)
        losses = []
        for i in range(80):
            x, y = syn.train_batch(spec, i)
            state, m = step(state, {"x": x.reshape(x.shape[0], -1), "y": y})
            losses.append(float(m["loss"]))
        assert np.mean(losses[-10:]) < 0.5 * np.mean(losses[:10])


class TestOptimizers:
    def test_sgd_momentum_matches_manual(self):
        opt = sgd_momentum(schedules.constant(0.1), momentum=0.9)
        p = {"w": jnp.array([1.0, -1.0])}
        s = opt.init(p)
        g = {"w": jnp.array([0.5, 0.5])}
        p1, s1 = opt.update(g, s, p, jnp.asarray(0, jnp.int32))
        np.testing.assert_allclose(p1["w"], jnp.array([0.95, -1.05]))
        p2, _ = opt.update(g, s1, p1, jnp.asarray(1, jnp.int32))
        # mu = 0.9*0.5 + 0.5 = 0.95; p = 0.95 - 0.1*0.95
        np.testing.assert_allclose(p2["w"], jnp.array([0.855, -1.145]),
                                   rtol=1e-6)

    def test_adamw_step_direction(self):
        opt = adamw(schedules.constant(1e-2))
        p = {"w": jnp.ones((8,))}
        s = opt.init(p)
        g = {"w": jnp.ones((8,))}
        p1, _ = opt.update(g, s, p, jnp.asarray(0, jnp.int32))
        assert (np.asarray(p1["w"]) < 1.0).all()

    def test_global_norm_clip(self):
        g = {"a": jnp.full((4,), 3.0), "b": jnp.full((4,), 4.0)}
        clipped, norm = clip_by_global_norm(g, 1.0)
        np.testing.assert_allclose(float(norm), 10.0)
        np.testing.assert_allclose(float(global_norm(clipped)), 1.0,
                                   rtol=1e-4)


def test_bf16_momentum_learns():
    """Quantized optimizer slot (beyond-paper lever for 300B+ single-pod
    Alg.-1 training): bf16 momentum must not break convergence."""
    import jax.numpy as jnp

    tree = mnist_fc.init(jax.random.key(0), hidden=(64, 64))
    opt = sgd_momentum(schedules.constant(0.05), momentum=0.9,
                       momentum_dtype=jnp.bfloat16)
    step = jax.jit(ST.make_train_step(
        ST.make_classifier_loss(mnist_fc.apply), opt, "det", POLICY,
        has_model_state=True))
    state = ST.init_train_state(tree["params"], opt,
                                model_state=tree["state"])
    assert jax.tree.leaves(state["opt"]["mu"])[0].dtype == jnp.bfloat16
    spec = syn.SyntheticSpec("mnist", n_train=6000, batch_size=64)
    losses = []
    for i in range(120):
        x, y = syn.train_batch(spec, i)
        state, m = step(state, {"x": x.reshape(64, -1), "y": y})
        losses.append(float(m["loss"]))
    assert np.mean(losses[-10:]) < 0.3 * np.mean(losses[:10])
