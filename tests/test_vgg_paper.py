"""The paper's VGG-16/CIFAR benchmark at smoke scale + paper-recipe pieces."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import synthetic as syn
from repro.launch.train import make_paper_policy
from repro.models import vgg
from repro.optim import schedules
from repro.optim.sgd import sgd_momentum
from repro.train import steps as ST


def test_vgg16_structure():
    tree = vgg.init(jax.random.key(0), width_mult=0.125)
    assert len(tree["params"]["conv"]) == 13  # VGG-16: 13 conv layers
    assert len(tree["params"]["fc"]) == 3


def test_vgg_forward_shapes():
    tree = vgg.init(jax.random.key(0), width_mult=0.125)
    x = jax.random.uniform(jax.random.key(1), (4, 32, 32, 3))
    logits, state = vgg.apply(tree["params"], tree["state"], x, training=True)
    assert logits.shape == (4, 10)
    assert not np.isnan(np.asarray(logits)).any()


@pytest.mark.parametrize("mode", ["det", "stoch"])
def test_vgg_binarized_train_step(mode):
    tree = vgg.init(jax.random.key(0), width_mult=0.125)
    policy = make_paper_policy(len(tree["params"]["fc"]))
    opt = sgd_momentum(schedules.paper_eq4(1e-3, 10), momentum=0.9)
    step = jax.jit(ST.make_train_step(
        ST.make_classifier_loss(vgg.apply), opt, mode, policy,
        has_model_state=True))
    state = ST.init_train_state(tree["params"], opt,
                                model_state=tree["state"])
    spec = syn.SyntheticSpec("cifar", n_train=64, batch_size=8)
    x, y = syn.train_batch(spec, 0)
    state, metrics = step(state, {"x": x, "y": y})
    assert np.isfinite(float(metrics["loss"]))
    # conv kernels (except the first) are clipped masters
    w = state["params"]["conv"][3]["kernel"]
    assert float(jnp.abs(w).max()) <= 1.0


def test_vgg_learns_a_little():
    """Short det-binarized run reduces loss on synthetic CIFAR."""
    tree = vgg.init(jax.random.key(0), width_mult=0.125)
    policy = make_paper_policy(3)
    opt = sgd_momentum(schedules.constant(1e-2), momentum=0.9)
    step = jax.jit(ST.make_train_step(
        ST.make_classifier_loss(vgg.apply), opt, "det", policy,
        has_model_state=True))
    state = ST.init_train_state(tree["params"], opt, model_state=tree["state"])
    spec = syn.SyntheticSpec("cifar", n_train=512, batch_size=16)
    losses = []
    for i in range(60):
        x, y = syn.train_batch(spec, i)
        state, m = step(state, {"x": x, "y": y})
        losses.append(float(m["loss"]))
    assert np.mean(losses[-5:]) < 0.75 * np.mean(losses[:5]), losses[:3] + losses[-3:]


def test_first_conv_and_classifier_stay_fp():
    policy = make_paper_policy(3)
    assert not policy.selects("conv/0/kernel")
    assert policy.selects("conv/5/kernel")
    assert not policy.selects("fc/2/kernel")
    assert policy.selects("fc/1/kernel")
