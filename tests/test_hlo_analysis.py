"""HLO analyzer correctness: FLOPs vs analytic, trip-count attribution,
collective accounting, shape parsing."""
import textwrap

import jax
import jax.numpy as jnp
import pytest

from repro.core import hlo_analysis as H
from repro.core import roofline as R


class TestShapeParsing:
    @pytest.mark.parametrize("s,expect", [
        ("f32[8,16]{1,0}", 8 * 16 * 4),
        ("bf16[128]", 128 * 2),
        ("pred[4,4]", 16),
        ("s32[]", 4),
        ("(f32[2,2], bf16[4])", 16 + 8),
        ("u8[10]{0}", 10),
    ])
    def test_shape_bytes(self, s, expect):
        assert H.shape_bytes(s) == expect


class TestFlops:
    def test_unscanned_matmul_matches_analytic(self):
        def f(a, b):
            return (a @ b).sum()

        c = jax.jit(f).lower(
            jax.ShapeDtypeStruct((256, 512), jnp.float32),
            jax.ShapeDtypeStruct((512, 128), jnp.float32)).compile()
        cost = H.analyze(c.as_text())
        assert cost.flops == 2 * 256 * 512 * 128

    def test_scan_trip_count_attribution(self):
        """The raison d'etre: XLA cost_analysis counts scan bodies once;
        the analyzer multiplies by the trip count."""
        L, D = 8, 64

        def f(ws, x):
            def body(x, w):
                return x @ w, ()
            x, _ = jax.lax.scan(body, x, ws)
            return x.sum()

        c = jax.jit(f).lower(
            jax.ShapeDtypeStruct((L, D, D), jnp.float32),
            jax.ShapeDtypeStruct((16, D), jnp.float32)).compile()
        cost = H.analyze(c.as_text())
        analytic = L * 2 * 16 * D * D
        assert cost.flops == analytic, (cost.flops, analytic)
        assert cost.unparsed_while == 0

    def test_grad_of_scan(self):
        L, D, B = 4, 32, 8

        def f(ws, x):
            def body(x, w):
                return jax.nn.relu(x @ w), ()
            y, _ = jax.lax.scan(body, x, ws)
            return (y ** 2).sum()

        c = jax.jit(jax.grad(f)).lower(
            jax.ShapeDtypeStruct((L, D, D), jnp.float32),
            jax.ShapeDtypeStruct((B, D), jnp.float32)).compile()
        cost = H.analyze(c.as_text())
        # fwd 1 matmul + bwd 2 matmuls per layer
        analytic = L * 3 * 2 * B * D * D
        assert abs(cost.flops - analytic) / analytic < 0.01


class TestCollectives:
    def test_collective_bytes_counted(self):
        import json
        import subprocess
        import sys
        # needs >1 device: run in a subprocess with forced host devices
        code = textwrap.dedent("""
            import os
            os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
            import json, sys
            sys.path.insert(0, "src")
            import jax, jax.numpy as jnp
            from jax.sharding import NamedSharding, PartitionSpec as P
            from repro.core import hlo_analysis as H
            from repro.distributed.sharding import mesh_context
            mesh = jax.make_mesh((4,), ("model",))
            def f(a, b):
                return (a @ b).sum()
            with mesh_context(mesh):
                c = jax.jit(f, in_shardings=(
                        NamedSharding(mesh, P(None, "model")),
                        NamedSharding(mesh, P("model", None))),
                    out_shardings=NamedSharding(mesh, P())).lower(
                    jax.ShapeDtypeStruct((64, 64), jnp.float32),
                    jax.ShapeDtypeStruct((64, 64), jnp.float32)).compile()
            cost = H.analyze(c.as_text())
            print(json.dumps({"ar": cost.collective_bytes_by_kind.get(
                "all-reduce", 0), "total": cost.collective_bytes}))
        """)
        out = subprocess.run([sys.executable, "-c", code], cwd="/root/repo",
                             capture_output=True, text=True, timeout=300)
        assert out.returncode == 0, out.stderr[-800:]
        res = json.loads(out.stdout.strip().splitlines()[-1])
        # contraction-sharded matmul => all-reduce of (64, 64) f32 partials
        # (possibly fused with the sum reduce: accept either operand size)
        assert res["total"] > 0
        assert res["ar"] >= 4  # at least the scalar sum's all-reduce


class TestRoofline:
    def test_terms_and_dominance(self):
        cost = H.HloCost(flops=197e12, bytes=819e9 * 2, collective_bytes=50e9)
        t = R.from_hlo_cost(cost, chips=256)
        assert t.compute_s == pytest.approx(1.0)
        assert t.memory_s == pytest.approx(2.0)
        assert t.collective_s == pytest.approx(1.0)
        assert t.dominant == "memory"
        assert t.bound_time_s == pytest.approx(2.0)

    def test_useful_flops_fraction(self):
        cost = H.HloCost(flops=6e12)
        t = R.from_hlo_cost(cost, chips=1, model_flops=3e12)
        assert t.useful_flops_fraction == pytest.approx(0.5)

    def test_model_flops(self):
        assert R.model_flops_train(1e9, 1e6) == 6e15
        assert R.model_flops_infer(1e9, 1) == 2e9


class TestIterOpsAndAliases:
    """Trip-weighted op iteration + module-header donation facts (the
    surfaces repro.analysis.hlo_lints builds on)."""

    _WHILE_COPY_HLO = textwrap.dedent("""\
        HloModule m

        %body (p.1: (s32[], f32[64])) -> (s32[], f32[64]) {
          %p.1 = (s32[], f32[64]) parameter(0)
          %i = s32[] get-tuple-element(%p.1), index=0
          %one = s32[] constant(1)
          %next = s32[] add(%i, %one)
          %x = f32[64]{0} get-tuple-element(%p.1), index=1
          %cp = f32[64]{0} copy(%x), metadata={op_name="jit(f)/while/reshard"}
          ROOT %t = (s32[], f32[64]) tuple(%next, %cp)
        }

        %cond (p.2: (s32[], f32[64])) -> pred[] {
          %p.2 = (s32[], f32[64]) parameter(0)
          %iv = s32[] get-tuple-element(%p.2), index=0
          %n = s32[] constant(5)
          ROOT %lt = pred[] compare(%iv, %n), direction=LT
        }

        ENTRY %main (a: f32[64]) -> f32[64] {
          %a = f32[64]{0} parameter(0)
          %z = s32[] constant(0)
          %init = (s32[], f32[64]) tuple(%z, %a)
          %w = (s32[], f32[64]) while(%init), condition=%cond, body=%body
          ROOT %out = f32[64]{0} get-tuple-element(%w), index=1
        }
        """)

    def test_copy_bytes_are_trip_weighted(self):
        """A resharding copy inside a 5-trip while counts 5x — the same
        attribution the collectives get."""
        cost = H.analyze(self._WHILE_COPY_HLO)
        assert cost.copy_count == 5
        assert cost.copy_bytes == 5 * 64 * 4
        assert cost.unparsed_while == 0

    def test_iter_ops_reaches_while_body_with_mult(self):
        visits = [v for v in H.iter_ops(self._WHILE_COPY_HLO)
                  if v.op.opcode == "copy"]
        assert len(visits) == 1
        v = visits[0]
        assert v.mult == 5.0
        assert v.computation == "body"
        assert not v.in_fusion
        assert H.op_metadata_name(v.op) == "jit(f)/while/reshard"

    def test_iter_ops_entry_selection(self):
        names = {v.op.name for v in H.iter_ops(self._WHILE_COPY_HLO,
                                               entry="cond")}
        assert names == {"p.2", "iv", "n", "lt"}

    def test_zero_collective_graph(self):
        c = jax.jit(lambda a: a @ a).lower(
            jax.ShapeDtypeStruct((64, 64), jnp.float32)).compile()
        cost = H.analyze(c.as_text())
        assert dict(cost.collective_count) == {}
        assert cost.collective_bytes == 0.0
        assert cost.flops > 0

    def test_donated_program_declares_alias(self):
        donated = jax.jit(lambda x: x * 2.0, donate_argnums=0).lower(
            jnp.ones((32, 32))).compile().as_text()
        aliases = H.input_output_aliases(donated)
        assert aliases, "donate_argnums=0 must surface in the module header"
        idx, param, kind = aliases[0]
        assert param == 0 and kind in ("may-alias", "must-alias")

    def test_undonated_program_has_no_alias(self):
        text = jax.jit(lambda x: x * 2.0).lower(
            jnp.ones((32, 32))).compile().as_text()
        assert H.input_output_aliases(text) == []

    def test_alias_header_multi_entry_parse(self):
        text = ("HloModule m, input_output_alias={ {1}: (13, {}, "
                "may-alias), {0, 2}: (2, {}, must-alias) }, "
                "entry_computation_layout={()->f32[1]}")
        assert H.input_output_aliases(text) == [
            ((1,), 13, "may-alias"), ((0, 2), 2, "must-alias")]
