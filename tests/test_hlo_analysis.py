"""HLO analyzer correctness: FLOPs vs analytic, trip-count attribution,
collective accounting, shape parsing."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import hlo_analysis as H
from repro.core import roofline as R


class TestShapeParsing:
    @pytest.mark.parametrize("s,expect", [
        ("f32[8,16]{1,0}", 8 * 16 * 4),
        ("bf16[128]", 128 * 2),
        ("pred[4,4]", 16),
        ("s32[]", 4),
        ("(f32[2,2], bf16[4])", 16 + 8),
        ("u8[10]{0}", 10),
    ])
    def test_shape_bytes(self, s, expect):
        assert H.shape_bytes(s) == expect


class TestFlops:
    def test_unscanned_matmul_matches_analytic(self):
        def f(a, b):
            return (a @ b).sum()

        c = jax.jit(f).lower(
            jax.ShapeDtypeStruct((256, 512), jnp.float32),
            jax.ShapeDtypeStruct((512, 128), jnp.float32)).compile()
        cost = H.analyze(c.as_text())
        assert cost.flops == 2 * 256 * 512 * 128

    def test_scan_trip_count_attribution(self):
        """The raison d'etre: XLA cost_analysis counts scan bodies once;
        the analyzer multiplies by the trip count."""
        L, D = 8, 64

        def f(ws, x):
            def body(x, w):
                return x @ w, ()
            x, _ = jax.lax.scan(body, x, ws)
            return x.sum()

        c = jax.jit(f).lower(
            jax.ShapeDtypeStruct((L, D, D), jnp.float32),
            jax.ShapeDtypeStruct((16, D), jnp.float32)).compile()
        cost = H.analyze(c.as_text())
        analytic = L * 2 * 16 * D * D
        assert cost.flops == analytic, (cost.flops, analytic)
        assert cost.unparsed_while == 0

    def test_grad_of_scan(self):
        L, D, B = 4, 32, 8

        def f(ws, x):
            def body(x, w):
                return jax.nn.relu(x @ w), ()
            y, _ = jax.lax.scan(body, x, ws)
            return (y ** 2).sum()

        c = jax.jit(jax.grad(f)).lower(
            jax.ShapeDtypeStruct((L, D, D), jnp.float32),
            jax.ShapeDtypeStruct((B, D), jnp.float32)).compile()
        cost = H.analyze(c.as_text())
        # fwd 1 matmul + bwd 2 matmuls per layer
        analytic = L * 3 * 2 * B * D * D
        assert abs(cost.flops - analytic) / analytic < 0.01


class TestCollectives:
    def test_collective_bytes_counted(self):
        import subprocess, sys, textwrap, json, os
        # needs >1 device: run in a subprocess with forced host devices
        code = textwrap.dedent("""
            import os
            os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
            import json, sys
            sys.path.insert(0, "src")
            import jax, jax.numpy as jnp
            from jax.sharding import NamedSharding, PartitionSpec as P
            from repro.core import hlo_analysis as H
            from repro.distributed.sharding import mesh_context
            mesh = jax.make_mesh((4,), ("model",))
            def f(a, b):
                return (a @ b).sum()
            with mesh_context(mesh):
                c = jax.jit(f, in_shardings=(
                        NamedSharding(mesh, P(None, "model")),
                        NamedSharding(mesh, P("model", None))),
                    out_shardings=NamedSharding(mesh, P())).lower(
                    jax.ShapeDtypeStruct((64, 64), jnp.float32),
                    jax.ShapeDtypeStruct((64, 64), jnp.float32)).compile()
            cost = H.analyze(c.as_text())
            print(json.dumps({"ar": cost.collective_bytes_by_kind.get(
                "all-reduce", 0), "total": cost.collective_bytes}))
        """)
        out = subprocess.run([sys.executable, "-c", code], cwd="/root/repo",
                             capture_output=True, text=True, timeout=300)
        assert out.returncode == 0, out.stderr[-800:]
        res = json.loads(out.stdout.strip().splitlines()[-1])
        # contraction-sharded matmul => all-reduce of (64, 64) f32 partials
        # (possibly fused with the sum reduce: accept either operand size)
        assert res["total"] > 0
        assert res["ar"] >= 4  # at least the scalar sum's all-reduce


class TestRoofline:
    def test_terms_and_dominance(self):
        cost = H.HloCost(flops=197e12, bytes=819e9 * 2, collective_bytes=50e9)
        t = R.from_hlo_cost(cost, chips=256)
        assert t.compute_s == pytest.approx(1.0)
        assert t.memory_s == pytest.approx(2.0)
        assert t.collective_s == pytest.approx(1.0)
        assert t.dominant == "memory"
        assert t.bound_time_s == pytest.approx(2.0)

    def test_useful_flops_fraction(self):
        cost = H.HloCost(flops=6e12)
        t = R.from_hlo_cost(cost, chips=1, model_flops=3e12)
        assert t.useful_flops_fraction == pytest.approx(0.5)

    def test_model_flops(self):
        assert R.model_flops_train(1e9, 1e6) == 6e15
        assert R.model_flops_infer(1e9, 1) == 2e9
