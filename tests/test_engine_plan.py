"""Execution-plan compiler: registry dispatch, plan round-trips, parity with
pack_params, overrides, fallthrough surfacing, golden manifests."""
import json
import os
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import base as cb
from repro.core.policy import DEFAULT_POLICY
from repro.engine import (ExecutionPlan, backends, compile_plan,
                          format_plan_table, get_backend, plan_report,
                          registry)
from repro.models import mnist_fc, transformer as T, vgg
from repro.models.layers import (PackedConv, PackedLinear, XnorConv,
                                 XnorLinear, apply_conv2d, apply_linear)
from repro.serve.engine import pack_params


def _trees():
    """(name, params, policy) fixtures: the paper nets + a stacked
    transformer (scan-stacked (L, K, N) projection leaves)."""
    fc = mnist_fc.init(jax.random.key(0), hidden=(128, 64))["params"]
    cnn = vgg.init(jax.random.key(1), width_mult=0.125)["params"]
    cfg = cb.get_config("starcoder2_3b", smoke=True)
    lm = T.init_lm(cfg, jax.random.key(2))
    return [("mnist_fc", fc, DEFAULT_POLICY),
            ("vgg16_cifar10", cnn, DEFAULT_POLICY),
            ("stacked_transformer", lm, DEFAULT_POLICY)]


def assert_trees_identical(a, b):
    """Same pytree structure (incl. serving-leaf classes + static aux) and
    bit-identical array values."""
    assert jax.tree_util.tree_structure(a) == jax.tree_util.tree_structure(b)
    for la, lb in zip(jax.tree_util.tree_leaves(a),
                      jax.tree_util.tree_leaves(b)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


class TestPackParity:
    @pytest.mark.parametrize("mode", ["det", "stoch", "xnor"])
    def test_plan_pack_equals_pack_params(self, mode):
        """Acceptance: pack_params output is pytree-identical (structure +
        values) to compile_plan(...).pack(params), per model and mode."""
        key = jax.random.key(7) if mode == "stoch" else None
        for name, params, policy in _trees():
            with warnings.catch_warnings():
                warnings.simplefilter("ignore")
                plan = compile_plan(params, policy, mode, warn=False)
                via_plan = plan.pack(params, key=key)
                via_wrapper = pack_params(params, policy, mode, key=key)
            assert_trees_identical(via_plan, via_wrapper)

    @pytest.mark.parametrize("mode", ["det", "stoch", "xnor"])
    def test_serialize_load_pack_roundtrip(self, mode, tmp_path):
        """compile -> save -> load -> pack: leaf-for-leaf identical dispatch
        and bit-identical values vs the in-memory plan."""
        key = jax.random.key(3) if mode == "stoch" else None
        for name, params, policy in _trees():
            plan = compile_plan(params, policy, mode, warn=False)
            path = os.path.join(tmp_path, f"{name}_{mode}.json")
            plan.save(path)
            loaded = ExecutionPlan.load(path)
            assert loaded.to_json() == plan.to_json()
            assert [a.backend for a in loaded.layers] == \
                   [a.backend for a in plan.layers]
            assert_trees_identical(loaded.pack(params, key=key),
                                   plan.pack(params, key=key))

    def test_forward_outputs_bit_identical(self):
        """Packed trees from plan vs wrapper produce bit-identical logits."""
        tree = mnist_fc.init(jax.random.key(0), hidden=(128, 64))
        plan = compile_plan(tree["params"], DEFAULT_POLICY, "xnor", warn=False)
        a = plan.pack(tree["params"])
        b = pack_params(tree["params"], DEFAULT_POLICY, "xnor")
        x = jax.random.normal(jax.random.key(5), (4, 784))
        la, _ = mnist_fc.apply(a, tree["state"], x, training=False,
                               binary_act=True)
        lb, _ = mnist_fc.apply(b, tree["state"], x, training=False,
                               binary_act=True)
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))

    def test_pack_rejects_mismatched_tree(self):
        fc = mnist_fc.init(jax.random.key(0), hidden=(128, 64))["params"]
        other = mnist_fc.init(jax.random.key(0), hidden=(64, 64))["params"]
        plan = compile_plan(fc, DEFAULT_POLICY, "det", warn=False)
        with pytest.raises(ValueError, match="mismatch"):
            plan.pack(other)


class TestCompile:
    def test_fallthrough_recorded_and_warned(self):
        """Satellite: a policy-selected leaf that cannot bitpack (784 % 32
        != 0) is assigned dense with the reason recorded — and warns,
        instead of the old silent fallthrough."""
        fc = mnist_fc.init(jax.random.key(0), hidden=(128, 64))["params"]
        with pytest.warns(UserWarning, match="cannot use a binary backend"):
            plan = compile_plan(fc, DEFAULT_POLICY, "xnor")
        row = plan["layers/0/kernel"]
        assert row.backend == "dense"
        assert "K=784 % 32 != 0" in row.reason
        assert plan.fallthroughs() == [row]
        # the plan report surfaces the row (it is not filtered as boring)
        assert any(r["path"] == "layers/0/kernel" and "784" in r["reason"]
                   for r in plan_report(plan))

    def test_xnor_boundary_reason(self):
        """VGG block 1 stays off the binary-activation path with the
        real-valued-input boundary named as the reason."""
        cnn = vgg.init(jax.random.key(1), width_mult=0.125)["params"]
        plan = compile_plan(cnn, DEFAULT_POLICY, "xnor", warn=False)
        row = plan["conv/1/kernel"]
        assert row.backend == "binarized_dense"
        assert "real-valued-input boundary" in row.reason
        assert all(plan[f"conv/{i}/kernel"].backend == "xnor_conv"
                   for i in range(2, 13))

    def test_every_leaf_has_assignment(self):
        fc = mnist_fc.init(jax.random.key(0), hidden=(128, 64))["params"]
        plan = compile_plan(fc, DEFAULT_POLICY, "det", warn=False)
        n_leaves = len(jax.tree_util.tree_leaves(fc))
        assert len(plan.layers) == n_leaves
        assert [a.index for a in plan.layers] == list(range(n_leaves))
        for a in plan.layers:
            assert a.backend in a.eligible and a.eligible[a.backend] == "ok"

    def test_overrides_force_and_validate(self):
        cnn = vgg.init(jax.random.key(1), width_mult=0.125)["params"]
        plan = compile_plan(cnn, DEFAULT_POLICY, "xnor", warn=False,
                            overrides={"conv/3": "binarized_dense",
                                       "fc/1/kernel": "packed"})
        assert plan["conv/3/kernel"].backend == "binarized_dense"
        assert plan["conv/3/kernel"].reason.startswith("override")
        assert plan["fc/1/kernel"].backend == "packed"
        assert plan["conv/4/kernel"].backend == "xnor_conv"  # untouched
        packed = plan.pack(cnn)
        assert isinstance(packed["conv"][3]["kernel"], jax.Array)
        assert isinstance(packed["conv"][4]["kernel"], XnorConv)
        # ineligible override: a conv leaf cannot take the FC xnor backend
        with pytest.raises(ValueError, match="override"):
            compile_plan(cnn, DEFAULT_POLICY, "xnor", warn=False,
                         overrides={"conv/3/kernel": "xnor"})
        # policy-excluded leaf cannot be forced onto a binary backend
        with pytest.raises(ValueError, match="ineligible"):
            compile_plan(cnn, DEFAULT_POLICY, "det", warn=False,
                         overrides={"conv/0/bias": "packed"})

    def test_unknown_mode_and_backend(self):
        fc = mnist_fc.init(jax.random.key(0), hidden=(128, 64))["params"]
        with pytest.raises(ValueError, match="mode"):
            compile_plan(fc, DEFAULT_POLICY, "int5", warn=False)
        with pytest.raises(KeyError, match="unknown backend"):
            compile_plan(fc, DEFAULT_POLICY, "det", warn=False,
                         overrides={"layers/1/kernel": "int5"})


class TestShardingColumn:
    """Plan rows carry the mesh placement of each layer's serving
    representation (tentpole: mesh-sharded serving)."""

    def _lm_plan(self, mode="det"):
        cfg = cb.get_config("starcoder2_3b", smoke=True)
        lm = T.init_lm(cfg, jax.random.key(0))
        return compile_plan(lm, DEFAULT_POLICY, mode, warn=False)

    def test_binary_backends_tp_shard_out_channel(self):
        """Every bitpacked row puts "model" on exactly one dim: the last
        (N / out-channel) dim by default, or — for backends declaring a
        ``tp_contract_dim`` (xnor's exact-popcount row-parallel path,
        PR 8) — the contraction dim of the Megatron row-parallel
        projections (w_o / w_down), where the word dim splits as whole
        int32 words. A 32-bit lane group never crosses a device either
        way."""
        plan = self._lm_plan("xnor")
        binary = [a for a in plan.layers
                  if a.backend in ("packed", "xnor", "xnor_conv",
                                   "binarized_dense")]
        assert binary, "expected bitpacked rows in the xnor plan"
        row_parallel = []
        for a in binary:
            spec = registry.get_backend(a.backend)
            if (spec.tp_contract_dim is not None
                    and a.sharding[-2] == "model"):
                row_parallel.append(a.path)
                others = a.sharding[:-2] + a.sharding[-1:]
            else:
                assert a.sharding[-1] == "model", a.path
                others = a.sharding[:-1]
            assert all(e is None for e in others), a.path
        # the xnor plan actually exercises the row-parallel branch
        assert any(p.endswith(("w_o", "w_down")) for p in row_parallel), \
            row_parallel

    def test_dense_rows_follow_megatron_rules(self):
        """w_o is row-parallel ("model" on the input dim) only when it
        serves dense; under a binary backend it flips to out-channel."""
        cfg = cb.get_config("starcoder2_3b", smoke=True)
        lm = T.init_lm(cfg, jax.random.key(0))
        from repro.core.policy import NONE_POLICY

        dense_plan = compile_plan(lm, NONE_POLICY, "det", warn=False)
        assert dense_plan["layers/attn/w_o"].backend == "dense"
        assert dense_plan["layers/attn/w_o"].sharding == [None, "model", None]
        packed_plan = self._lm_plan("det")
        assert packed_plan["layers/attn/w_o"].backend == "packed"
        assert packed_plan["layers/attn/w_o"].sharding == [None, None, "model"]
        # non-matmul leaves replicate
        assert all(e is None
                   for e in packed_plan["layers/ln1/scale"].sharding)

    def test_mesh_validation_downgrades_nondivisible(self):
        """With a concrete mesh, a dim the mesh cannot split cleanly is
        recorded replicated (placement never errors at serve time)."""
        import dataclasses as dc

        from repro.engine.plan import _row_sharding

        class FakeMesh:
            axis_names = ("data", "model")
            devices = np.zeros((2, 3))    # model axis size 3

        col = _row_sharding("layers/attn/w_qkv", (4, 64, 96), "packed",
                            FakeMesh())
        assert col == [None, None, "model"]       # 96 % 3 == 0
        col = _row_sharding("layers/attn/w_qkv", (4, 64, 100), "packed",
                            FakeMesh())
        assert col == [None, None, None]          # 100 % 3 != 0 -> replicate

    def test_v2_manifest_still_loads(self, tmp_path):
        """A pre-ensemble (version 2) manifest — no ``replica_axis`` field —
        loads with replica_axis=None and packs identically."""
        fc = mnist_fc.init(jax.random.key(0), hidden=(128, 64))["params"]
        plan = compile_plan(fc, DEFAULT_POLICY, "det", warn=False)
        d = plan.to_json()
        assert d["version"] == 3 and "replica_axis" in d
        d["version"] = 2
        del d["replica_axis"]
        p = os.path.join(tmp_path, "v2.json")
        with open(p, "w") as f:
            json.dump(d, f)
        loaded = ExecutionPlan.load(p)
        assert loaded.replica_axis is None
        assert_trees_identical(loaded.pack(fc), plan.pack(fc))

    def test_replica_axis_roundtrip_and_validation(self, tmp_path):
        """replica_axis survives save/load, and compile_plan rejects an
        axis name the concrete mesh does not have."""
        fc = mnist_fc.init(jax.random.key(0), hidden=(128, 64))["params"]
        plan = compile_plan(fc, DEFAULT_POLICY, "stoch", warn=False,
                            replica_axis="data")
        p = os.path.join(tmp_path, "v3.json")
        plan.save(p)
        assert ExecutionPlan.load(p).replica_axis == "data"

        class FakeMesh:
            axis_names = ("model",)
            devices = np.zeros((1,))

        with pytest.raises(ValueError, match="replica_axis"):
            compile_plan(fc, DEFAULT_POLICY, "stoch", warn=False,
                         mesh=FakeMesh(), replica_axis="data")

    def test_v1_manifest_still_loads(self, tmp_path):
        """A pre-sharding (version 1) manifest loads with sharding=None and
        still packs; unknown versions still raise."""
        fc = mnist_fc.init(jax.random.key(0), hidden=(128, 64))["params"]
        plan = compile_plan(fc, DEFAULT_POLICY, "det", warn=False)
        d = plan.to_json()
        d["version"] = 1
        for row in d["layers"]:
            del row["sharding"]
        p = os.path.join(tmp_path, "v1.json")
        with open(p, "w") as f:
            json.dump(d, f)
        loaded = ExecutionPlan.load(p)
        assert all(a.sharding is None and a.pspec is None
                   for a in loaded.layers)
        assert_trees_identical(loaded.pack(fc), plan.pack(fc))
        d["version"] = 99
        with open(p, "w") as f:
            json.dump(d, f)
        with pytest.raises(ValueError, match="version"):
            ExecutionPlan.load(p)


class TestRegistryDispatch:
    def test_backend_order_and_lookup(self):
        names = [s.name for s in backends()]
        assert names == ["xnor_conv", "xnor", "packed", "packed_conv",
                         "binarized_dense", "dense"]
        assert get_backend("packed").leaf_type is PackedLinear
        assert get_backend("packed_conv").leaf_type is PackedConv

    def test_leaf_type_dispatch(self):
        assert registry.backend_for_leaf(jnp.ones((4, 4)), "linear").name \
            == "dense"
        pl = PackedLinear(jnp.zeros((2, 8), jnp.int32), None, 64)
        assert registry.backend_for_leaf(pl, "linear").name == "packed"
        xl = XnorLinear(jnp.zeros((2, 8), jnp.int32), None, 64)
        assert registry.backend_for_leaf(xl, "linear").name == "xnor"
        xc = XnorConv(jnp.zeros((9, 8), jnp.int32), None, (3, 3), 16)
        assert registry.backend_for_leaf(xc, "conv").name == "xnor_conv"

    def test_apply_linear_via_registry(self):
        from repro.kernels import ops as kops

        w = jax.random.normal(jax.random.key(0), (64, 32))
        x = jax.random.normal(jax.random.key(1), (4, 64))
        got = apply_linear(XnorLinear(kops.binarize_and_pack(w), None, 64), x)
        want = jnp.where(x > 0, 1.0, -1.0) @ jnp.where(w > 0, 1.0, -1.0)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-6)

    def test_custom_backend_registration(self):
        """Backend N+1 is a registration, not a cross-cutting edit: a new
        leaf type dispatches through apply_linear with no layers.py change."""
        import dataclasses as dc

        @jax.tree_util.register_pytree_node_class
        @dc.dataclass
        class NegatedLinear:
            w: jax.Array

            def tree_flatten(self):
                return (self.w,), ()

            @classmethod
            def tree_unflatten(cls, aux, children):
                return cls(children[0])

        spec = registry.BackendSpec(
            name="negated", kinds=("linear",), priority=1,
            leaf_type=NegatedLinear,
            eligible=lambda lc: (False, "test-only"),
            pack=lambda lc, leaf, pc: NegatedLinear(-leaf),
            apply=lambda w, x: -jnp.dot(x, w.w), cost=lambda m, k, n: {})
        registry.register_backend(spec)
        try:
            x = jnp.ones((2, 4))
            w = jnp.ones((4, 3))
            out = apply_linear(NegatedLinear(w), x)
            np.testing.assert_allclose(np.asarray(out), -4.0 * np.ones((2, 3)))
        finally:
            registry.unregister_backend("negated")
        assert registry.backend_for_leaf(NegatedLinear(w), "linear").name \
            == "dense"

    def test_packed_conv_stoch_only_and_parity(self):
        """packed_conv serves conv layers only in stoch mode (det conv
        already has the free ±1 dense fallback), and its apply matches a
        dense conv of the unpacked scaled ±1 weights bit-for-bit."""
        cnn = vgg.init(jax.random.key(1), width_mult=0.125)["params"]
        det = compile_plan(cnn, DEFAULT_POLICY, "det", warn=False)
        assert det["conv/2/kernel"].backend == "binarized_dense"
        assert "stoch" in det["conv/2/kernel"].eligible["packed_conv"]
        stoch = compile_plan(cnn, DEFAULT_POLICY, "stoch", warn=False)
        assert all(stoch[f"conv/{i}/kernel"].backend == "packed_conv"
                   for i in range(1, 13))
        packed = stoch.pack(cnn, key=jax.random.key(9))
        from repro.core.packing import unpack_bits

        leaf = packed["conv"][2]["kernel"]
        assert isinstance(leaf, PackedConv)
        kh, kw, c_in, n = leaf.shape
        w = unpack_bits(leaf.packed, dtype=jnp.float32)[: leaf.k]
        w = (w * leaf.scale).reshape(kh, kw, c_in, n)
        x = jax.random.normal(jax.random.key(2), (2, 6, 6, c_in))
        got = apply_conv2d(leaf, x)
        want = jax.lax.conv_general_dilated(
            x, w, (1, 1), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC"))
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_stoch_pack_without_key_names_leaf(self):
        """Satellite: the missing-key error names the leaf path and the
        fix, instead of a bare 'key required'."""
        cnn = vgg.init(jax.random.key(1), width_mult=0.125)["params"]
        plan = compile_plan(cnn, DEFAULT_POLICY, "stoch", warn=False)
        with pytest.raises(ValueError) as ei:
            plan.pack(cnn)
        msg = str(ei.value)
        assert "stochastic packing requires a PRNG key" in msg
        assert "conv/1/kernel" in msg or "kernel" in msg
        assert "mode='det'" in msg and "plan.pack" in msg

    def test_apply_conv2d_dense_via_registry(self):
        w = jax.random.normal(jax.random.key(0), (3, 3, 4, 8))
        x = jax.random.normal(jax.random.key(1), (2, 5, 5, 4))
        got = apply_conv2d(w, x)
        want = jax.lax.conv_general_dilated(
            x, w, (1, 1), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC"))
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-6, atol=1e-6)


class TestReport:
    def test_costs_every_eligible_backend(self):
        cnn = vgg.init(jax.random.key(1), width_mult=0.125)["params"]
        plan = compile_plan(cnn, DEFAULT_POLICY, "xnor", warn=False)
        rows = plan_report(plan, batch=16)
        by_path = {r["path"]: r for r in rows}
        conv_row = by_path["conv/2/kernel"]
        assert set(conv_row["costs"]) == {"xnor_conv", "binarized_dense",
                                          "dense"}
        for c in conv_row["costs"].values():
            assert c["bytes"] > 0 and c["ops"] > 0
        assert conv_row["costs"]["xnor_conv"]["bytes"] < \
            conv_row["costs"]["dense"]["bytes"]
        table = format_plan_table(rows)
        assert "xnor_conv" in table and "conv/2/kernel" in table

    def test_conv_cost_uses_per_tap_word_layout(self):
        """The xnor_conv cost must count kh*kw*ceil(C/32) per-tap words
        (the layout the kernel stores), not the flat ceil(kh*kw*C/32) —
        they differ whenever C % 32 != 0 (smoke VGG: C=16)."""
        cnn = vgg.init(jax.random.key(1), width_mult=0.125)["params"]
        plan = compile_plan(cnn, DEFAULT_POLICY, "xnor", warn=False)
        row = [r for r in plan_report(plan, batch=16)
               if r["path"] == "conv/2/kernel"][0]
        kh, kw, c, n = row["shape"]
        assert c % 32 != 0  # the case where the layouts differ
        words = kh * kw * ((c + 31) // 32)
        # weight_bytes column and the cost model's weight component agree
        assert row["weight_bytes"] == words * n * 4 + n * 4  # + f32 scale
        cost = row["costs"]["xnor_conv"]
        assert cost["bytes"] == (words * n * 4 + n * 4     # packed w + scale
                                 + 16 * words * 4          # packed patches
                                 + 16 * n * 4)             # f32 out
        assert cost["ops"] == 2 * 16 * words * n

    def test_report_hides_boring_rows_by_default(self):
        fc = mnist_fc.init(jax.random.key(0), hidden=(128, 64))["params"]
        plan = compile_plan(fc, DEFAULT_POLICY, "det", warn=False)
        assert all("bias" not in r["path"] for r in plan_report(plan))
        full = plan_report(plan, full=True)
        assert len(full) == len(plan.layers)


class TestGoldenManifests:
    def test_committed_goldens_match_compiled(self):
        """Mirror of the CI gate: the committed golden manifests equal a
        fresh compile (dispatch-boundary regressions fail here too)."""
        from benchmarks.check_golden_plans import GOLDEN_DIR, compiled_plans

        plans = compiled_plans()
        assert len(plans) == 6
        for name, got in plans.items():
            path = os.path.join(GOLDEN_DIR, f"{name}.json")
            assert os.path.exists(path), f"golden manifest missing: {name}"
            with open(path) as f:
                assert json.load(f) == got, f"golden mismatch: {name}"


class TestGenerateValidation:
    def test_temperature_without_key_raises(self):
        """Satellite: clear error instead of failing inside
        jax.random.split(None) deep in the decode loop."""
        from repro.serve.engine import ServeEngine

        cfg = cb.get_config("starcoder2_3b", smoke=True)
        params = T.init_lm(cfg, jax.random.key(0))
        engine = ServeEngine(cfg, params)
        prompts = jnp.zeros((1, 4), jnp.int32)
        with pytest.raises(ValueError, match="PRNG key"):
            engine.generate(prompts, max_new=2, temperature=0.7)
        out = engine.generate(prompts, max_new=2, temperature=0.7,
                              key=jax.random.key(1))
        assert out.tokens.shape == (1, 2)
