"""Distribution-layer tests: run under forced multi-device CPU in
subprocesses (so the main test process stays single-device).

Covers: small-mesh dry-run of train/serve steps (the in-CI proxy for the
512-chip dry-run), pipeline parallelism vs the serial oracle, sharding-rule
divisibility invariants, and distributed equivalence of the sharded train
step vs single-device execution.
"""
import json
import subprocess
import sys
import textwrap

import pytest

from repro.configs import base as cb
from repro.distributed.sharding import divisibility_report


def _run(code: str, timeout=560):
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         cwd="/root/repo", capture_output=True, text=True,
                         timeout=timeout)
    assert out.returncode == 0, (out.stdout[-500:], out.stderr[-2000:])
    return out.stdout


class TestShardingRules:
    @pytest.mark.parametrize("arch", [a for a in cb.ARCH_IDS
                                      if a not in ("mnist_fc", "vgg16_cifar10")])
    def test_tp16_divisibility(self, arch):
        """The documented invariant: d_ff / q_dim / kv_dim shard cleanly
        over the 16-way model axis for every assigned arch."""
        cfg = cb.get_config(arch)
        rep = divisibility_report(cfg, 16)
        assert rep["d_ff"], (arch, cfg.d_ff)
        assert rep["q_dim"], (arch, cfg.q_dim)
        assert rep["kv_dim"], (arch, cfg.kv_dim)
        assert rep["d_inner"], (arch, cfg.d_inner)

    def test_params_pspecs_rank_safe(self):
        import jax
        import jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from repro.distributed.sharding import params_pspecs
        from repro.models import transformer as T

        cfg = cb.get_config("jamba_1_5_large", smoke=True)
        params = jax.eval_shape(lambda: T.init_lm(cfg, jax.random.key(0)))
        specs = params_pspecs(params, fsdp=True)
        for (path, leaf), (_, spec) in zip(
                jax.tree_util.tree_leaves_with_path(params),
                jax.tree_util.tree_leaves_with_path(
                    specs, is_leaf=lambda x: isinstance(x, P))):
            assert len(spec) <= leaf.ndim, (path, spec, leaf.shape)


class TestShardingHelpers:
    """Satellite coverage for the distributed/sharding.py helpers."""

    def test_shardctx_act_noop_without_mesh(self):
        import jax.numpy as jnp
        from repro.distributed.sharding import ShardCtx

        x = jnp.arange(12.0).reshape(2, 2, 3)
        sh = ShardCtx(mesh=None)
        assert sh.act(x, "btd") is x          # identity, no device state
        assert ShardCtx(mesh=None, enable=False).act(x, "btf") is x

    def test_mesh_context_spans_both_jax_apis(self, monkeypatch):
        """jax >= 0.5 exposes jax.set_mesh; 0.4.x enters the Mesh object.
        The shim must return a context manager on both branches."""
        import jax
        from repro.distributed.sharding import mesh_context

        class FakeMesh:
            entered = exited = False

            def __enter__(self):
                FakeMesh.entered = True
                return self

            def __exit__(self, *a):
                FakeMesh.exited = True
                return False

        # branch 1: jax.set_mesh present — the shim must call it
        calls = []
        monkeypatch.setattr(jax, "set_mesh",
                            lambda m: calls.append(m) or FakeMesh(),
                            raising=False)
        with mesh_context("the-mesh"):
            pass
        assert calls == ["the-mesh"]
        # branch 2: no jax.set_mesh — the mesh object itself is the context
        monkeypatch.delattr(jax, "set_mesh", raising=False)
        m = FakeMesh()
        with mesh_context(m) as entered:
            assert entered is m
        assert FakeMesh.entered and FakeMesh.exited

    def test_batch_axes_with_and_without_pod(self):
        from types import SimpleNamespace

        from repro.distributed.sharding import batch_axes

        assert batch_axes(None) == ("data",)
        single = SimpleNamespace(axis_names=("data", "model"))
        multi = SimpleNamespace(axis_names=("pod", "data", "model"))
        assert batch_axes(single) == ("data",)
        assert batch_axes(multi) == ("pod", "data")

    def test_leaf_pspec_matches_params_pspecs(self):
        """leaf_pspec is the single-leaf form of the tree mapper."""
        import jax
        from jax.sharding import PartitionSpec as P

        from repro.distributed.sharding import leaf_pspec, params_pspecs

        params = {"layers": {"attn": {"w_qkv": jax.ShapeDtypeStruct(
            (4, 64, 96), jax.numpy.float32)}}}
        tree = params_pspecs(params)
        assert tree["layers"]["attn"]["w_qkv"] == \
            leaf_pspec("layers/attn/w_qkv", 3)
        assert leaf_pspec("layers/attn/w_qkv", 3) == P(None, None, "model")
        assert leaf_pspec("layers/attn/w_o", 2) == P("model", None)
        assert leaf_pspec("layers/ln1/scale", 1) == P(None)  # replicated

    def test_shardctx_threads_through_apply_seams(self):
        """apply_linear/apply_conv2d constrain their OUTPUT through the
        sh/kind kwargs — whichever backend served the layer — and stay
        no-ops when sh or kind is absent."""
        import jax
        import jax.numpy as jnp

        from repro.models.layers import apply_conv2d, apply_linear

        calls = []

        class SpyCtx:
            def act(self, x, kind):
                calls.append((kind, x.shape))
                return x + 1.0

        w = jnp.ones((4, 3))
        x = jnp.ones((2, 4))
        base = apply_linear(w, x)
        got = apply_linear(w, x, sh=SpyCtx(), kind="btf")
        assert calls == [("btf", (2, 3))]
        assert float(jnp.abs(got - (base + 1.0)).max()) == 0.0
        assert apply_linear(w, x, sh=SpyCtx()) is not None  # kind=None: no-op
        assert calls == [("btf", (2, 3))]
        cw = jnp.ones((3, 3, 2, 5))
        cx = jnp.ones((1, 4, 4, 2))
        calls.clear()
        out = apply_conv2d(cw, cx, sh=SpyCtx(), kind="btd")
        assert calls == [("btd", out.shape)]

    def test_cache_pspecs_handle_empty_data_axes(self):
        """A pure tensor-parallel mesh has no data/pod axis: slot dims
        must replicate (entry None), not crash on the empty dp tuple."""
        from jax.sharding import PartitionSpec as P

        from repro.configs import base as cb
        from repro.models.transformer import cache_pspecs, cache_slot_axes

        for arch in ("starcoder2_3b", "mamba2_130m", "jamba_1_5_large"):
            cfg = cb.get_config(arch, smoke=True)
            specs = cache_pspecs(cfg, dp_axes=())
            assert set(specs) == set(cache_slot_axes(cfg))
            for name, axis in cache_slot_axes(cfg).items():
                spec = specs[name]
                assert len(spec) <= axis + 1 or spec[axis] is None, \
                    (arch, name)
            assert specs["pos"] == P(None)

    def test_spec_json_roundtrip(self):
        from jax.sharding import PartitionSpec as P

        from repro.distributed.sharding import spec_from_json, spec_to_json

        for spec in (P(), P(None, "model"), P(("pod", "data"), None, "model")):
            assert spec_from_json(spec_to_json(spec)) == spec


class TestSmallMeshDryRun:
    """8-device (2 data x 4 model) version of the production dry-run."""

    def test_train_step_lowers_and_runs(self):
        out = _run("""
            import os
            os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
            import sys; sys.path.insert(0, "src")
            import json
            import jax, jax.numpy as jnp
            from repro.configs import base as cb
            from repro.core.policy import DEFAULT_POLICY
            from repro.distributed.sharding import ShardCtx, mesh_context, params_pspecs
            from repro.launch import specs as SP
            from repro.models import transformer as T
            from repro.optim import schedules
            from repro.optim.sgd import sgd_momentum
            from repro.train import steps as ST
            from jax.sharding import NamedSharding, PartitionSpec as P

            mesh = jax.make_mesh((2, 4), ("data", "model"))
            cfg = cb.get_config("starcoder2_3b", smoke=True)
            sh = ShardCtx(mesh)
            opt = sgd_momentum(schedules.constant(1e-2))
            step = ST.make_train_step(ST.make_lm_loss(cfg, sh), opt, "det",
                                      DEFAULT_POLICY)
            params = T.init_lm(cfg, jax.random.key(0))
            state = ST.init_train_state(params, opt)
            st_ps = SP.state_pspecs(state["params"], mesh, fsdp=False)
            st_ps = SP.sanitize_pspecs(jax.eval_shape(lambda: state), st_ps, mesh)
            ns = lambda t: jax.tree.map(lambda s: NamedSharding(mesh, s), t,
                                        is_leaf=lambda x: isinstance(x, P))
            batch = {"tokens": jax.random.randint(jax.random.key(1), (8, 33),
                                                  0, cfg.vocab_size)}
            with mesh_context(mesh):
                jitted = jax.jit(step, in_shardings=(ns(st_ps),
                                 ns({"tokens": P(("data",), None)})),
                                 out_shardings=(ns(st_ps), None))
                state2, metrics = jitted(state, batch)
            # run ACTUALLY executes on 8 devices (not just lowers)
            print(json.dumps({"loss": float(metrics["loss"]),
                              "step": int(state2["step"])}))
        """)
        res = json.loads(out.strip().splitlines()[-1])
        assert res["step"] == 1
        assert res["loss"] > 0

    def test_sharded_equals_single_device(self):
        """Same step, same data: 8-device SPMD == single device."""
        out = _run("""
            import os
            os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
            import sys; sys.path.insert(0, "src")
            import json
            import jax, jax.numpy as jnp, numpy as np
            from repro.configs import base as cb
            from repro.core.policy import DEFAULT_POLICY
            from repro.distributed.sharding import ShardCtx, mesh_context
            from repro.launch import specs as SP
            from repro.models import transformer as T
            from repro.optim import schedules
            from repro.optim.sgd import sgd_momentum
            from repro.train import steps as ST
            from jax.sharding import NamedSharding, PartitionSpec as P

            cfg = cb.get_config("starcoder2_3b", smoke=True)
            opt = sgd_momentum(schedules.constant(1e-2))
            params = T.init_lm(cfg, jax.random.key(0))
            batch = {"tokens": jax.random.randint(jax.random.key(1), (8, 33),
                                                  0, cfg.vocab_size)}
            # single device
            step0 = ST.make_train_step(ST.make_lm_loss(cfg), opt, "det",
                                       DEFAULT_POLICY)
            s0 = ST.init_train_state(jax.tree.map(jnp.copy, params), opt)
            s0, m0 = jax.jit(step0)(s0, batch)
            # sharded
            mesh = jax.make_mesh((2, 4), ("data", "model"))
            sh = ShardCtx(mesh)
            step1 = ST.make_train_step(ST.make_lm_loss(cfg, sh), opt, "det",
                                       DEFAULT_POLICY)
            s1 = ST.init_train_state(jax.tree.map(jnp.copy, params), opt)
            st_ps = SP.state_pspecs(s1["params"], mesh, fsdp=False)
            st_ps = SP.sanitize_pspecs(jax.eval_shape(lambda: s1), st_ps, mesh)
            ns = lambda t: jax.tree.map(lambda s: NamedSharding(mesh, s), t,
                                        is_leaf=lambda x: isinstance(x, P))
            with mesh_context(mesh):
                s1, m1 = jax.jit(step1, in_shardings=(ns(st_ps),
                    ns({"tokens": P(("data",), None)})),
                    out_shardings=(ns(st_ps), None))(s1, batch)
            d = max(float(jnp.abs(a.astype(jnp.float32) -
                                  b.astype(jnp.float32)).max())
                    for a, b in zip(jax.tree.leaves(s0["params"]),
                                    jax.tree.leaves(s1["params"]))
                    if hasattr(a, "astype"))
            print(json.dumps({"loss0": float(m0["loss"]),
                              "loss1": float(m1["loss"]), "max_param_diff": d}))
        """)
        res = json.loads(out.strip().splitlines()[-1])
        assert abs(res["loss0"] - res["loss1"]) < 1e-3, res
        assert res["max_param_diff"] < 5e-3, res

    def test_serve_decode_lowers(self):
        out = _run("""
            import os
            os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
            import sys; sys.path.insert(0, "src")
            sys.argv = ["dryrun", "--arch", "h2o_danube_3_4b", "--shape",
                        "decode_32k", "--mesh", "single", "--smoke",
                        "--out", "/tmp/dr_smoke_test", "--force"]
            # monkeypatch the production mesh to the 8-device debug mesh
            import jax
            from repro.launch import mesh as M
            M.make_production_mesh = lambda multi_pod=False: (
                jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
                if multi_pod else jax.make_mesh((2, 4), ("data", "model")))
            from repro.launch import dryrun
            dryrun.make_production_mesh = M.make_production_mesh
            dryrun.main()
        """)
        assert "1 ok" in out


class TestMeshShardedServing:
    """Tentpole acceptance: tensor-parallel execution plans through the
    step-level decode engine on a forced 4-device CPU mesh."""

    def test_stream_serve_bit_identical_and_placed(self):
        """For det and xnor plans on a 2x2 ("data", "model") mesh: greedy
        stream_serve output is bit-identical to the single-device engine
        through a mid-stream slot refill (5 requests, 2 slots, mixed
        max_new), packed weight words shard over "model" on the out-channel
        dim, and the decode cache shards slots over "data"."""
        out = _run("""
            import os
            os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
            import sys; sys.path.insert(0, "src")
            import json
            import jax, numpy as np
            from jax.sharding import PartitionSpec as P
            from repro.configs import base as cb
            from repro.core.policy import DEFAULT_POLICY
            from repro.engine import compile_plan
            from repro.models import transformer as T
            from repro.serve.batcher import SlotBatcher
            from repro.serve.engine import ServeEngine, stream_serve

            mesh = jax.make_mesh((2, 2), ("data", "model"))
            cfg = cb.get_config("starcoder2_3b", smoke=True)
            params = T.init_lm(cfg, jax.random.key(0))

            def run(engine):
                rng = np.random.default_rng(0)
                b = SlotBatcher(2, 8)
                for m in [3, 5, 2, 4, 3]:   # 5 requests > 2 slots: refill
                    b.submit(rng.integers(0, cfg.vocab_size, 8), m)
                stream_serve(engine, b)
                return {int(r.uid): list(map(int, r.generated))
                        for r in b.completed}

            identical = {}
            for mode in ("det", "xnor"):
                plan = compile_plan(params, DEFAULT_POLICY, mode, warn=False,
                                    mesh=mesh)
                packed = plan.pack(params)
                single = run(ServeEngine(cfg, packed))
                eng = ServeEngine(cfg, packed, mesh=mesh, plan=plan)
                identical[mode] = run(eng) == single
            # placement facts (last engine): packed words TP on out-channel
            w = eng.params["layers"]["attn"]["w_qkv"]
            wspec = w.packed.sharding.spec
            state = eng.init_decode(2, 8, 4)
            kspec = state.cache["k"].sharding.spec
            # pure-TP mesh (no data axis): placement must not crash and
            # slot dims replicate
            tp_mesh = jax.make_mesh((4,), ("model",))
            tp_state = ServeEngine(cfg, packed, mesh=tp_mesh).init_decode(
                2, 8, 4)
            tp_pos = list(tp_state.cache["pos"].sharding.spec)
            print(json.dumps({
                "identical": identical,
                "w_qkv_spec": [None if e is None else str(e) for e in wspec],
                "k_model_sharded": "model" in kspec,
                "k_data_axis": kspec[1] if len(kspec) > 1 else None,
                "pos_spec": list(state.cache["pos"].sharding.spec),
                "tp_pos_replicated": all(e is None for e in tp_pos),
            }))
        """)
        res = json.loads(out.strip().splitlines()[-1])
        assert res["identical"] == {"det": True, "xnor": True}
        # packed int32 words: "model" on the out-channel (last) dim only —
        # the word (K//32) dim is never split
        assert res["w_qkv_spec"][-1] == "model"
        assert all(e is None for e in res["w_qkv_spec"][:-1])
        # decode cache: slots over "data"
        assert res["k_data_axis"] == "data"
        assert res["pos_spec"] == ["data"]
        assert res["tp_pos_replicated"]

    def test_chunked_decode_bit_identical_sharded(self):
        """The multi-step inner loop (``decode_chunk > 1``: d decode steps
        under one lax.scan, one host crossing per chunk) emits streams
        bit-identical to the single-step single-device loop for det AND
        xnor on the 2x2 mesh, through a mid-stream slot refill (5 requests,
        2 slots, mixed max_new — chunk clipping to ``min_remaining`` must
        land every completion exactly on a chunk boundary)."""
        out = _run("""
            import os
            os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
            import sys; sys.path.insert(0, "src")
            import json
            import jax, numpy as np
            from repro.configs import base as cb
            from repro.core.policy import DEFAULT_POLICY
            from repro.engine import compile_plan
            from repro.models import transformer as T
            from repro.serve.batcher import SlotBatcher
            from repro.serve.engine import ServeEngine, stream_serve

            mesh = jax.make_mesh((2, 2), ("data", "model"))
            cfg = cb.get_config("starcoder2_3b", smoke=True)
            params = T.init_lm(cfg, jax.random.key(0))

            def run(engine, chunk):
                rng = np.random.default_rng(0)
                b = SlotBatcher(2, 8)
                for m in [3, 5, 2, 4, 3]:   # 5 requests > 2 slots: refill
                    b.submit(rng.integers(0, cfg.vocab_size, 8), m)
                steps = stream_serve(engine, b, decode_chunk=chunk)
                return steps, {int(r.uid): list(map(int, r.generated))
                               for r in b.completed}

            res = {}
            for mode in ("det", "xnor"):
                plan = compile_plan(params, DEFAULT_POLICY, mode,
                                    warn=False, mesh=mesh)
                packed = plan.pack(params)
                s1, single = run(ServeEngine(cfg, packed), 1)
                eng = ServeEngine(cfg, packed, mesh=mesh, plan=plan)
                s3, chunked = run(eng, 3)
                res[mode] = {"identical": chunked == single,
                             "same_steps": s1 == s3}
            print(json.dumps(res))
        """)
        res = json.loads(out.strip().splitlines()[-1])
        for mode in ("det", "xnor"):
            assert res[mode]["identical"], mode
            assert res[mode]["same_steps"], mode

    def test_ensemble_replica_axis_sharded_bit_identical(self):
        """Ensemble acceptance: K=4 stochastic replicas with the replica
        axis sharded over the plan's ``replica_axis`` column ("data" and
        "model" both exercised) on a forced 4-device mesh stream greedy
        tokens bit-identical to the single-device ensemble engine, and the
        stacked packed words actually carry the replica axis on dim 0."""
        out = _run("""
            import os
            os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
            import sys; sys.path.insert(0, "src")
            import json
            import jax, numpy as np
            from repro.configs import base as cb
            from repro.core.policy import DEFAULT_POLICY
            from repro.engine import compile_plan
            from repro.models import transformer as T
            from repro.serve.batcher import SlotBatcher
            from repro.serve.engine import ServeEngine, stream_serve
            from repro.stoch import place_replicas, sample_replicas

            cfg = cb.get_config("starcoder2_3b", smoke=True)
            params = T.init_lm(cfg, jax.random.key(0))

            def run(engine):
                rng = np.random.default_rng(0)
                b = SlotBatcher(2, 8)
                for m in [3, 5, 2]:
                    b.submit(rng.integers(0, cfg.vocab_size, 8), m)
                stream_serve(engine, b)
                return {int(r.uid): list(map(int, r.generated))
                        for r in b.completed}

            res = {}
            for rax, shape, names in [("data", (4,), ("data",)),
                                      ("model", (2, 2), ("data", "model"))]:
                mesh = jax.make_mesh(shape, names)
                plan = compile_plan(params, DEFAULT_POLICY, "stoch",
                                    warn=False, mesh=mesh, replica_axis=rax)
                rs = sample_replicas(params, plan, jax.random.key(1), 4)
                single = run(ServeEngine(cfg, None, ensemble=rs))
                eng = ServeEngine(cfg, None, ensemble=rs, mesh=mesh,
                                  plan=plan)
                stacked_w = eng._replicas.stacked["layers/attn/w_qkv"]
                res[rax] = {
                    "identical": run(eng) == single,
                    "lead_spec": str(stacked_w.packed.sharding.spec[0]),
                }
            print(json.dumps(res))
        """)
        res = json.loads(out.strip().splitlines()[-1])
        for rax in ("data", "model"):
            assert res[rax]["identical"], rax
            assert res[rax]["lead_spec"] == rax

    def test_plan_manifest_roundtrips_sharding_column(self, tmp_path):
        """Satellite of the tentpole: the sharding column survives
        save/load and the loaded plan still packs identically (no mesh
        needed — the column is axis names)."""
        import jax

        from repro.configs import base as cb
        from repro.engine import ExecutionPlan, compile_plan
        from repro.models import transformer as T

        from repro.core.policy import DEFAULT_POLICY

        cfg = cb.get_config("starcoder2_3b", smoke=True)
        params = T.init_lm(cfg, jax.random.key(0))
        plan = compile_plan(params, DEFAULT_POLICY, "det", warn=False)
        path = str(tmp_path / "plan.json")
        plan.save(path)
        loaded = ExecutionPlan.load(path)
        assert loaded.to_json() == plan.to_json()
        # binary backends: "model" on the out-channel dim
        row = loaded["layers/attn/w_qkv"]
        assert row.backend == "packed"
        assert row.sharding == [None, None, "model"]
        from jax.sharding import PartitionSpec as P
        assert row.pspec == P(None, None, "model")
        # dense leaves follow the Megatron rules (w_o is row-parallel when
        # dense or xnor — exact integer partial sums — and out-channel
        # under packed, whose f32 partials must not cross an all-reduce);
        # the tied embedding is vocab-parallel: (V, D) sharded on V
        assert loaded["embed/embedding"].sharding == ["model", None]
        assert loaded["layers/ln1/scale"].sharding == [None, None]


class TestPipelineParallel:
    def test_gpipe_matches_serial_oracle(self):
        out = _run("""
            import os
            os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
            import sys; sys.path.insert(0, "src")
            import json
            import jax, jax.numpy as jnp, numpy as np
            from repro.distributed.pipeline_parallel import (
                pipeline_forward, reference_forward, run_pipeline)

            n_stages, n_micro, mb, d = 4, 8, 2, 16
            mesh = jax.make_mesh((n_stages,), ("stage",))
            def stage_fn(p, x):
                return jnp.tanh(x @ p["w"] + p["b"])
            params = {
                "w": jax.random.normal(jax.random.key(0), (n_stages, d, d)) * 0.5,
                "b": jax.random.normal(jax.random.key(1), (n_stages, d)) * 0.1,
            }
            micro = jax.random.normal(jax.random.key(2), (n_micro, mb, d))
            got = run_pipeline(mesh, stage_fn, params, micro)
            want = reference_forward(stage_fn, params, micro)
            err = float(jnp.abs(got - want).max())
            print(json.dumps({"err": err}))
        """)
        res = json.loads(out.strip().splitlines()[-1])
        assert res["err"] < 1e-5, res
