"""Distribution-layer tests: run under forced multi-device CPU in
subprocesses (so the main test process stays single-device).

Covers: small-mesh dry-run of train/serve steps (the in-CI proxy for the
512-chip dry-run), pipeline parallelism vs the serial oracle, sharding-rule
divisibility invariants, and distributed equivalence of the sharded train
step vs single-device execution.
"""
import json
import subprocess
import sys
import textwrap

import pytest

from repro.configs import base as cb
from repro.distributed.sharding import divisibility_report


def _run(code: str, timeout=560):
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         cwd="/root/repo", capture_output=True, text=True,
                         timeout=timeout)
    assert out.returncode == 0, (out.stdout[-500:], out.stderr[-2000:])
    return out.stdout


class TestShardingRules:
    @pytest.mark.parametrize("arch", [a for a in cb.ARCH_IDS
                                      if a not in ("mnist_fc", "vgg16_cifar10")])
    def test_tp16_divisibility(self, arch):
        """The documented invariant: d_ff / q_dim / kv_dim shard cleanly
        over the 16-way model axis for every assigned arch."""
        cfg = cb.get_config(arch)
        rep = divisibility_report(cfg, 16)
        assert rep["d_ff"], (arch, cfg.d_ff)
        assert rep["q_dim"], (arch, cfg.q_dim)
        assert rep["kv_dim"], (arch, cfg.kv_dim)
        assert rep["d_inner"], (arch, cfg.d_inner)

    def test_params_pspecs_rank_safe(self):
        import jax
        import jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from repro.distributed.sharding import params_pspecs
        from repro.models import transformer as T

        cfg = cb.get_config("jamba_1_5_large", smoke=True)
        params = jax.eval_shape(lambda: T.init_lm(cfg, jax.random.key(0)))
        specs = params_pspecs(params, fsdp=True)
        for (path, leaf), (_, spec) in zip(
                jax.tree_util.tree_leaves_with_path(params),
                jax.tree_util.tree_leaves_with_path(
                    specs, is_leaf=lambda x: isinstance(x, P))):
            assert len(spec) <= leaf.ndim, (path, spec, leaf.shape)


class TestSmallMeshDryRun:
    """8-device (2 data x 4 model) version of the production dry-run."""

    def test_train_step_lowers_and_runs(self):
        out = _run("""
            import os
            os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
            import sys; sys.path.insert(0, "src")
            import json
            import jax, jax.numpy as jnp
            from repro.configs import base as cb
            from repro.core.policy import DEFAULT_POLICY
            from repro.distributed.sharding import ShardCtx, mesh_context, params_pspecs
            from repro.launch import specs as SP
            from repro.models import transformer as T
            from repro.optim import schedules
            from repro.optim.sgd import sgd_momentum
            from repro.train import steps as ST
            from jax.sharding import NamedSharding, PartitionSpec as P

            mesh = jax.make_mesh((2, 4), ("data", "model"))
            cfg = cb.get_config("starcoder2_3b", smoke=True)
            sh = ShardCtx(mesh)
            opt = sgd_momentum(schedules.constant(1e-2))
            step = ST.make_train_step(ST.make_lm_loss(cfg, sh), opt, "det",
                                      DEFAULT_POLICY)
            params = T.init_lm(cfg, jax.random.key(0))
            state = ST.init_train_state(params, opt)
            st_ps = SP.state_pspecs(state["params"], mesh, fsdp=False)
            st_ps = SP.sanitize_pspecs(jax.eval_shape(lambda: state), st_ps, mesh)
            ns = lambda t: jax.tree.map(lambda s: NamedSharding(mesh, s), t,
                                        is_leaf=lambda x: isinstance(x, P))
            batch = {"tokens": jax.random.randint(jax.random.key(1), (8, 33),
                                                  0, cfg.vocab_size)}
            with mesh_context(mesh):
                jitted = jax.jit(step, in_shardings=(ns(st_ps),
                                 ns({"tokens": P(("data",), None)})),
                                 out_shardings=(ns(st_ps), None))
                state2, metrics = jitted(state, batch)
            # run ACTUALLY executes on 8 devices (not just lowers)
            print(json.dumps({"loss": float(metrics["loss"]),
                              "step": int(state2["step"])}))
        """)
        res = json.loads(out.strip().splitlines()[-1])
        assert res["step"] == 1
        assert res["loss"] > 0

    def test_sharded_equals_single_device(self):
        """Same step, same data: 8-device SPMD == single device."""
        out = _run("""
            import os
            os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
            import sys; sys.path.insert(0, "src")
            import json
            import jax, jax.numpy as jnp, numpy as np
            from repro.configs import base as cb
            from repro.core.policy import DEFAULT_POLICY
            from repro.distributed.sharding import ShardCtx, mesh_context
            from repro.launch import specs as SP
            from repro.models import transformer as T
            from repro.optim import schedules
            from repro.optim.sgd import sgd_momentum
            from repro.train import steps as ST
            from jax.sharding import NamedSharding, PartitionSpec as P

            cfg = cb.get_config("starcoder2_3b", smoke=True)
            opt = sgd_momentum(schedules.constant(1e-2))
            params = T.init_lm(cfg, jax.random.key(0))
            batch = {"tokens": jax.random.randint(jax.random.key(1), (8, 33),
                                                  0, cfg.vocab_size)}
            # single device
            step0 = ST.make_train_step(ST.make_lm_loss(cfg), opt, "det",
                                       DEFAULT_POLICY)
            s0 = ST.init_train_state(jax.tree.map(jnp.copy, params), opt)
            s0, m0 = jax.jit(step0)(s0, batch)
            # sharded
            mesh = jax.make_mesh((2, 4), ("data", "model"))
            sh = ShardCtx(mesh)
            step1 = ST.make_train_step(ST.make_lm_loss(cfg, sh), opt, "det",
                                       DEFAULT_POLICY)
            s1 = ST.init_train_state(jax.tree.map(jnp.copy, params), opt)
            st_ps = SP.state_pspecs(s1["params"], mesh, fsdp=False)
            st_ps = SP.sanitize_pspecs(jax.eval_shape(lambda: s1), st_ps, mesh)
            ns = lambda t: jax.tree.map(lambda s: NamedSharding(mesh, s), t,
                                        is_leaf=lambda x: isinstance(x, P))
            with mesh_context(mesh):
                s1, m1 = jax.jit(step1, in_shardings=(ns(st_ps),
                    ns({"tokens": P(("data",), None)})),
                    out_shardings=(ns(st_ps), None))(s1, batch)
            d = max(float(jnp.abs(a.astype(jnp.float32) -
                                  b.astype(jnp.float32)).max())
                    for a, b in zip(jax.tree.leaves(s0["params"]),
                                    jax.tree.leaves(s1["params"]))
                    if hasattr(a, "astype"))
            print(json.dumps({"loss0": float(m0["loss"]),
                              "loss1": float(m1["loss"]), "max_param_diff": d}))
        """)
        res = json.loads(out.strip().splitlines()[-1])
        assert abs(res["loss0"] - res["loss1"]) < 1e-3, res
        assert res["max_param_diff"] < 5e-3, res

    def test_serve_decode_lowers(self):
        out = _run("""
            import os
            os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
            import sys; sys.path.insert(0, "src")
            sys.argv = ["dryrun", "--arch", "h2o_danube_3_4b", "--shape",
                        "decode_32k", "--mesh", "single", "--smoke",
                        "--out", "/tmp/dr_smoke_test", "--force"]
            # monkeypatch the production mesh to the 8-device debug mesh
            import jax
            from repro.launch import mesh as M
            M.make_production_mesh = lambda multi_pod=False: (
                jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
                if multi_pod else jax.make_mesh((2, 4), ("data", "model")))
            from repro.launch import dryrun
            dryrun.make_production_mesh = M.make_production_mesh
            dryrun.main()
        """)
        assert "1 ok" in out


class TestPipelineParallel:
    def test_gpipe_matches_serial_oracle(self):
        out = _run("""
            import os
            os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
            import sys; sys.path.insert(0, "src")
            import json
            import jax, jax.numpy as jnp, numpy as np
            from repro.distributed.pipeline_parallel import (
                pipeline_forward, reference_forward, run_pipeline)

            n_stages, n_micro, mb, d = 4, 8, 2, 16
            mesh = jax.make_mesh((n_stages,), ("stage",))
            def stage_fn(p, x):
                return jnp.tanh(x @ p["w"] + p["b"])
            params = {
                "w": jax.random.normal(jax.random.key(0), (n_stages, d, d)) * 0.5,
                "b": jax.random.normal(jax.random.key(1), (n_stages, d)) * 0.1,
            }
            micro = jax.random.normal(jax.random.key(2), (n_micro, mb, d))
            got = run_pipeline(mesh, stage_fn, params, micro)
            want = reference_forward(stage_fn, params, micro)
            err = float(jnp.abs(got - want).max())
            print(json.dumps({"err": err}))
        """)
        res = json.loads(out.strip().splitlines()[-1])
        assert res["err"] < 1e-5, res
