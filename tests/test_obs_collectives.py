"""Static collective audit: exact per-step collective counts for the
sharded serving programs (golden-checked), the audit vs hlo_analysis
cross-check on a hand-built sharded program, and the plan_report
prediction column.

Multi-device pieces run in subprocesses with forced host devices (device
count is fixed at backend init), mirroring tests/test_distributed.py.
"""
import json
import os
import subprocess
import sys
import textwrap

import pytest

from repro.obs.collectives import (ACT_BYTES, CollectiveAudit, audit_hlo,
                                   format_audit, predict_row_collective)

GOLDEN = os.path.join(os.path.dirname(__file__), os.pardir, "benchmarks",
                      "golden_plans", "collectives.json")


def _run(code: str, timeout=560):
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         cwd="/root/repo", capture_output=True, text=True,
                         timeout=timeout)
    assert out.returncode == 0, (out.stdout[-500:], out.stderr[-2000:])
    return out.stdout


class TestCollectiveAudit:
    def test_json_round_trip(self):
        a = CollectiveAudit("decode_step",
                            counts={"all-reduce": 3, "all-gather": 1},
                            bytes={"all-reduce": 96.0, "all-gather": 32.0},
                            reshard_copies=2, reshard_copy_bytes=64.0)
        b = CollectiveAudit.from_json(json.loads(json.dumps(a.to_json())))
        assert b == a
        assert a.total_count == 4 and a.total_bytes == 128.0
        assert "all-reduce x3" in a.summary()

    def test_format_audit_table(self):
        a = CollectiveAudit("decode_step", counts={"all-reduce": 3},
                            bytes={"all-reduce": 96.0}, reshard_copies=1,
                            reshard_copy_bytes=8.0)
        table = format_audit({"decode_step": a})
        assert "all-reduce" in table and "reshard-copy" in table
        assert table.splitlines()[0].startswith("entry")

    def test_empty_program_audits_clean(self):
        """A trivial single-device program has no collectives at all."""
        import jax
        import jax.numpy as jnp

        compiled = jax.jit(lambda x: x * 2.0).lower(
            jnp.ones((4, 4))).compile()
        a = audit_hlo(compiled.as_text(), entry="double")
        assert a.total_count == 0 and a.counts == {}


class TestPredictRowCollective:
    def test_out_channel_split_predicts_all_gather(self):
        c = predict_row_collective([None, "model"], (256, 512), batch=8)
        assert c["kind"] == "all-gather" and c["axes"] == ["model"]
        assert c["bytes_per_app"] == 8 * 512 * ACT_BYTES
        assert c["parts"] is None        # unknown without axis sizes
        c = predict_row_collective([None, "model"], (256, 512), batch=8,
                                   axis_sizes={"model": 4, "data": 2})
        assert c["parts"] == 4

    def test_contraction_split_predicts_all_reduce(self):
        c = predict_row_collective(["model", None], (256, 512), batch=4)
        assert c["kind"] == "all-reduce" and c["axes"] == ["model"]
        assert c["bytes_per_app"] == 4 * 512 * ACT_BYTES

    def test_batch_axes_and_trivial_splits_predict_nothing(self):
        assert predict_row_collective(["data", None], (256, 512)) is None
        assert predict_row_collective(None, (256, 512)) is None
        assert predict_row_collective([None, "model"], (512,)) is None
        assert predict_row_collective([None, "model"], (256, 512),
                                      axis_sizes={"model": 1}) is None

    def test_plan_report_carries_collectives_column(self):
        """A mesh-compiled plan's report predicts a collective for every
        TP-sharded row and formats it into the table."""
        import jax

        from repro.configs import base as cb
        from repro.core.policy import DEFAULT_POLICY
        from repro.engine import compile_plan
        from repro.engine.plan import format_plan_table, plan_report
        from repro.models import transformer as T

        mesh = jax.make_mesh((1, 1), ("data", "model"))
        cfg = cb.get_config("starcoder2_3b", smoke=True)
        params = jax.eval_shape(lambda: T.init_lm(cfg, jax.random.key(0)))
        plan = compile_plan(params, DEFAULT_POLICY, "det", warn=False,
                            mesh=mesh)
        rows = plan_report(plan, batch=8)
        predicted = [r for r in rows if r["collectives"] is not None]
        assert predicted, "no TP-sharded row produced a prediction"
        for r in predicted:
            c = r["collectives"]
            assert c["kind"] in ("all-gather", "all-reduce")
            assert c["bytes_per_app"] == 8 * r["n"] * ACT_BYTES
        table = format_plan_table(rows)
        assert "collectives" in table.splitlines()[0]
        assert "all-gather@model" in table
        # axis size 1 resolves every prediction away (nothing to gather)
        rows1 = plan_report(plan, batch=8,
                            axis_sizes={"data": 1, "model": 1})
        assert all(r["collectives"] is None for r in rows1)


class TestAuditVsHloAnalysis:
    def test_psum_matmul_audit_is_exact(self):
        """Cross-check on an unscanned hand-built sharded program: the
        audit must agree with hlo_analysis kind-for-kind AND with the
        analytic expectation — a contraction-sharded matmul needs exactly
        one all-reduce of the (M, N) f32 output."""
        out = _run("""
            import os
            os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
            os.environ["JAX_PLATFORMS"] = "cpu"
            import sys, json
            sys.path.insert(0, "src")
            import jax, jax.numpy as jnp
            from jax.sharding import NamedSharding, PartitionSpec as P
            from repro.core import hlo_analysis as H
            from repro.obs.collectives import audit_hlo

            mesh = jax.make_mesh((4,), ("model",))
            x = jax.device_put(jnp.ones((8, 64), jnp.float32),
                               NamedSharding(mesh, P(None, "model")))
            w = jax.device_put(jnp.ones((64, 16), jnp.float32),
                               NamedSharding(mesh, P("model", None)))
            out_s = NamedSharding(mesh, P(None, None))
            f = jax.jit(lambda x, w: x @ w, out_shardings=out_s)
            text = f.lower(x, w).compile().as_text()
            audit = audit_hlo(text, entry="psum_matmul")
            cost = H.analyze(text)
            print("RESULT " + json.dumps({
                "audit": audit.to_json(),
                "hlo_counts": {k: int(v)
                               for k, v in cost.collective_count.items()},
                "hlo_bytes": dict(cost.collective_bytes_by_kind),
            }))
        """)
        res = json.loads([ln for ln in out.splitlines()
                          if ln.startswith("RESULT ")][-1][len("RESULT "):])
        audit = CollectiveAudit.from_json(res["audit"])
        # agreement with the hlo_analysis walk, kind for kind
        assert audit.counts == res["hlo_counts"]
        assert audit.bytes == pytest.approx(res["hlo_bytes"])
        # analytic exactness: one all-reduce of the f32 (8, 16) output
        assert audit.counts == {"all-reduce": 1}
        assert audit.bytes["all-reduce"] == 8 * 16 * 4


class TestGoldenShardedAudit:
    """The ROADMAP success metric, stated as a test: the det and xnor
    sharded golden plans execute an exact, known number of collectives per
    decode step on the 2x2 ("data", "model") mesh."""

    @pytest.fixture(scope="class")
    def measured(self):
        out = _run("""
            import os
            os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
            os.environ["JAX_PLATFORMS"] = "cpu"
            import sys, json
            sys.path.insert(0, "src"); sys.path.insert(0, ".")
            from benchmarks.check_collectives import _child
            print("RESULT " + json.dumps(_child()))
        """)
        return json.loads([ln for ln in out.splitlines()
                           if ln.startswith("RESULT ")][-1][len("RESULT "):])

    def test_matches_committed_golden(self, measured):
        with open(GOLDEN) as f:
            golden = json.load(f)
        assert golden["mesh"] == {"shape": [2, 2],
                                  "axes": ["data", "model"]}
        assert measured == golden["audits"]

    def test_decode_step_exact_counts(self, measured):
        """The headline numbers, asserted inline — after the decode-mode
        ShardCtx overhaul (replicated decode activations, model-free cache,
        vocab-parallel tied embedding, deferred logits gather, one-hot
        cache writes, outputs pinned to the init_decode placement;
        docs/ARCHITECTURE.md §Decode-step collective budget)
        a decode step runs 10 (det) / 18 (xnor) collectives, down from the
        41 the seq-parallel training layout cost. All remaining traffic is
        activation-sized: det is 8 per-layer all-gathers + the deferred
        logits gather + the vocab-parallel embed-lookup all-reduce; xnor
        swaps four of the gathers for exact integer popcount all-reduces
        (row-parallel down-projections) and pays two extra gathers pinning
        the fresh KV entries back to the model-replicated cache layout —
        the price of steady-state == audited program (unpinned, GSPMD
        retraced into a far slower second program)."""
        det = CollectiveAudit.from_json(measured["det"]["decode_step"])
        assert det.counts == {"all-gather": 9, "all-reduce": 1}
        assert det.total_count == 10
        assert det.bytes["all-gather"] == 10240.0
        assert det.bytes["all-reduce"] == 1024.0
        xnor = CollectiveAudit.from_json(measured["xnor"]["decode_step"])
        assert xnor.counts == {"all-gather": 7, "all-reduce": 5,
                               "collective-permute": 6}
        assert xnor.total_count == 18
        # no weight-sized traffic anywhere: the largest single transfer is
        # well under the 131072-byte tied-embedding table gather the old
        # layout paid every step
        for mode in ("det", "xnor"):
            a = CollectiveAudit.from_json(measured[mode]["decode_step"])
            assert a.total_bytes < 40_000
            assert a.reshard_copy_bytes < 65_536

    def test_prefill_exact_counts(self, measured):
        pre = CollectiveAudit.from_json(measured["det"]["prefill_into"])
        assert pre.counts == {"all-gather": 1, "all-reduce": 12,
                              "all-to-all": 12, "collective-permute": 8}
        assert pre.total_count == 33
