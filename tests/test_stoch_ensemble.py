"""Stochastic ensemble serving (repro.stoch): Eq.-2/3 sampling statistics,
replica reproducibility, k=1 bit-identity with the single-sample path, and
ensemble uncertainty stats through generate / stream_serve."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import base as cb
from repro.core.policy import DEFAULT_POLICY
from repro.engine import compile_plan
from repro.kernels import ops as kops
from repro.models import mnist_fc, transformer as T
from repro.serve.batcher import SlotBatcher
from repro.serve.engine import ServeEngine, stream_serve
from repro.stoch import (EnsembleStats, ensemble_forward, ensemble_stats,
                         replica_key, sample_replicas)


def _tree_arrays(tree):
    return [np.asarray(x) for x in jax.tree_util.tree_leaves(tree)]


def assert_trees_identical(a, b):
    assert jax.tree_util.tree_structure(a) == jax.tree_util.tree_structure(b)
    for la, lb in zip(_tree_arrays(a), _tree_arrays(b)):
        np.testing.assert_array_equal(la, lb)


class TestSamplingStatistics:
    """Satellite: the stochastic binarizer's empirical bit frequency matches
    the paper's Eq. 3 hard sigmoid P(w_b = +1) = clip((w+1)/2, 0, 1)."""

    def test_bit_frequency_matches_hard_sigmoid(self):
        grid = jnp.array([-0.9, -0.5, -0.25, 0.0, 0.25, 0.5, 0.9])
        samples = 4096                       # rows are iid draws per column
        w = jnp.broadcast_to(grid[None, :], (samples, grid.shape[0]))
        packed = kops.binarize_and_pack(w, jax.random.key(0),
                                        stochastic=True)
        from repro.core.packing import unpack_bits

        bits = unpack_bits(packed, dtype=jnp.float32)[:samples]   # +-1
        freq = np.asarray(jnp.mean((bits + 1.0) / 2.0, axis=0))
        want = np.asarray(jnp.clip((grid + 1.0) / 2.0, 0.0, 1.0))
        # 4096 iid draws: std <= 0.5/sqrt(4096) ~ 0.008; 5 sigma margin
        np.testing.assert_allclose(freq, want, atol=0.04)

    def test_endpoints_exact(self):
        """w = +-1 must be deterministic (P = 1 / 0 exactly): the fixed
        point threshold rounds 2^32 to f32 — without the endpoint guard the
        top ~128 uint32 words would tie and flip sign."""
        w = jnp.concatenate([jnp.full((64, 32), -1.0),
                             jnp.full((64, 32), 1.0)], axis=1)
        packed = kops.binarize_and_pack(w, jax.random.key(1),
                                        stochastic=True)
        from repro.core.packing import unpack_bits

        bits = np.asarray(unpack_bits(packed, dtype=jnp.float32)[:64])
        np.testing.assert_array_equal(bits[:, :32], -1.0)
        np.testing.assert_array_equal(bits[:, 32:], 1.0)


class TestReplicaSampling:
    def _plan_params(self):
        params = mnist_fc.init(jax.random.key(0), hidden=(128, 64))["params"]
        plan = compile_plan(params, DEFAULT_POLICY, "stoch", warn=False)
        return params, plan

    def test_same_seed_bit_identical(self):
        """Satellite: same seed -> bit-identical replica pytrees."""
        params, plan = self._plan_params()
        a = sample_replicas(params, plan, jax.random.key(5), 4)
        b = sample_replicas(params, plan, jax.random.key(5), 4)
        assert_trees_identical(a.base, b.base)
        assert_trees_identical(a.stacked, b.stacked)
        assert a.paths == b.paths and a.k == b.k == 4

    def test_replicas_differ(self):
        params, plan = self._plan_params()
        rs = sample_replicas(params, plan, jax.random.key(5), 4)
        assert rs.paths, "expected stochastic leaves in the smoke net"
        for r in range(1, 4):
            rep = rs.merge_replica(r)
            diffs = sum(
                int(not np.array_equal(la, lb))
                for la, lb in zip(_tree_arrays(rs.base), _tree_arrays(rep)))
            assert diffs > 0, f"replica {r} identical to replica 0"

    def test_replica0_equals_single_sample_pack(self):
        """Acceptance: replica 0 IS the existing single-sample stochastic
        pack — same key, same bits (replica_key(key, 0) == key)."""
        params, plan = self._plan_params()
        key = jax.random.key(11)
        rs = sample_replicas(params, plan, key, 3)
        assert_trees_identical(rs.base, plan.pack(params, key=key))
        assert jnp.array_equal(replica_key(key, 0), key)

    def test_validation(self):
        params, plan = self._plan_params()
        with pytest.raises(ValueError, match="k"):
            sample_replicas(params, plan, jax.random.key(0), 0)
        det = compile_plan(params, DEFAULT_POLICY, "det", warn=False)
        with pytest.raises(ValueError, match="stoch"):
            sample_replicas(params, det, jax.random.key(0), 2)

    def test_tree_nbytes_shares_base(self):
        """Byte accounting: K replicas cost base + (K-1) extra stochastic
        stacks, never K full copies (shared leaves stored once)."""
        params, plan = self._plan_params()
        b1 = sample_replicas(params, plan, jax.random.key(0), 1).tree_nbytes()
        b4 = sample_replicas(params, plan, jax.random.key(0), 4).tree_nbytes()
        assert b1 < b4 < 4 * b1


class TestEnsembleForward:
    def test_stats_shapes_and_k1_agreement(self):
        logits = jax.random.normal(jax.random.key(0), (4, 8, 10))
        es = ensemble_stats(logits)
        assert isinstance(es, EnsembleStats)
        assert es.mean_logits.shape == (8, 10)
        assert es.variance.shape == (8,) and es.agreement.shape == (8,)
        one = ensemble_stats(logits[:1])
        np.testing.assert_array_equal(np.asarray(one.agreement), 1.0)
        np.testing.assert_array_equal(np.asarray(one.variance), 0.0)
        np.testing.assert_array_equal(np.asarray(one.mean_logits),
                                      np.asarray(logits[0]))

    def test_k1_forward_bit_identical_to_plain(self):
        """Acceptance: ensemble_k=1 lowers to exactly the single-sample
        stochastic program — bit-identical logits."""
        tree = mnist_fc.init(jax.random.key(0), hidden=(128, 64))
        params, state = tree["params"], tree["state"]
        plan = compile_plan(params, DEFAULT_POLICY, "stoch", warn=False)
        key = jax.random.key(3)
        rs = sample_replicas(params, plan, key, 1)
        x = jax.random.normal(jax.random.key(4), (4, 784))

        def fwd(t):
            return mnist_fc.apply(t, state, x, training=False)[0]

        want = fwd(plan.pack(params, key=key))
        got = ensemble_forward(rs, fwd)
        np.testing.assert_array_equal(np.asarray(got.mean_logits),
                                      np.asarray(want))

    def test_vmapped_forward_averages_replicas(self):
        """K>1: mean_logits equals the per-replica forwards averaged by
        hand (via merge_replica), bit-tolerance only from the f32 mean."""
        tree = mnist_fc.init(jax.random.key(0), hidden=(128, 64))
        params, state = tree["params"], tree["state"]
        plan = compile_plan(params, DEFAULT_POLICY, "stoch", warn=False)
        rs = sample_replicas(params, plan, jax.random.key(3), 3)
        x = jax.random.normal(jax.random.key(4), (2, 784))

        def fwd(t):
            return mnist_fc.apply(t, state, x, training=False)[0]

        es = ensemble_forward(rs, fwd)
        per_rep = jnp.stack([fwd(rs.merge_replica(r)) for r in range(3)])
        np.testing.assert_allclose(
            np.asarray(es.mean_logits),
            np.asarray(jnp.mean(per_rep.astype(jnp.float32), axis=0)),
            rtol=1e-5, atol=1e-5)


class TestEnsembleServing:
    def _cfg_params(self):
        cfg = cb.get_config("starcoder2_3b", smoke=True)
        params = T.init_lm(cfg, jax.random.key(0))
        return cfg, params

    def _prompts(self, cfg, n=2, s=8):
        return jax.random.randint(jax.random.key(1), (n, s), 0,
                                  cfg.vocab_size)

    def test_k1_engine_bit_identical_to_stoch_packed(self):
        """Acceptance: serving with ensemble_k=1 is bit-identical (tokens
        AND logprobs) to the existing single-sample stochastic pack path."""
        cfg, params = self._cfg_params()
        plan = compile_plan(params, DEFAULT_POLICY, "stoch", warn=False)
        key = jax.random.key(7)
        plain = ServeEngine(cfg, plan.pack(params, key=key))
        rs = sample_replicas(params, plan, key, 1)
        ens = ServeEngine(cfg, None, ensemble=rs)
        prompts = self._prompts(cfg)
        a = plain.generate(prompts, max_new=6)
        b = ens.generate(prompts, max_new=6)
        np.testing.assert_array_equal(np.asarray(a.tokens),
                                      np.asarray(b.tokens))
        np.testing.assert_array_equal(np.asarray(a.logprobs),
                                      np.asarray(b.logprobs))

    def test_same_seed_same_ensemble_stream(self):
        """Satellite: same seed -> identical K=2 greedy streams; the result
        carries per-token uncertainty with valid ranges."""
        cfg, params = self._cfg_params()
        plan = compile_plan(params, DEFAULT_POLICY, "stoch", warn=False)
        prompts = self._prompts(cfg)
        outs = []
        for _ in range(2):
            rs = sample_replicas(params, plan, jax.random.key(2), 2)
            eng = ServeEngine(cfg, None, ensemble=rs,
                              abstain_threshold=2.0)  # everything abstains
            outs.append(eng.generate(prompts, max_new=4))
        a, b = outs
        np.testing.assert_array_equal(np.asarray(a.tokens),
                                      np.asarray(b.tokens))
        np.testing.assert_array_equal(np.asarray(a.vote_agreement),
                                      np.asarray(b.vote_agreement))
        agr = np.asarray(a.vote_agreement)
        assert a.tokens.shape == agr.shape == a.logit_variance.shape
        assert ((agr >= 0.0) & (agr <= 1.0)).all()
        assert (np.asarray(a.logit_variance) >= 0.0).all()
        assert np.asarray(a.abstained).all()     # threshold 2.0 > max 1.0

    def test_stream_serve_matches_generate(self):
        """The continuous-batching loop with resident K-replica caches
        emits the same greedy tokens as one-shot ensemble generate, and the
        per-request uncertainty lands on the Request ledger."""
        cfg, params = self._cfg_params()
        plan = compile_plan(params, DEFAULT_POLICY, "stoch", warn=False)
        rs = sample_replicas(params, plan, jax.random.key(2), 2)
        engine = ServeEngine(cfg, None, ensemble=rs, abstain_threshold=0.0)
        prompts = np.asarray(self._prompts(cfg, n=3))
        max_new = 4
        want = engine.generate(jnp.asarray(prompts), max_new=max_new)
        batcher = SlotBatcher(n_slots=2, prompt_len=prompts.shape[1])
        for p in prompts:
            batcher.submit(p, max_new)
        stream_serve(engine, batcher)
        done = sorted(batcher.completed, key=lambda r: r.uid)
        assert len(done) == 3
        for i, r in enumerate(done):
            assert r.generated == list(np.asarray(want.tokens)[i])
            assert len(r.agreement) == len(r.variance) == max_new
            assert not r.abstained       # threshold 0.0 never triggers
