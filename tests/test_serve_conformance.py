"""Serving conformance harness: greedy streams are bit-identical to
one-shot ``generate`` across the serving configuration cross-product.

The invariant every serving PR inherits: however a request's prompt gets
into its slot — whole-prompt ``prefill_into``, chunked prefill through the
fused ``decode_prefill`` step, or a prefix-cache splice (cold miss or
mid-stream hit) — and however the engine is built — {dense, det, xnor}
plan, single device or a forced 4-device ("data", "model") mesh, K=1
ensemble — the per-request greedy token streams must equal the one-shot
oracle exactly. The forced-mesh rows run in subprocesses (marked ``slow``;
CI runs them as their own step).
"""
import dataclasses
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import base as cb
from repro.core.policy import DEFAULT_POLICY
from repro.models import transformer as T
from repro.serve import PrefixCache, ServeEngine, SlotBatcher, stream_serve
from repro.serve.engine import pack_params

ARCH = "starcoder2_3b"
PROMPT_LEN = 8
MAX_NEWS = [3, 5, 2, 4, 3]
CAP = 5


@pytest.fixture(scope="module")
def engines():
    """One engine per plan mode, built lazily and shared across the
    matrix (engine construction dominates test wall-clock)."""
    cache = {}

    def get(plan_mode):
        if plan_mode not in cache:
            cfg = cb.get_config(ARCH, smoke=True)
            params = T.init_lm(cfg, jax.random.key(0))
            if plan_mode != "dense":
                params = pack_params(params, DEFAULT_POLICY, plan_mode)
            cache[plan_mode] = (cfg, ServeEngine(cfg, params))
        return cache[plan_mode]

    return get


def _prompts(cfg, shared_prefix=True):
    rng = np.random.default_rng(0)
    prompts = rng.integers(1, cfg.vocab_size,
                           size=(len(MAX_NEWS), PROMPT_LEN)).astype(np.int32)
    if shared_prefix:
        # request 3 repeats request 0's prompt: with a prefix cache it is
        # admitted MID-STREAM as a full-prompt hit (zero prefill chunks)
        prompts[3] = prompts[0]
    return prompts


def _oracle(engine, prompts, max_news=MAX_NEWS):
    return {i: np.asarray(engine.generate(jnp.asarray(p)[None],
                                          m).tokens)[0].tolist()
            for i, (p, m) in enumerate(zip(prompts, max_news))}


def _stream(engine, prompts, *, n_slots=2, max_news=MAX_NEWS,
            prompt_len=PROMPT_LEN, cap=CAP, **kw):
    b = SlotBatcher(n_slots, prompt_len)
    for p, m in zip(prompts, max_news):
        b.submit(p, m)
    stream_serve(engine, b, max_new_cap=cap, **kw)
    assert b.idle and len(b.completed) == len(max_news)
    return {r.uid: list(r.generated) for r in b.completed}


class TestSingleDeviceMatrix:
    @pytest.mark.parametrize("prefill", ["whole", "chunked"])
    @pytest.mark.parametrize("plan_mode", ["dense", "det", "xnor"])
    def test_stream_matches_generate(self, engines, plan_mode, prefill):
        """{dense, det, xnor} x {whole-prompt, chunked} without a prefix
        cache: streams through mid-stream slot refill == generate."""
        cfg, engine = engines(plan_mode)
        prompts = _prompts(cfg)
        want = _oracle(engine, prompts)
        kw = {"prefill_chunk": 3} if prefill == "chunked" else {}
        assert _stream(engine, prompts, **kw) == want

    @pytest.mark.parametrize("prefill", ["whole", "chunked"])
    @pytest.mark.parametrize("plan_mode", ["dense", "det", "xnor"])
    def test_prefix_cache_miss_then_hit(self, engines, plan_mode, prefill):
        """Cold pass (misses + ONE mid-stream full hit from the duplicate
        prompt), then a fully-warm pass where every admission is a prefix
        hit. Both passes bit-identical to generate."""
        cfg, engine = engines(plan_mode)
        prompts = _prompts(cfg)
        want = _oracle(engine, prompts)
        pc = PrefixCache()
        chunk = 3 if prefill == "chunked" else 0
        assert _stream(engine, prompts, prefill_chunk=chunk,
                       prefix_cache=pc) == want
        assert pc.hits >= 1, "mid-stream duplicate-prompt hit missing"
        cold_hits = pc.hits
        assert _stream(engine, prompts, prefill_chunk=chunk,
                       prefix_cache=pc) == want
        assert pc.hits >= cold_hits + len(MAX_NEWS)
        assert pc.evictions == 0


class TestFamilyConformance:
    @pytest.mark.parametrize("arch", ["mamba2_130m", "jamba_1_5_large",
                                      "h2o_danube_3_4b"])
    def test_chunked_prefix_stream_per_family(self, arch):
        """Chunked prefill + prefix reuse across the non-uniform cache
        families (ssm / hybrid / sliding-window): a partially-prefilled
        slot is a first-class cache state for each of them."""
        cfg = cb.get_config(arch, smoke=True)
        params = T.init_lm(cfg, jax.random.key(0))
        engine = ServeEngine(cfg, params)
        prompts = _prompts(cfg)
        want = _oracle(engine, prompts)
        pc = PrefixCache()
        assert _stream(engine, prompts, prefill_chunk=3,
                       prefix_cache=pc) == want
        assert pc.hits >= 1

    def test_sliding_window_ring_wrap(self):
        """Chunk boundaries crossing the ring-buffer wrap: window 6 with a
        12-token prompt makes the chunked writes wrap mid-prefill, so the
        age-based cache masks and the post-attention ring write are
        exercised on both sides of the seam."""
        cfg = dataclasses.replace(cb.get_config("h2o_danube_3_4b",
                                                smoke=True),
                                  sliding_window=6)
        params = T.init_lm(cfg, jax.random.key(0))
        engine = ServeEngine(cfg, params)
        rng = np.random.default_rng(1)
        prompts = rng.integers(1, cfg.vocab_size, size=(3, 12)).astype(
            np.int32)
        max_news = [3, 4, 2]
        want = _oracle(engine, prompts, max_news)
        got = _stream(engine, prompts, max_news=max_news, prompt_len=12,
                      cap=4, prefill_chunk=5)
        assert got == want


class TestEnsembleConformance:
    def test_k1_ensemble_chunked_prefix_stream(self):
        """K=1 ensemble serving degrades to the single-sample path, so
        chunked prefill + prefix reuse must hold there too."""
        from repro.engine import compile_plan
        from repro.stoch import sample_replicas

        cfg = cb.get_config(ARCH, smoke=True)
        params = T.init_lm(cfg, jax.random.key(0))
        plan = compile_plan(params, DEFAULT_POLICY, "stoch", warn=False)
        rs = sample_replicas(params, plan, jax.random.key(7), 1)
        engine = ServeEngine(cfg, None, ensemble=rs)
        prompts = _prompts(cfg)
        want = _oracle(engine, prompts)
        pc = PrefixCache()
        assert _stream(engine, prompts, prefill_chunk=3,
                       prefix_cache=pc) == want
        assert pc.hits >= 1

    def test_k2_ensemble_rejects_chunked_prefill(self):
        """K>=2 replica serving prefills whole prompts; asking for chunked
        prefill must fail loudly, not silently fall back."""
        from repro.engine import compile_plan
        from repro.stoch import sample_replicas

        cfg = cb.get_config(ARCH, smoke=True)
        params = T.init_lm(cfg, jax.random.key(0))
        plan = compile_plan(params, DEFAULT_POLICY, "stoch", warn=False)
        rs = sample_replicas(params, plan, jax.random.key(7), 2)
        engine = ServeEngine(cfg, None, ensemble=rs)
        b = SlotBatcher(2, PROMPT_LEN)
        b.submit(np.arange(PROMPT_LEN), 2)
        with pytest.raises(NotImplementedError, match="single-sample"):
            stream_serve(engine, b, prefill_chunk=3)


def _run(code: str, timeout=560):
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         cwd="/root/repo", capture_output=True, text=True,
                         timeout=timeout)
    assert out.returncode == 0, (out.stdout[-500:], out.stderr[-2000:])
    return out.stdout


@pytest.mark.slow
class TestForcedMeshMatrix:
    """Forced 4-device CPU mesh rows of the matrix (subprocess so the main
    test process stays single-device)."""

    @pytest.mark.parametrize("mode", ["det", "xnor"])
    def test_sharded_chunked_prefix_stream(self, mode):
        out = _run(f"""
            import os
            os.environ["JAX_PLATFORMS"] = "cpu"
            os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
            import sys
            sys.path.insert(0, "src")
            import numpy as np
            import jax, jax.numpy as jnp
            from repro.configs import base as cb
            from repro.core.policy import DEFAULT_POLICY
            from repro.engine import compile_plan
            from repro.models import transformer as T
            from repro.serve import (PrefixCache, ServeEngine, SlotBatcher,
                                     stream_serve)

            cfg = cb.get_config("{ARCH}", smoke=True)
            params = T.init_lm(cfg, jax.random.key(0))
            plan = compile_plan(params, DEFAULT_POLICY, "{mode}", warn=False)
            packed = plan.pack(params)
            oracle_eng = ServeEngine(cfg, packed)
            mesh = jax.make_mesh((2, 2), ("data", "model"))
            eng = ServeEngine(cfg, packed, mesh=mesh, plan=plan)

            rng = np.random.default_rng(0)
            prompts = rng.integers(1, cfg.vocab_size,
                                   size=(5, 8)).astype(np.int32)
            # request 4 queues behind the 4 slots, so by its admission
            # prompt 0's full snapshot exists: a mid-stream prefix hit
            # (request 3 would be admitted in the SAME refill as 0)
            prompts[4] = prompts[0]
            max_news = [3, 5, 2, 4, 3]
            want = {{i: np.asarray(oracle_eng.generate(
                        jnp.asarray(p)[None], m).tokens)[0].tolist()
                    for i, (p, m) in enumerate(zip(prompts, max_news))}}
            pc = PrefixCache()
            b = SlotBatcher(4, 8)
            for p, m in zip(prompts, max_news):
                b.submit(p, m)
            stream_serve(eng, b, max_new_cap=5, prefill_chunk=3,
                         prefix_cache=pc)
            got = {{r.uid: list(r.generated) for r in b.completed}}
            assert got == want, (got, want)
            assert pc.hits >= 1
            print("MESH_OK")
        """)
        assert "MESH_OK" in out

    def test_sharded_whole_prompt_stream_dense(self):
        """Dense plan on the forced mesh, whole-prompt path: the matrix's
        {single-device vs mesh} axis is covered for the legacy admission
        path too."""
        out = _run("""
            import os
            os.environ["JAX_PLATFORMS"] = "cpu"
            os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
            import sys
            sys.path.insert(0, "src")
            import numpy as np
            import jax, jax.numpy as jnp
            from repro.configs import base as cb
            from repro.models import transformer as T
            from repro.serve import ServeEngine, SlotBatcher, stream_serve

            cfg = cb.get_config("starcoder2_3b", smoke=True)
            params = T.init_lm(cfg, jax.random.key(0))
            oracle_eng = ServeEngine(cfg, params)
            mesh = jax.make_mesh((2, 2), ("data", "model"))
            eng = ServeEngine(cfg, params, mesh=mesh)

            rng = np.random.default_rng(0)
            prompts = rng.integers(1, cfg.vocab_size,
                                   size=(5, 8)).astype(np.int32)
            max_news = [3, 5, 2, 4, 3]
            want = {i: np.asarray(oracle_eng.generate(
                        jnp.asarray(p)[None], m).tokens)[0].tolist()
                    for i, (p, m) in enumerate(zip(prompts, max_news))}
            b = SlotBatcher(4, 8)
            for p, m in zip(prompts, max_news):
                b.submit(p, m)
            stream_serve(eng, b, max_new_cap=5)
            got = {r.uid: list(r.generated) for r in b.completed}
            assert got == want, (got, want)
            print("MESH_OK")
        """)
        assert "MESH_OK" in out
