"""Model correctness: per-arch smoke tests + component oracles."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import base as cb
from repro.models import attention as A
from repro.models import ssm as S
from repro.models import transformer as T

LM_ARCHS = [a for a in cb.ARCH_IDS if a not in ("mnist_fc", "vgg16_cifar10")]


def _toks(cfg, b, s, key=1):
    if cfg.frontend:
        return (jax.random.normal(jax.random.key(key), (b, s, cfg.d_model))
                * 0.02).astype(jnp.float32)
    return jax.random.randint(jax.random.key(key), (b, s), 0, cfg.vocab_size)


@pytest.mark.parametrize("arch", LM_ARCHS)
class TestArchSmoke:
    """Reduced-config smoke: one forward + one train step, shapes + no NaNs."""

    def test_forward_shapes_no_nan(self, arch):
        cfg = cb.get_config(arch, smoke=True)
        params = T.init_lm(cfg, jax.random.key(0))
        b, s = 2, 32
        logits, aux = T.forward(cfg, params, _toks(cfg, b, s))
        assert logits.shape == (b, s, cfg.vocab_size)
        assert not np.isnan(np.asarray(logits, np.float32)).any()

    def test_train_step_no_nan(self, arch):
        from repro.core.policy import DEFAULT_POLICY
        from repro.optim import schedules
        from repro.optim.sgd import sgd_momentum
        from repro.train import steps as ST

        cfg = cb.get_config(arch, smoke=True)
        params = T.init_lm(cfg, jax.random.key(0))
        opt = sgd_momentum(schedules.constant(1e-2))
        step = ST.make_train_step(ST.make_lm_loss(cfg), opt, "det",
                                  DEFAULT_POLICY)
        state = ST.init_train_state(params, opt)
        if cfg.frontend:
            batch = {"tokens": _toks(cfg, 2, 16),
                     "labels": jax.random.randint(jax.random.key(3), (2, 16),
                                                  0, cfg.vocab_size)}
        else:
            batch = {"tokens": _toks(cfg, 2, 17)}
        state, metrics = jax.jit(step)(state, batch)
        assert np.isfinite(float(metrics["loss"]))
        assert int(state["step"]) == 1
        # masters stayed clipped (Alg. 1 step 4)
        from repro.core.binarize import _path_str
        for p, leaf in jax.tree_util.tree_leaves_with_path(state["params"]):
            from repro.core.policy import DEFAULT_POLICY as POL
            if POL.selects(_path_str(p)):
                assert float(jnp.abs(leaf).max()) <= 1.0 + 1e-6

    def test_prefill_decode_consistency(self, arch):
        cfg = cb.get_config(arch, smoke=True)
        params = T.init_lm(cfg, jax.random.key(0))
        b, s = 2, 32
        toks = _toks(cfg, b, s)
        logits, _ = T.forward(cfg, params, toks)
        lp, cache = T.prefill(cfg, params, toks[:, : s - 1], max_len=s)
        np.testing.assert_allclose(
            np.asarray(lp, np.float32),
            np.asarray(logits[:, s - 2], np.float32), rtol=5e-2, atol=5e-3)
        ld, cache = T.decode_step(cfg, params, cache, toks[:, s - 1: s])
        np.testing.assert_allclose(
            np.asarray(ld, np.float32),
            np.asarray(logits[:, s - 1], np.float32), rtol=5e-2, atol=5e-3)


class TestAttention:
    def test_gqa_equals_mha_when_kv_equals_heads(self):
        cfg = cb.get_config("musicgen_large", smoke=True)  # kv == heads
        assert cfg.n_kv_heads == cfg.n_heads

    def test_flash_matches_dense(self):
        b, s, h, hd = 2, 512, 4, 32
        q, k, v = (jax.random.normal(kk, (b, s, h, hd))
                   for kk in jax.random.split(jax.random.key(0), 3))
        fl = A.flash_attention(q, k, v, window=None, chunk_q=128, chunk_k=128)
        logits = jnp.einsum("bqhd,bkhd->bhqk", q, k) * hd ** -0.5
        logits = jnp.where(A.causal_mask(s, s, None)[None, None],
                           logits, A.NEG_INF)
        dense = jnp.einsum("bhqk,bkhd->bqhd", jax.nn.softmax(logits, -1), v)
        np.testing.assert_allclose(np.asarray(fl), np.asarray(dense),
                                   atol=1e-4)

    def test_flash_matches_dense_sliding_window(self):
        b, s, h, hd = 1, 256, 2, 16
        q, k, v = (jax.random.normal(kk, (b, s, h, hd))
                   for kk in jax.random.split(jax.random.key(1), 3))
        fl = A.flash_attention(q, k, v, window=64, chunk_q=64, chunk_k=64)
        logits = jnp.einsum("bqhd,bkhd->bhqk", q, k) * hd ** -0.5
        logits = jnp.where(A.causal_mask(s, s, 64)[None, None],
                           logits, A.NEG_INF)
        dense = jnp.einsum("bhqk,bkhd->bqhd", jax.nn.softmax(logits, -1), v)
        np.testing.assert_allclose(np.asarray(fl), np.asarray(dense),
                                   atol=1e-4)

    def test_swa_ring_decode_long(self):
        cfg = dataclasses.replace(cb.get_config("h2o_danube_3_4b", smoke=True),
                                  sliding_window=16)
        params = T.init_lm(cfg, jax.random.key(0))
        toks = jax.random.randint(jax.random.key(1), (1, 48), 0, cfg.vocab_size)
        logits, _ = T.forward(cfg, params, toks)
        lp, cache = T.prefill(cfg, params, toks[:, :24], max_len=48)
        errs = [float(np.abs(np.asarray(lp) - np.asarray(logits[:, 23])).max())]
        for t in range(24, 48):
            ld, cache = T.decode_step(cfg, params, cache, toks[:, t: t + 1])
            errs.append(float(
                np.abs(np.asarray(ld) - np.asarray(logits[:, t])).max()))
        assert max(errs) < 5e-4, errs

    def test_cache_length(self):
        cfg = cb.get_config("h2o_danube_3_4b")
        assert A.cache_length(cfg, 524288) == 4096  # ring buffer = window
        cfg2 = cb.get_config("qwen2_5_32b")
        assert A.cache_length(cfg2, 32768) == 32768


class TestSSM:
    def test_ssd_chunked_matches_recurrence(self):
        b, s, h, p, n = 2, 64, 3, 8, 16
        ks = jax.random.split(jax.random.key(0), 5)
        x = jax.random.normal(ks[0], (b, s, h, p))
        dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
        a = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.5)
        bm = jax.random.normal(ks[3], (b, s, n)) * 0.3
        cm = jax.random.normal(ks[4], (b, s, n)) * 0.3
        y_ref, st_ref = S.ssd_reference(x, dt, a, bm, cm)
        for chunk in (8, 32, 64):
            y, stf = S.ssd_chunked(x, dt, a, bm, cm, chunk)
            np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                       rtol=1e-3, atol=1e-4)
            np.testing.assert_allclose(np.asarray(stf), np.asarray(st_ref),
                                       rtol=1e-3, atol=1e-4)

    def test_decode_step_matches_forward(self):
        cfg = cb.get_config("mamba2_130m", smoke=True)
        params = T.init_lm(cfg, jax.random.key(0))
        toks = jax.random.randint(jax.random.key(1), (1, 33), 0, cfg.vocab_size)
        logits, _ = T.forward(cfg, params, toks)
        lp, cache = T.prefill(cfg, params, toks[:, :16], max_len=33)
        for t in range(16, 33):
            ld, cache = T.decode_step(cfg, params, cache, toks[:, t: t + 1])
            np.testing.assert_allclose(
                np.asarray(ld, np.float32),
                np.asarray(logits[:, t], np.float32), rtol=5e-2, atol=5e-3)


class TestMoE:
    def test_routing_mass_conservation(self):
        """With ample capacity, combine weights sum to 1 per token."""
        from repro.models import moe as MOE

        cfg = cb.get_config("moonshot_v1_16b_a3b", smoke=True)
        params = MOE.init_moe(jax.random.key(0), cfg,
                              lambda k, s, fan_in=None: 0.05 * jax.random.normal(k, s))
        x = jax.random.normal(jax.random.key(1), (2, 16, cfg.d_model))
        y, aux = MOE.moe_ffn(cfg, params, x)
        assert y.shape == x.shape
        assert float(aux["dropped_frac"]) == 0.0
        assert np.isfinite(float(aux["lb_loss"]))

    def test_capacity_drops(self):
        from repro.models import moe as MOE

        cfg = dataclasses.replace(cb.get_config("moonshot_v1_16b_a3b", smoke=True),
                                  capacity_factor=0.05)
        params = MOE.init_moe(jax.random.key(0), cfg,
                              lambda k, s, fan_in=None: 0.05 * jax.random.normal(k, s))
        x = jax.random.normal(jax.random.key(1), (4, 64, cfg.d_model))
        _, aux = MOE.moe_ffn(cfg, params, x)
        assert float(aux["dropped_frac"]) > 0.0

    def test_moe_flops_are_active_only(self):
        """The (E, C, ...) buffer bounds compute at tokens*topk, not E."""
        from repro.models import moe as MOE

        cfg = cb.get_config("moonshot_v1_16b_a3b", smoke=True)
        cap = MOE.capacity(cfg, 1024)
        assert cap * cfg.n_experts <= int(
            1024 * cfg.experts_per_token * cfg.capacity_factor) + 8 * cfg.n_experts


class TestParamCount:
    @pytest.mark.parametrize("arch,approx_b", [
        ("starcoder2_3b", 3.0), ("qwen2_5_32b", 32.5), ("deepseek_coder_33b", 33.0),
        ("grok_1_314b", 314.0), ("mamba2_130m", 0.13), ("internvl2_76b", 76.0),
    ])
    def test_full_config_param_count(self, arch, approx_b):
        n = cb.get_config(arch).param_count()
        assert abs(n / 1e9 - approx_b) / approx_b < 0.35, n / 1e9

    @pytest.mark.parametrize("arch", LM_ARCHS)
    def test_param_count_matches_init_on_smoke(self, arch):
        cfg = cb.get_config(arch, smoke=True)
        params = T.init_lm(cfg, jax.random.key(0))
        actual = sum(x.size for x in jax.tree.leaves(params))
        predicted = cfg.param_count()
        assert abs(actual - predicted) / actual < 0.05, (actual, predicted)
