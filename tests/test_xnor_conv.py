"""XNOR conv engine: exact integer parity sweeps + VGG integration.

Three-way parity (no tolerance — binary convolutions are exact integers):
Pallas patch kernel + popcount GEMM == jnp popcount oracle == dense
zero-padded sign-conv (``lax.conv(sign(x), sign(w))``), across stride 1/2,
SAME/VALID, ragged spatial dims, and kh*kw*C not a multiple of 32. SAME
cases exercise the border correction: without it, every border pixel would
be off by sum(sign(w)) over its out-of-bounds taps.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.xnor.conv import (conv_geometry, pack_conv_kernel,
                             patch_nbytes_dense, patch_nbytes_packed,
                             sign_and_pack_patches, xnor_conv2d)
from repro.xnor.conv import ref as cref
from repro.xnor.conv.kernel import patch_pack_pallas
from repro.xnor.conv.packing import padding_mask

# (b, h, w, c, n, kh, kw, sh, sw, padding): aligned K (C=32 -> K=288),
# stride 2, ragged spatial + K=144 (not %32), first-conv-like C=3 (K=27),
# VALID stride 2, 1x1 pointwise, asymmetric kernel+stride.
CONV_CASES = [
    (2, 8, 8, 32, 64, 3, 3, 1, 1, "SAME"),
    (2, 8, 8, 32, 48, 3, 3, 2, 2, "SAME"),
    (1, 9, 7, 16, 32, 3, 3, 1, 1, "SAME"),
    (2, 8, 8, 3, 16, 3, 3, 1, 1, "SAME"),
    (1, 7, 7, 8, 8, 3, 3, 2, 2, "VALID"),
    (2, 6, 6, 32, 32, 1, 1, 1, 1, "VALID"),
    (1, 10, 6, 24, 40, 5, 3, 2, 1, "SAME"),
]


def _operands(b, h, w, c, n, kh, kw, seed=0):
    kx, kwt = jax.random.split(jax.random.key(seed + b * h * w + c * n))
    x = jax.random.normal(kx, (b, h, w, c), jnp.float32)
    wk = jax.random.normal(kwt, (kh, kw, c, n), jnp.float32)
    return x, wk, pack_conv_kernel(wk)


class TestPatchPacking:
    @pytest.mark.parametrize("b,h,w,c,n,kh,kw,sh,sw,pad", CONV_CASES)
    def test_pallas_matches_ref(self, b, h, w, c, n, kh, kw, sh, sw, pad):
        x, _, _ = _operands(b, h, w, c, n, kh, kw)
        got = sign_and_pack_patches(x, ksize=(kh, kw), stride=(sh, sw),
                                    padding=pad)
        want = cref.sign_pack_patches_ref(x, (kh, kw), (sh, sw), pad)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_pallas_direct(self):
        x = jax.random.normal(jax.random.key(1), (2, 8, 8, 32))
        xp = jnp.pad(x, ((0, 0), (1, 1), (1, 1), (0, 0)))
        got = patch_pack_pallas(xp, ksize=(3, 3), oh=8, ow=8, interpret=True)
        want = cref.sign_pack_patches_ref(x, (3, 3), (1, 1), "SAME")
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_patch_values_roundtrip(self):
        """Dense patches of a ±1 image survive the pack exactly (borders and
        channel pad read back as -1, i.e. bit 0)."""
        from repro.xnor.packing import unpack_activations

        x = jnp.where(jax.random.bernoulli(jax.random.key(2), 0.5,
                                           (1, 5, 5, 3)), 1.0, -1.0)
        packed = sign_and_pack_patches(x, ksize=(3, 3))
        dense = cref.conv_patches_ref(x, (3, 3))  # zero-filled borders
        unpacked = unpack_activations(packed)     # (1, 5, 5, 9*32)
        # per-tap layout: tap t occupies [t*32, t*32+3) of the unpacked axis
        for t in range(9):
            np.testing.assert_array_equal(
                np.asarray(unpacked[..., t * 32:t * 32 + 3]),
                np.asarray(jnp.where(dense[..., t * 3:(t + 1) * 3] > 0,
                                     1.0, -1.0)))


class TestXnorConvParity:
    """The acceptance sweep: kernel == oracle == dense sign-conv, exactly."""

    @pytest.mark.parametrize("b,h,w,c,n,kh,kw,sh,sw,pad", CONV_CASES)
    def test_three_way_exact(self, b, h, w, c, n, kh, kw, sh, sw, pad):
        x, wk, wp = _operands(b, h, w, c, n, kh, kw)
        dense = np.asarray(
            cref.sign_conv_ref(x, wk, (sh, sw), pad)).astype(np.int32)
        oracle = np.asarray(cref.xnor_conv2d_ref(
            x, wp, ksize=(kh, kw), c_in=c, stride=(sh, sw), padding=pad))
        kernel = np.asarray(xnor_conv2d(
            x, wp, ksize=(kh, kw), c_in=c, stride=(sh, sw), padding=pad))
        np.testing.assert_array_equal(oracle, dense)
        np.testing.assert_array_equal(kernel, dense)

    def test_border_correction_is_load_bearing(self):
        """An all-positive kernel makes the uncorrected border error maximal:
        every padded tap would contribute -C instead of 0."""
        x = jnp.ones((1, 4, 4, 8))
        wk = jnp.ones((3, 3, 8, 4))
        wp = pack_conv_kernel(wk)
        got = np.asarray(xnor_conv2d(x, wp, ksize=(3, 3), c_in=8))
        want = np.asarray(cref.sign_conv_ref(x, wk)).astype(np.int32)
        np.testing.assert_array_equal(got, want)
        # corner pixel sees 4 valid taps * 8 channels = 32, center 9*8 = 72
        assert got[0, 0, 0, 0] == 32 and got[0, 1, 1, 0] == 72

    def test_scaled(self):
        b, h, w, c, n = 2, 6, 6, 16, 24
        x, wk, wp = _operands(b, h, w, c, n, 3, 3, seed=7)
        s = jax.random.uniform(jax.random.key(9), (n,), minval=0.5, maxval=2.0)
        got = np.asarray(xnor_conv2d(x, wp, s, ksize=(3, 3), c_in=c))
        want = (np.asarray(cref.sign_conv_ref(x, wk))
                * np.asarray(s)[None, None, None, :])
        np.testing.assert_allclose(got, want, rtol=1e-6)

    def test_geometry_same_matches_lax(self):
        """conv_geometry reproduces XLA SAME semantics (incl. odd sizes)."""
        for h, w, sh, sw in [(7, 5, 2, 2), (8, 8, 1, 1), (9, 4, 3, 2)]:
            oh, ow, pads = conv_geometry(h, w, (3, 3), (sh, sw), "SAME")
            out = jax.lax.conv_general_dilated(
                jnp.ones((1, h, w, 2)), jnp.ones((3, 3, 2, 1)),
                window_strides=(sh, sw), padding="SAME",
                dimension_numbers=("NHWC", "HWIO", "NHWC"))
            assert out.shape[1:3] == (oh, ow)

    def test_padding_mask_counts(self):
        """3x3 SAME on 4x4: corners lose 5 taps, edges 3, interior 0."""
        m = padding_mask(4, 4, (3, 3), (1, 1), "SAME").reshape(4, 4, 9)
        assert m.sum(-1)[0, 0] == 5 and m.sum(-1)[0, 1] == 3
        assert m.sum(-1)[1, 1] == 0


class TestVggIntegration:
    def test_pack_params_xnor_conv_blocks(self):
        """mode="xnor" turns conv blocks 2-5 into XnorConv; block 1 (the
        raw-pixel boundary) and the head split stay as before."""
        from repro.core.policy import DEFAULT_POLICY
        from repro.models import vgg
        from repro.models.layers import PackedLinear, XnorConv, XnorLinear
        from repro.serve.engine import pack_params

        tree = vgg.init(jax.random.key(0), width_mult=0.125)
        packed = pack_params(tree["params"], DEFAULT_POLICY, "xnor")
        kinds = [type(lp["kernel"]) for lp in packed["conv"]]
        assert kinds[0] is not XnorConv and kinds[1] is not XnorConv
        assert all(k is XnorConv for k in kinds[2:])
        assert isinstance(packed["fc"][0]["kernel"], PackedLinear)
        assert isinstance(packed["fc"][1]["kernel"], XnorLinear)
        x = jax.random.normal(jax.random.key(2), (2, 32, 32, 3))
        logits, _ = vgg.apply(packed, tree["state"], x, training=False,
                              binary_act=True)
        assert logits.shape == (2, 10)
        assert np.isfinite(np.asarray(logits)).all()

    def test_nonxnor_modes_binarize_conv_densely(self):
        """No packed-weight MXU conv path: under det packing a selected conv
        kernel keeps its dense array form but carries the Alg.-1 binarized
        values, so serving runs the network training optimized."""
        from repro.core.policy import DEFAULT_POLICY
        from repro.models import vgg
        from repro.serve.engine import pack_params

        tree = vgg.init(jax.random.key(0), width_mult=0.125)
        packed = pack_params(tree["params"], DEFAULT_POLICY, "det",
                             with_scale=False)
        for lp in packed["conv"]:
            assert isinstance(lp["kernel"], jax.Array)
            assert set(np.unique(np.asarray(lp["kernel"]))) <= {-1.0, 1.0}
        # xnor mode: the xnor-excluded block-1 kernels also serve binarized
        packed = pack_params(tree["params"], DEFAULT_POLICY, "xnor",
                             with_scale=False)
        for lp in packed["conv"][:2]:
            assert set(np.unique(np.asarray(lp["kernel"]))) <= {-1.0, 1.0}

    def test_xnor_conv_layer_exact(self):
        """apply_conv2d on an XnorConv == scale * sign-conv, exactly."""
        from repro.models.layers import XnorConv, apply_conv2d

        c, n = 16, 8
        x = jax.random.normal(jax.random.key(3), (2, 6, 6, c))
        wk = jax.random.normal(jax.random.key(4), (3, 3, c, n))
        s = jnp.mean(jnp.abs(wk), axis=(0, 1, 2))
        leaf = XnorConv(pack_conv_kernel(wk), s, (3, 3), c)
        got = np.asarray(apply_conv2d(leaf, x))
        want = (np.asarray(cref.sign_conv_ref(x, wk))
                * np.asarray(s)[None, None, None, :])
        np.testing.assert_allclose(got, want, rtol=1e-5)
        assert leaf.k == 9 * c and leaf.shape == (3, 3, c, n)

    def test_xnor_policy_conv_boundary(self):
        from repro.core.policy import DEFAULT_POLICY, XNOR_POLICY

        for i in (0, 1):
            assert DEFAULT_POLICY.selects(f"conv/{i}/kernel")
            assert not XNOR_POLICY.selects(f"conv/{i}/kernel")
        for i in (2, 5, 12):
            assert XNOR_POLICY.selects(f"conv/{i}/kernel")
        # SSM depthwise-conv leaves stay excluded everywhere
        assert not DEFAULT_POLICY.selects("layers/conv")

    def test_byte_accounting(self):
        # C % 32 == 0 -> exactly 16x vs bf16 patches (the paper's claim)
        dense = patch_nbytes_dense(8, 16, 16, (3, 3), 128)
        packed = patch_nbytes_packed(8, 16, 16, (3, 3), 128)
        assert dense / packed == 16.0
