"""Static verifier (repro.analysis): clean passes on the shipped golden
manifests, a red test per lint rule (deliberately broken plan / HLO /
engine, rule id asserted), the retrace sentinel unit + live behavior, and
the CLI gate.

Multi-device pieces run in subprocesses with forced host devices
(mirroring tests/test_obs_collectives.py)."""
import copy
import glob
import json
import os
import subprocess
import sys
import textwrap

import pytest

from repro.analysis import (ERROR, INFO, Finding, RetraceError,
                            RetraceSentinel, errors, findings_to_json,
                            format_findings, gate, lint_cache_donation,
                            lint_collective_budget, lint_f32_upcast,
                            lint_hlo, lint_host_transfer, lint_plan, waive)
from repro.engine import ExecutionPlan

GOLDEN_DIR = os.path.join(os.path.dirname(__file__), os.pardir,
                          "benchmarks", "golden_plans")


def golden_plan_files():
    out = []
    for path in sorted(glob.glob(os.path.join(GOLDEN_DIR, "*.json"))):
        with open(path) as f:
            if "layers" in json.load(f):
                out.append(path)
    return out


def rules_of(findings):
    return {f.rule for f in findings}


# ---------------------------------------------------------------------------
# findings plumbing
# ---------------------------------------------------------------------------

class TestFindings:
    def test_round_trip_and_gate(self):
        f = Finding(rule="plan.dense_fallthrough", severity=ERROR,
                    where="fc/0/kernel", message="m", hint="h",
                    data={"k": 30})
        g = Finding.from_json(json.loads(json.dumps(f.to_json())))
        assert g == f
        info = Finding(rule="plan.boundary_reshard", severity=INFO,
                       where="x", message="m")
        assert gate([f, info]) == 1 and gate([info]) == 0
        assert errors([f, info]) == [f]

    def test_waive_drops_by_rule_id(self):
        f = Finding(rule="hlo.f32_upcast", severity=ERROR, where="e",
                    message="m")
        assert waive([f], ["hlo.f32_upcast"]) == []
        assert waive([f], ["other.rule"]) == [f]
        assert gate(waive([f], ["hlo.f32_upcast"])) == 0

    def test_invalid_severity_rejected(self):
        with pytest.raises(ValueError, match="severity"):
            Finding(rule="r", severity="fatal", where="w", message="m")

    def test_format_orders_errors_first(self):
        out = format_findings([
            Finding(rule="b.info", severity=INFO, where="w", message="m"),
            Finding(rule="a.err", severity=ERROR, where="w", message="m",
                    hint="do the thing"),
        ], title="t")
        assert out.index("a.err") < out.index("b.info")
        assert "fix: do the thing" in out
        assert "1 error(s)" in out
        assert "no findings" in format_findings([], title="t")


# ---------------------------------------------------------------------------
# plan lints: clean pass on every shipped golden, red test per rule
# ---------------------------------------------------------------------------

class TestPlanLintsClean:
    @pytest.mark.parametrize("path", golden_plan_files(),
                             ids=lambda p: os.path.basename(p))
    def test_golden_manifests_have_no_errors(self, path):
        plan = ExecutionPlan.load(path)
        findings = lint_plan(plan)
        assert errors(findings) == [], findings_to_json(errors(findings))

    def test_boundary_reshard_is_informational_on_goldens(self):
        """The packed->dense boundary at the paper nets' final dense
        layer is real and expected: reported, but never gating."""
        plan = ExecutionPlan.load(
            os.path.join(GOLDEN_DIR, "mnist_fc_det.json"))
        findings = lint_plan(plan)
        hits = [f for f in findings if f.rule == "plan.boundary_reshard"]
        assert hits and all(f.severity == INFO for f in hits)
        assert gate(findings) == 0


class TestPlanLintsRed:
    @pytest.fixture()
    def det_plan(self):
        return ExecutionPlan.load(
            os.path.join(GOLDEN_DIR, "mnist_fc_det.json"))

    @pytest.fixture()
    def stoch_plan(self):
        return ExecutionPlan.load(
            os.path.join(GOLDEN_DIR, "mnist_fc_stoch.json"))

    def _packed_row(self, plan):
        rows = [a for a in plan.layers if a.backend == "packed"]
        assert rows
        return rows[0]

    def test_dense_fallthrough_fires(self, det_plan):
        plan = copy.deepcopy(det_plan)
        row = self._packed_row(plan)
        row.backend = "dense"
        row.reason = "cannot pack: K % 32 != 0 (K=30)"
        findings = lint_plan(plan)
        hits = [f for f in findings if f.rule == "plan.dense_fallthrough"]
        assert len(hits) == 1 and hits[0].severity == ERROR
        assert hits[0].where == row.path
        assert gate(findings) == 1

    def test_fallthrough_fires_from_a_real_compile(self):
        """End-to-end: a policy-selected K % 32 != 0 layer compiles to a
        dense fallthrough that the lint gates on."""
        import jax

        from repro.core.policy import DEFAULT_POLICY
        from repro.engine import compile_plan
        from repro.models import mnist_fc

        tree = mnist_fc.init(jax.random.key(0), hidden=(30, 64))
        plan = compile_plan(tree["params"], DEFAULT_POLICY, "det",
                            warn=False)
        hits = [f for f in lint_plan(plan)
                if f.rule == "plan.dense_fallthrough"]
        assert hits, "hidden=30 must fall through and be linted"

    def test_word_lane_split_fires_on_contraction_shard(self, det_plan):
        """'packed' declares no tp_contract_dim: model on the K dim is a
        word-lane / accumulation-order bug."""
        plan = copy.deepcopy(det_plan)
        row = self._packed_row(plan)
        row.sharding = ["model", None]
        hits = [f for f in lint_plan(plan)
                if f.rule == "plan.word_lane_split"]
        assert len(hits) == 1 and hits[0].where == row.path
        assert "accumulation order" in hits[0].message

    def test_word_lane_split_fires_on_uneven_word_split(self):
        """xnor may shard K (tp_contract_dim) — but only whole int32
        words per device."""
        plan = ExecutionPlan.load(
            os.path.join(GOLDEN_DIR, "mnist_fc_xnor.json"))
        plan = copy.deepcopy(plan)
        row = [a for a in plan.layers if a.backend == "xnor"][0]
        row.sharding = ["model", None]
        k = row.shape[-2]
        assert k % 32 == 0
        # k/32 words over 3 devices cannot split evenly
        uneven = {"model": 3} if (k // 32) % 3 else {"model": (k // 32) + 1}
        hits = [f for f in lint_plan(plan, axis_sizes=uneven)
                if f.rule == "plan.word_lane_split"]
        assert len(hits) == 1 and "whole" in hits[0].message
        # an even split of whole words is legal
        assert not [f for f in lint_plan(plan, axis_sizes={"model": 2})
                    if f.rule == "plan.word_lane_split"]

    def test_word_lane_split_fires_on_conv_folded_dims(self):
        plan = copy.deepcopy(ExecutionPlan.load(
            os.path.join(GOLDEN_DIR, "vgg16_cifar10_xnor.json")))
        row = [a for a in plan.layers if a.backend == "xnor_conv"][0]
        row.sharding = [None, None, "model", None]   # sharded C: folded
        hits = [f for f in lint_plan(plan)
                if f.rule == "plan.word_lane_split"]
        assert len(hits) == 1 and hits[0].where == row.path

    def test_unknown_axis_fires(self, det_plan):
        plan = copy.deepcopy(det_plan)
        row = self._packed_row(plan)
        row.sharding = [None, "modle"]               # typo
        hits = [f for f in lint_plan(plan) if f.rule == "plan.unknown_axis"]
        assert len(hits) == 1 and "modle" in hits[0].message
        # the same name is fine when the mesh really has it
        ok_axes = ("data", "model", "modle")
        assert not [f for f in lint_plan(plan, mesh_axes=ok_axes)
                    if f.rule == "plan.unknown_axis"]

    def test_unknown_replica_axis_fires(self, stoch_plan):
        plan = copy.deepcopy(stoch_plan)
        plan.replica_axis = "ensemble"
        hits = [f for f in lint_plan(plan) if f.rule == "plan.unknown_axis"]
        assert len(hits) == 1 and hits[0].where == "<replica_axis>"

    def test_replica_collision_fires(self, stoch_plan):
        """The stoch golden's packed rows shard 'model'; making 'model'
        the replica axis reuses one mesh axis on two tensor dims."""
        plan = copy.deepcopy(stoch_plan)
        plan.replica_axis = "model"
        hits = [f for f in lint_plan(plan)
                if f.rule == "plan.replica_axis_collision"]
        assert hits and all(h.severity == ERROR for h in hits)
        # 'data' does not collide (rows only use 'model')
        plan.replica_axis = "data"
        assert not [f for f in lint_plan(plan)
                    if f.rule == "plan.replica_axis_collision"]

    def test_plan_lint_method_hook(self, det_plan):
        assert det_plan.lint() == lint_plan(det_plan)


# ---------------------------------------------------------------------------
# HLO lints: synthetic red programs + real clean programs
# ---------------------------------------------------------------------------

_UPCAST_HLO = textwrap.dedent("""\
    HloModule m, entry_computation_layout={(bf16[512,512])->f32[512,512]}

    ENTRY %main (p0: bf16[512,512]) -> f32[512,512] {
      %p0 = bf16[512,512]{1,0} parameter(0)
      ROOT %convert.1 = f32[512,512]{1,0} convert(%p0), metadata={op_name="jit(f)/convert"}
    }
    """)

_HOST_HLO = textwrap.dedent("""\
    HloModule m

    ENTRY %main (p0: f32[64]) -> f32[64] {
      %p0 = f32[64]{0} parameter(0)
      %tok = token[] after-all()
      %snd = (f32[64], u32[], token[]) send(%p0, %tok), channel_id=1
      %sd = token[] send-done(%snd), channel_id=1
      ROOT %out = f32[64]{0} copy(%p0)
    }
    """)

_TWO_AR_HLO = textwrap.dedent("""\
    HloModule m

    %sum (a: f32[], b: f32[]) -> f32[] {
      %a = f32[] parameter(0)
      %b = f32[] parameter(1)
      ROOT %add = f32[] add(%a, %b)
    }

    ENTRY %main (p0: f32[8,16]) -> f32[8,16] {
      %p0 = f32[8,16]{1,0} parameter(0)
      %ar1 = f32[8,16]{1,0} all-reduce(%p0), to_apply=%sum, metadata={op_name="jit(f)/layer1/psum"}
      ROOT %ar2 = f32[8,16]{1,0} all-reduce(%ar1), to_apply=%sum, metadata={op_name="jit(f)/layer2/psum"}
    }
    """)


class TestHloLints:
    def test_f32_upcast_fires_and_respects_threshold(self):
        hits = lint_f32_upcast(_UPCAST_HLO, "decode_step", min_bytes=1024)
        assert len(hits) == 1 and hits[0].rule == "hlo.f32_upcast"
        assert hits[0].data["offenders"][0]["from"] == "bf16"
        assert "jit(f)/convert" in hits[0].message
        # 512*512*4 bytes < a huge threshold: below-threshold is clean
        assert lint_f32_upcast(_UPCAST_HLO, "d", min_bytes=10**9) == []

    def test_f32_upcast_clean_on_integer_converts(self):
        """s32->f32 converts (popcount/iota results) are not upcasts."""
        text = _UPCAST_HLO.replace("bf16", "s32")
        assert lint_f32_upcast(text, "d", min_bytes=1024) == []

    def test_cache_donation_red_and_clean(self):
        import jax
        import jax.numpy as jnp

        donated = jax.jit(lambda x: x * 2.0, donate_argnums=0).lower(
            jnp.ones((64, 64))).compile().as_text()
        assert lint_cache_donation(donated, "decode_step") == []
        undonated = jax.jit(lambda x: x * 2.0).lower(
            jnp.ones((64, 64))).compile().as_text()
        hits = lint_cache_donation(undonated, "decode_step")
        assert len(hits) == 1
        assert hits[0].rule == "hlo.cache_not_donated"
        assert hits[0].severity == ERROR

    def test_host_transfer_fires(self):
        hits = lint_host_transfer(_HOST_HLO, "decode_step")
        assert len(hits) == 1 and hits[0].rule == "hlo.host_transfer"
        assert "send" in hits[0].message

    def test_host_transfer_clean_on_device_only_program(self):
        import jax
        import jax.numpy as jnp

        text = jax.jit(lambda x: x @ x).lower(
            jnp.ones((16, 16))).compile().as_text()
        assert lint_host_transfer(text, "d") == []

    def test_collective_budget_blames_by_op_name(self):
        hits = lint_collective_budget(_TWO_AR_HLO, "decode_step",
                                      {"all-reduce": 1})
        assert len(hits) == 1 and hits[0].rule == "hlo.collective_budget"
        assert hits[0].data["over"]["all-reduce"] == {"measured": 2,
                                                      "budget": 1}
        blamed = {r["op_name"] for r in hits[0].data["blame"]}
        assert "jit(f)/layer1/psum" in blamed
        assert "jit(f)/layer2/psum" in blamed
        # within budget: clean
        assert lint_collective_budget(_TWO_AR_HLO, "d",
                                      {"all-reduce": 2}) == []

    def test_lint_hlo_composes(self):
        findings = lint_hlo(_TWO_AR_HLO, "decode_step",
                            budget={"all-reduce": 0},
                            require_donation=True)
        assert rules_of(findings) == {"hlo.collective_budget",
                                      "hlo.cache_not_donated"}


# ---------------------------------------------------------------------------
# retrace sentinel
# ---------------------------------------------------------------------------

class _FakeJit:
    def __init__(self):
        self.size = 0

    def _cache_size(self):
        return self.size


class TestRetraceSentinel:
    def test_warmup_compiles_are_free_then_growth_fires(self):
        decode, chunk = _FakeJit(), _FakeJit()
        s = RetraceSentinel(entries={"decode": decode,
                                     "decode_chunk": chunk},
                            warmup_steps=1)
        decode.size = 1          # first-step compile
        s.step()
        s.step()
        assert s.ok and s.steps == 2
        chunk.size = 2           # allowlisted: new chunk length
        s.step()
        assert s.ok
        decode.size = 2          # post-warmup retrace: the bug
        s.step()
        assert not s.ok and len(s.events) == 1
        e = s.events[0]
        assert e["entry"] == "decode" and e["step"] == 4
        f = s.findings()
        assert len(f) == 1 and f[0].rule == "serve.retrace"
        assert f[0].severity == ERROR
        assert "recompile" in s.summary()

    def test_strict_raises(self):
        decode = _FakeJit()
        s = RetraceSentinel(entries={"decode": decode}, warmup_steps=1,
                            strict=True)
        s.step()
        decode.size = 1
        with pytest.raises(RetraceError, match="decode"):
            s.step()

    def test_needs_engine_or_entries(self):
        with pytest.raises(ValueError):
            RetraceSentinel()

    def test_shape_change_is_caught_live(self):
        """The acceptance red test: serving again with a different prompt
        length recompiles prefill/decode, and the sentinel catches it."""
        import jax
        import numpy as np

        from repro.configs import base as cb
        from repro.models import transformer as T
        from repro.serve.batcher import SlotBatcher
        from repro.serve.engine import ServeEngine, stream_serve

        cfg = cb.get_config("starcoder2_3b", smoke=True)
        params = T.init_lm(cfg, jax.random.key(0))
        engine = ServeEngine(cfg, params)
        sentinel = RetraceSentinel(engine, warmup_steps=1)

        def serve(prompt_len):
            b = SlotBatcher(2, prompt_len)
            for i in range(2):
                b.submit(np.full((prompt_len,), 1 + i, dtype=np.int32),
                         max_new=3)
            return stream_serve(engine, b, max_new_cap=4,
                                sentinel=sentinel)

        serve(prompt_len=8)
        assert sentinel.ok, sentinel.summary()   # steady state: no events
        serve(prompt_len=16)                     # shape change mid-session
        assert not sentinel.ok
        assert {e["entry"] for e in sentinel.events} & {"prefill_into",
                                                        "decode"}


@pytest.mark.slow
class TestLiveAnalysis:
    """The CI analysis job's live smoke, as a test: det sharded engine on
    the forced 4-device mesh — plan lints, HLO lints against the
    committed collective budget, and a mid-stream-refill stream_serve
    with zero post-warmup recompiles."""

    def test_live_det_clean(self):
        out = subprocess.run(
            [sys.executable, "-c", textwrap.dedent("""
                import os
                os.environ["XLA_FLAGS"] = \
                    "--xla_force_host_platform_device_count=4"
                os.environ["JAX_PLATFORMS"] = "cpu"
                import sys, json
                sys.path.insert(0, "src")
                from repro.analysis.__main__ import _live_child
                from repro.analysis.findings import findings_to_json
                print("FINDINGS " +
                      json.dumps(findings_to_json(_live_child("det"))))
            """)], cwd="/root/repo", capture_output=True, text=True,
            timeout=560)
        assert out.returncode == 0, (out.stdout[-500:], out.stderr[-2000:])
        line = [ln for ln in out.stdout.splitlines()
                if ln.startswith("FINDINGS ")][-1]
        findings = [Finding.from_json(d)
                    for d in json.loads(line[len("FINDINGS "):])]
        assert errors(findings) == [], findings_to_json(errors(findings))
        assert not [f for f in findings if f.rule == "serve.retrace"]


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

class TestCli:
    def test_all_goldens_gate_is_clean(self, tmp_path, capsys):
        from repro.analysis.__main__ import main

        out_json = tmp_path / "findings.json"
        assert main(["--all-goldens", "--json", str(out_json)]) == 0
        report = capsys.readouterr().out
        assert "repro.analysis: OK" in report
        data = json.loads(out_json.read_text())
        assert all(d["severity"] != "error" for d in data)

    def test_broken_manifest_fails_and_waiver_passes(self, tmp_path,
                                                     capsys):
        from repro.analysis.__main__ import main

        plan = ExecutionPlan.load(
            os.path.join(GOLDEN_DIR, "mnist_fc_det.json"))
        bad = copy.deepcopy(plan)
        row = [a for a in bad.layers if a.backend == "packed"][0]
        row.sharding = [None, "typo_axis"]
        path = str(tmp_path / "bad.json")
        bad.save(path)
        assert main(["--plan", path]) == 1
        assert "FAIL" in capsys.readouterr().out
        assert main(["--plan", path, "--waive", "plan.unknown_axis"]) == 0
