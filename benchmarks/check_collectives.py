"""Golden per-step collective audit: measured vs committed, fail loudly.

The ROADMAP's sharded-serving hunt needs its success metric pinned: the
*exact* number (and operand bytes) of collectives one ``decode_step`` /
``prefill_into`` executes for the det and xnor sharded golden plans on the
2x2 ("data", "model") mesh. A code change that silently adds an all-gather
to the decode step — a plan sharding tweak, a cache layout change, a new
engine epilogue — shifts serving throughput without failing any numeric
test. This gate compiles the actual jitted serving programs on a forced
4-device CPU mesh (in a subprocess: device count is fixed at backend init),
audits their SPMD HLO via ``repro.obs.collectives``, and diffs against the
manifest committed in ``benchmarks/golden_plans/collectives.json``.

  PYTHONPATH=src python -m benchmarks.check_collectives          # check
  PYTHONPATH=src python -m benchmarks.check_collectives --write  # regen

Regenerate (and commit) the golden only when a collective change is
intentional; the printed diff is the review artifact. Counts are exact
integers; bytes are exact operand sums — but both can legitimately move
under an XLA upgrade (the partitioner chooses the collectives), so a
version bump that shifts them is also a --write-and-review event.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys

GOLDEN = os.path.join(os.path.dirname(__file__), "golden_plans",
                      "collectives.json")

# audit geometry — mirrors serve_bench's sharded row: starcoder2-3b smoke
# config, 2x2 ("data", "model") mesh, 4 slots (even data-axis split)
ARCH = "starcoder2_3b"
MODES = ("det", "xnor")
MESH_SHAPE = (2, 2)
MESH_AXES = ("data", "model")
SLOTS = 4
PROMPT_LEN = 8
MAX_NEW_CAP = 8


def _child() -> dict:
    """Runs inside the forced-multi-device subprocess: builds the sharded
    engine per mode and audits its compiled decode/prefill programs."""
    import jax

    from repro.configs import base as cb
    from repro.core.policy import DEFAULT_POLICY
    from repro.engine import compile_plan
    from repro.models import transformer as T
    from repro.obs.collectives import audit_engine
    from repro.serve.engine import ServeEngine

    mesh = jax.make_mesh(MESH_SHAPE, MESH_AXES)
    cfg = cb.get_config(ARCH, smoke=True)
    params = T.init_lm(cfg, jax.random.key(0))
    out = {}
    for mode in MODES:
        plan = compile_plan(params, DEFAULT_POLICY, mode, warn=False,
                            mesh=mesh)
        packed = plan.pack(params, key=jax.random.key(1))
        engine = ServeEngine(cfg, packed, mesh=mesh, plan=plan)
        audits = audit_engine(engine, n_slots=SLOTS, prompt_len=PROMPT_LEN,
                              max_new_cap=MAX_NEW_CAP)
        out[mode] = {name: a.to_json() for name, a in audits.items()}
    return out


def measured(timeout: int = 540) -> dict | None:
    """Measured audit dict, or None if the subprocess cannot run."""
    code = ("import benchmarks.check_collectives as cc, json; "
            "print('RESULT ' + json.dumps(cc._child()))")
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=4",
               JAX_PLATFORMS="cpu")
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), os.pardir, "src"),
         os.path.join(os.path.dirname(__file__), os.pardir),
         env.get("PYTHONPATH", "")])
    try:
        proc = subprocess.run([sys.executable, "-c", code], env=env,
                              capture_output=True, text=True, timeout=timeout)
    except (OSError, subprocess.TimeoutExpired):
        return None
    for line in reversed(proc.stdout.splitlines()):
        if line.startswith("RESULT "):
            return json.loads(line[len("RESULT "):])
    print(f"collective-audit child failed:\n{proc.stderr[-2000:]}",
          file=sys.stderr)
    return None


# canonical category order for the mismatch table; anything else the audit
# ever reports (e.g. a new collective kind from an XLA upgrade) is appended
CATEGORIES = ("all-gather", "all-reduce", "all-to-all", "collective-permute")


def _entry_table(mode: str, entry: str, w: dict, g: dict) -> list[str]:
    """Per-category delta table for one drifted program: golden vs measured
    count + operand bytes per collective kind, plus reshard copies and the
    totals — the whole decode-step budget at a glance."""
    kinds = list(CATEGORIES) + sorted(
        (set(w.get("counts", {})) | set(g.get("counts", {})))
        - set(CATEGORIES))

    def row(name, wc, wb, gc, gb):
        flag = "   " if (wc, wb) == (gc, gb) else " <-"
        return (f"    {name:<20} {wc:>6} {wb:>12,.0f}   "
                f"{gc:>6} {gb:>12,.0f}{flag}")

    lines = [f"  {mode}/{entry}:",
             f"    {'category':<20} {'golden':>6} {'bytes':>12}   "
             f"{'measured':>6} {'bytes':>12}"]
    for k in kinds:
        lines.append(row(k, w.get("counts", {}).get(k, 0),
                         w.get("bytes", {}).get(k, 0.0),
                         g.get("counts", {}).get(k, 0),
                         g.get("bytes", {}).get(k, 0.0)))
    lines.append(row("reshard-copies",
                     w.get("reshard_copies", 0),
                     w.get("reshard_copy_bytes", 0.0),
                     g.get("reshard_copies", 0),
                     g.get("reshard_copy_bytes", 0.0)))
    lines.append(row("total collectives",
                     sum(w.get("counts", {}).values()),
                     sum(w.get("bytes", {}).values()),
                     sum(g.get("counts", {}).values()),
                     sum(g.get("bytes", {}).values())))
    return lines


def _diff(want: dict, got: dict) -> list[str]:
    lines = []
    for mode in sorted(set(want) | set(got)):
        w_mode, g_mode = want.get(mode, {}), got.get(mode, {})
        for entry in sorted(set(w_mode) | set(g_mode)):
            w, g = w_mode.get(entry), g_mode.get(entry)
            if w == g:
                continue
            if w is None or g is None:
                lines.append(f"  {mode}/{entry}: "
                             f"{'NEW' if w is None else 'MISSING'}")
                continue
            lines.extend(_entry_table(mode, entry, w, g))
    return lines


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--write", action="store_true",
                    help="(re)write the golden audit instead of checking")
    args = ap.parse_args(argv)

    got = measured()
    if got is None:
        print("collective audit: subprocess unavailable, skipping "
              "(no multi-device CPU mesh)", file=sys.stderr)
        return 0

    if args.write:
        payload = {"arch": ARCH, "smoke": True,
                   "mesh": {"shape": list(MESH_SHAPE),
                            "axes": list(MESH_AXES)},
                   "geometry": {"n_slots": SLOTS, "prompt_len": PROMPT_LEN,
                                "max_new_cap": MAX_NEW_CAP},
                   "audits": got}
        with open(GOLDEN, "w") as f:
            json.dump(payload, f, indent=1, sort_keys=True)
            f.write("\n")
        print(f"wrote {GOLDEN}")
        return 0

    if not os.path.exists(GOLDEN):
        print(f"missing golden {GOLDEN}; run with --write", file=sys.stderr)
        return 1
    with open(GOLDEN) as f:
        want = json.load(f)["audits"]
    lines = _diff(want, got)
    if lines:
        print("per-step collective audit drifted from golden "
              "(review, then --write if intentional):")
        print("\n".join(lines))
        return 1
    n = {m: sum(got[m]["decode_step"]["counts"].values()) for m in got}
    print("collective audit matches golden: " + ", ".join(
        f"{m}: {c} collectives/decode_step" for m, c in sorted(n.items())))
    return 0


if __name__ == "__main__":
    sys.exit(main())
