"""Renders the §Roofline table from the dry-run JSON cache.

One row per (arch x shape x mesh): the three roofline terms (seconds),
dominant bottleneck, per-device HBM, MODEL_FLOPS/HLO_FLOPs ratio, and the
roofline-implied MFU bound. Also emits the §Dry-run summary (memory and
collective schedule per cell).
"""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import RESULTS_DIR, csv_row

DRYRUN_DIR = os.path.join(RESULTS_DIR, "dryrun")

SKIPS = [
    ("starcoder2_3b", "long_500k", "full attention at 500k ctx"),
    ("qwen2_5_32b", "long_500k", "full attention at 500k ctx"),
    ("deepseek_coder_33b", "long_500k", "full attention at 500k ctx"),
    ("moonshot_v1_16b_a3b", "long_500k", "full attention at 500k ctx"),
    ("grok_1_314b", "long_500k", "full attention at 500k ctx"),
    ("musicgen_large", "long_500k", "full attention at 500k ctx"),
    ("internvl2_76b", "long_500k", "full attention at 500k ctx"),
]


def load_cells(dirname: str = DRYRUN_DIR, pattern: str = "*.json"):
    cells = []
    for path in sorted(glob.glob(os.path.join(dirname, pattern))):
        with open(path) as f:
            cells.append(json.load(f))
    return cells


def _fmt(x, nd=2):
    if x is None:
        return "-"
    if x == 0:
        return "0"
    if abs(x) >= 1000 or abs(x) < 0.01:
        return f"{x:.2e}"
    return f"{x:.{nd}f}"


def markdown_table(cells) -> str:
    hdr = ("| arch | shape | mesh | mode | compute (s) | memory (s) | "
           "collective (s) | dominant | HBM GB/dev | useful-FLOPs | "
           "MFU bound |\n"
           "|---|---|---|---|---|---|---|---|---|---|---|\n")
    rows = []
    for c in sorted(cells, key=lambda c: (c["arch"], c["shape"], c["mesh"])):
        r = c["roofline"]
        tag = c["binarize"] + ("+packed" if c.get("packed") else "")
        rows.append(
            f"| {c['arch']} | {c['shape']} | {c['mesh']} | {tag} "
            f"| {_fmt(r['compute_s'])} | {_fmt(r['memory_s'])} "
            f"| {_fmt(r['collective_s'])} | **{r['dominant']}** "
            f"| {_fmt(c['memory']['peak_gb'])} "
            f"| {_fmt(r['useful_flops_fraction'])} "
            f"| {_fmt(r['mfu_bound'], 3)} |")
    skip_rows = [
        f"| {a} | {s} | both | — | skipped | skipped | skipped | — | — | — "
        f"| — | <!-- {why} -->" for a, s, why in SKIPS]
    return hdr + "\n".join(rows + skip_rows)


def summary(cells) -> dict:
    by_dom = {}
    over_budget = []
    for c in cells:
        by_dom.setdefault(c["roofline"]["dominant"], 0)
        by_dom[c["roofline"]["dominant"]] += 1
        if c["memory"]["peak_gb"] > 17.18:  # 16 GiB
            over_budget.append(
                (c["arch"], c["shape"], c["mesh"], c["memory"]["peak_gb"]))
    return {"cells": len(cells), "dominant_histogram": by_dom,
            "over_hbm_budget": over_budget}


def main(fast: bool = False) -> list[str]:
    cells = load_cells()
    if not cells:
        return [csv_row("roofline/no_dryrun_cache", 0,
                        "run python -m repro.launch.dryrun first")]
    lines = []
    for c in cells:
        r = c["roofline"]
        name = (f"roofline/{c['arch']}/{c['shape']}/{c['mesh']}/"
                f"{c['binarize']}{'+packed' if c.get('packed') else ''}")
        lines.append(csv_row(
            name, r["bound_time_s"] * 1e6,
            f"dom={r['dominant']};mfu_bound={_fmt(r['mfu_bound'], 3)};"
            f"hbm={_fmt(c['memory']['peak_gb'])}GB"))
    s = summary(cells)
    lines.append(csv_row("roofline/summary", s["cells"],
                         f"dominant={s['dominant_histogram']};"
                         f"over_budget={len(s['over_hbm_budget'])}"))
    with open(os.path.join(RESULTS_DIR, "roofline_table.md"), "w") as f:
        f.write(markdown_table(cells) + "\n")
    return lines


if __name__ == "__main__":
    print("\n".join(main()))
