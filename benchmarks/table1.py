"""Paper Table I analogue: {no-reg, det, stoch} x {MNIST FC, CIFAR VGG}.

Columns reproduced:
  * learning time per epoch      — measured wall time (CPU container; the
                                   relative det/stoch/none ordering is the
                                   claim under test, not absolute seconds),
  * inference time per image     — measured, dense-f32 vs packed-binary path,
  * validation accuracy          — on the synthetic stand-in datasets,
  * kernel power                 — NOT measurable here; replaced by the
                                   roofline-derived energy-per-image proxy
                                   (labeled "derived"), see core/roofline.py.

The paper's qualitative claims checked by this table:
  1. binarized nets' accuracy is within ~1% of the unregularized baseline;
  2. binarized inference is substantially faster/cheaper per image than
     unregularized inference on the same platform (weight-bytes bound);
  3. det and stoch behave near-identically at inference.
"""
from __future__ import annotations

import time

import jax

from repro.core import binarize as B
from repro.core import roofline as R
from repro.core.policy import NONE_POLICY
from repro.data import synthetic as syn
from repro.launch.train import make_paper_policy
from repro.models import mnist_fc, vgg
from repro.optim import schedules
from repro.optim.sgd import sgd_momentum
from repro.serve.engine import pack_params, packed_param_bytes
from repro.train import steps as ST

from benchmarks.common import csv_row, save_json, timed


def _bench_model(model_name: str, steps_per_epoch: int = 40,
                 epochs: int = 3, batch: int = 64, lr: float = 1e-2):
    rows = []
    if model_name == "mnist_fc":
        init_fn = lambda: mnist_fc.init(jax.random.key(0), hidden=(256, 256))
        apply_fn = mnist_fc.apply
        spec = syn.SyntheticSpec("mnist", n_train=steps_per_epoch * batch,
                                 batch_size=batch)
        n_fc = 3
        flat = True
        img_flops = 2 * (784 * 256 + 256 * 256 + 256 * 10)
    else:
        init_fn = lambda: vgg.init(jax.random.key(0), width_mult=0.25)
        apply_fn = vgg.apply
        spec = syn.SyntheticSpec("cifar", n_train=steps_per_epoch * batch,
                                 batch_size=batch)
        n_fc = 3
        flat = False
        img_flops = 2 * 39e6 * 0.25 ** 2  # ~VGG16-CIFAR @ width 0.25

    policy = make_paper_policy(n_fc)
    for mode in ("none", "det", "stoch"):
        tree = init_fn()
        opt = sgd_momentum(schedules.paper_eq4(lr, steps_per_epoch),
                           momentum=0.9)
        step = jax.jit(ST.make_train_step(
            ST.make_classifier_loss(apply_fn), opt, mode,
            policy if mode != "none" else NONE_POLICY, has_model_state=True))
        state = ST.init_train_state(tree["params"], opt,
                                    model_state=tree["state"])

        def batch_fn(i):
            x, y = syn.train_batch(spec, i)
            return {"x": x.reshape(x.shape[0], -1) if flat else x, "y": y}

        state, _ = step(state, batch_fn(0))  # compile outside timing
        t0 = time.perf_counter()
        total = epochs * steps_per_epoch
        for i in range(1, total):
            state, metrics = step(state, batch_fn(i))
        jax.block_until_ready(state["params"])
        epoch_s = (time.perf_counter() - t0) / epochs

        # inference path: binarized modes use the packed-weight network
        params = state["params"]
        model_state = state["model_state"]
        if mode != "none":
            params_inf = B.binarize_tree(params, "det", policy)
            cal = [batch_fn(10_000 + j)["x"] for j in range(10)]
            model_state = ST.recalibrate_bn(apply_fn, params_inf, model_state,
                                            cal)
            params_packed = pack_params(params, policy, "det")
            dense_b, packed_b = packed_param_bytes(params_packed)
        else:
            params_inf = params
            dense_b = packed_b = sum(
                x.size * 4 for x in jax.tree.leaves(params))

        eval_fn = ST.make_eval_fn(apply_fn)
        x, y = syn.eval_batch(spec)
        xin = x.reshape(x.shape[0], -1) if flat else x
        _, acc = eval_fn(params_inf, model_state, xin, y)

        infer = jax.jit(lambda p, s, xx: apply_fn(p, s, xx, training=False)[0])
        per_image_s = timed(infer, params_inf, model_state, xin) / batch

        # derived energy proxy per image (roofline model, NOT a measurement)
        weight_bytes = packed_b if mode != "none" else dense_b
        energy_j = (img_flops * R.PJ_PER_FLOP
                    + weight_bytes * R.PJ_PER_HBM_BYTE)
        rows.append({
            "model": model_name, "regularizer": mode,
            "learning_time_per_epoch_s": epoch_s,
            "inference_time_per_image_s": per_image_s,
            "validation_accuracy": float(acc),
            "weight_bytes": int(weight_bytes),
            "derived_energy_per_image_J": energy_j,
        })
    return rows


def main(fast: bool = False) -> list[str]:
    lines = []
    rows = []
    rows += _bench_model("mnist_fc", steps_per_epoch=20 if fast else 40)
    rows += _bench_model("vgg16_cifar10", steps_per_epoch=10 if fast else 30,
                         epochs=3, batch=16, lr=1e-2)
    save_json("table1", rows)
    for r in rows:
        lines.append(csv_row(
            f"table1/{r['model']}/{r['regularizer']}/epoch",
            r["learning_time_per_epoch_s"] * 1e6,
            f"acc={r['validation_accuracy']:.3f}"))
        lines.append(csv_row(
            f"table1/{r['model']}/{r['regularizer']}/infer_img",
            r["inference_time_per_image_s"] * 1e6,
            f"E_img={r['derived_energy_per_image_J']:.2e}J"
            f";w_bytes={r['weight_bytes']}"))
    return lines


if __name__ == "__main__":
    print("\n".join(main()))
