"""XNOR conv engine benchmarks (the paper's VGG/CIFAR-10 conv stack).

Per-layer comparison of the real-valued conv baseline (bf16
``lax.conv_general_dilated``) against the binary im2col popcount path,
reporting the activation HBM bytes each engine moves and roofline-projected
TPU time. As in the other suites, the bytes columns are the
platform-independent mechanism; CPU wall times are labeled cpu-ref and only
meaningful relatively.

Activation bytes are reported like-for-like at the im2col interface: a dense
bf16 patch matrix (B*OH*OW, kh*kw*C) vs its bitpacked form — exactly 16x
smaller whenever C is a multiple of 32, i.e. for all of VGG's binarized
blocks 2-5. The raw input-tensor bytes are also recorded so the kh*kw patch
duplication the im2col lowering pays is visible rather than hidden.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import roofline as R
from repro.xnor.conv import (conv_geometry, conv_k, pack_conv_kernel,
                             patch_nbytes_dense, patch_nbytes_packed,
                             patch_words, xnor_conv2d)

from benchmarks.common import csv_row, save_json, timed

# (tag, B, H, W, C_in, C_out): one representative 3x3 conv per binarized
# VGG block at CIFAR-10 spatial sizes (block 1 is excluded by XNOR_POLICY —
# its row is the real-valued-input contrast).
VGG_LAYERS = [
    ("block1_realvalued", 8, 32, 32, 64, 64),
    ("block2", 8, 16, 16, 128, 128),
    ("block3", 8, 8, 8, 256, 256),
    ("block4", 8, 4, 4, 512, 512),
    ("block5", 8, 2, 2, 512, 512),
]
KSIZE = (3, 3)


def layer_roofline(b: int, h: int, w: int, c: int, n: int,
                   ksize=KSIZE) -> dict:
    """Structural per-layer numbers for a SAME stride-1 conv: HBM bytes each
    engine moves and the roofline-projected TPU time. Shared with
    kernel_bench so the two suites can't diverge."""
    oh, ow, _ = conv_geometry(h, w, ksize, (1, 1), "SAME")
    k = conv_k(ksize, c)
    act_in_bf16 = b * h * w * c * 2
    patches_bf16 = patch_nbytes_dense(b, oh, ow, ksize, c)
    patches_packed = patch_nbytes_packed(b, oh, ow, ksize, c)
    out_bytes = b * oh * ow * n * 4
    w_dense = k * n * 2
    w_packed = patch_words(ksize, c) * n * 4
    flops = 2 * b * oh * ow * k * n
    tpu_dense_s = max((w_dense + act_in_bf16 + out_bytes) / R.HBM_BW,
                      flops / R.PEAK_FLOPS_BF16)
    # xnor does no MXU flops: bytes + VPU int ops over 32x fewer words
    tpu_xnor_s = max((w_packed + patches_packed + out_bytes) / R.HBM_BW,
                     2 * b * oh * ow * patch_words(ksize, c) * n
                     / R.PEAK_FLOPS_BF16)
    return {
        "shape": [b, h, w, c, n],
        "activation_bytes_input_bf16": act_in_bf16,
        "activation_bytes_patches_bf16": patches_bf16,
        "activation_bytes_patches_packed": patches_packed,
        "activation_compression": patches_bf16 / patches_packed,
        "weight_bytes_dense_bf16": w_dense,
        "weight_bytes_packed": w_packed,
        "tpu_roofline_dense_s": tpu_dense_s,
        "tpu_roofline_xnor_s": tpu_xnor_s,
        "tpu_projected_speedup": tpu_dense_s / tpu_xnor_s,
    }


def roofline_csv_rows(name: str, rec: dict) -> list[str]:
    """The two standard CSV rows (activation compression, projected time)."""
    return [
        csv_row(f"{name}/activation_compression",
                rec["activation_bytes_patches_packed"],
                f"{rec['activation_compression']:.1f}x_fewer_activation_bytes"),
        csv_row(f"{name}/tpu_projected", rec["tpu_roofline_xnor_s"] * 1e6,
                f"dense={rec['tpu_roofline_dense_s']*1e6:.1f}us;"
                f"speedup={rec['tpu_projected_speedup']:.2f}x"),
    ]


def main(fast: bool = False) -> list[str]:
    lines: list[str] = []
    records = []
    layers = VGG_LAYERS[1:3] if fast else VGG_LAYERS
    for tag, b, h, w, c, n in layers:
        x = jax.random.normal(jax.random.key(0), (b, h, w, c), jnp.float32)
        wk = jax.random.normal(jax.random.key(1), (*KSIZE, c, n), jnp.float32)
        wp = pack_conv_kernel(wk)

        dense_fn = jax.jit(lambda x, wk: jax.lax.conv_general_dilated(
            x.astype(jnp.bfloat16), wk.astype(jnp.bfloat16),
            window_strides=(1, 1), padding="SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC")))
        xnor_fn = jax.jit(lambda x, wp, c=c: xnor_conv2d(
            x, wp, ksize=KSIZE, c_in=c, use_pallas=False))

        rec = {"layer": tag, **layer_roofline(b, h, w, c, n),
               "cpu_ref_dense_conv_s": timed(dense_fn, x, wk, iters=3),
               "cpu_ref_xnor_conv_s": timed(xnor_fn, x, wp, iters=3)}
        records.append(rec)
        lines += roofline_csv_rows(f"xnor_conv/{tag}/{b}x{h}x{w}x{c}->{n}",
                                   rec)

    # whole-stack summary: total activation bytes over VGG's binarized blocks
    tot_bf16 = sum(r["activation_bytes_patches_bf16"] for r in records
                   if r["layer"] != "block1_realvalued")
    tot_pack = sum(r["activation_bytes_patches_packed"] for r in records
                   if r["layer"] != "block1_realvalued")
    lines.append(csv_row("xnor_conv/blocks2-5/total_activation_bytes",
                         tot_pack, f"{tot_bf16/tot_pack:.1f}x_vs_bf16"))
    save_json("xnor_conv_bench", records)
    return lines


if __name__ == "__main__":
    print("\n".join(main()))
