"""Fully-binary (XNOR-popcount) path benchmarks.

End-to-end comparison of the three execution engines on the paper's FC
workload shapes — dense bf16, packed-weight (binary weights, full-width
activations), and xnor (binary weights *and* activations) — reporting the
bytes each path moves per layer and the roofline-projected TPU time. The
bytes columns are the platform-independent mechanism (the paper's argument);
CPU wall times are labeled cpu-ref and only meaningful relatively.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.engine import costs as ecosts
from repro.kernels import ops as kops
from repro.xnor import ops as xops
from repro.xnor import packing as xpack
from repro.xnor import ref as xref

from benchmarks.common import csv_row, save_json, timed

#: report label -> engine-registry backend name (the cost model's key)
ENGINES = {"dense": "dense", "packed_weight": "packed", "xnor": "xnor"}


def xnor_cpu_ref(x, wp, k: int, chunk: int = 512):
    """Column-chunked oracle: bounds the (M, K/32, N) popcount intermediate."""
    a = xops.sign_and_pack(x)
    return jnp.concatenate(
        [xref.xnor_matmul_ref(a, wp[:, i:i + chunk], k)
         for i in range(0, wp.shape[1], chunk)], axis=1)


def layer_bytes(m: int, k: int, n: int) -> dict:
    """HBM bytes per (M,K)x(K,N) layer for each engine (out always f32),
    straight from the shared ``repro.engine.costs`` model."""
    return {label: ecosts.gemm_cost(b, m, k, n, with_scale=False)["bytes"]
            for label, b in ENGINES.items()}


def main(fast: bool = False) -> list[str]:
    lines: list[str] = []
    records = []
    # paper FC-net serving shapes (batch x 2048-wide hidden layers) + decode
    shapes = [(8, 2048, 2048), (128, 2048, 2048)]
    if not fast:
        shapes.append((256, 4096, 4096))
    for m, k, n in shapes:
        x = jax.random.normal(jax.random.key(0), (m, k), jnp.float32)
        w = jax.random.normal(jax.random.key(1), (k, n), jnp.float32)
        wp = kops.binarize_and_pack(w)

        t_dense = timed(jax.jit(
            lambda x, w: x.astype(jnp.bfloat16) @ w.astype(jnp.bfloat16)),
            x, w, iters=3)
        t_xnor = timed(jax.jit(
            lambda x, wp, k=k: xnor_cpu_ref(x, wp, k)), x, wp, iters=3)

        b = layer_bytes(m, k, n)
        # xnor replaces the MXU dot with VPU int ops over 32x fewer words —
        # the op-count difference is inside the shared cost model
        t = {label: ecosts.roofline_seconds(be, m, k, n, with_scale=False)
             for label, be in ENGINES.items()}
        act_ratio = (xpack.activation_nbytes((m, k), 2)
                     / xpack.packed_activation_nbytes((m, k)))
        rec = {"shape": [m, k, n], "bytes": b, "tpu_roofline_s": t,
               "activation_compression_vs_bf16": act_ratio,
               "cpu_ref_dense_s": t_dense, "cpu_ref_xnor_s": t_xnor}
        records.append(rec)
        lines.append(csv_row(
            f"xnor/{m}x{k}x{n}/bytes_moved", b["xnor"],
            f"dense={b['dense']};packed={b['packed_weight']};"
            f"act_compression={act_ratio:.1f}x"))
        lines.append(csv_row(
            f"xnor/{m}x{k}x{n}/tpu_projected", t["xnor"] * 1e6,
            f"dense={t['dense']*1e6:.1f}us;packed={t['packed_weight']*1e6:.1f}us;"
            f"speedup_vs_packed={t['packed_weight']/t['xnor']:.2f}x"))

    save_json("xnor_bench", records)
    return lines


if __name__ == "__main__":
    print("\n".join(main()))
