"""Serving-loop benchmark: step-level continuous batching vs the legacy
round-based loop.

The paper's inference headline (>9.89x deterministic / >9.91x stochastic
binarized speedup) only matters at serving scale, and sustained *streaming*
throughput — not one-shot batch latency — is where binarized datapaths pay
off (FINN, arXiv:1612.07119; Scaling BNNs, arXiv:1701.03400). This suite
measures:

* step-level continuous batching (``serve.engine.stream_serve``) vs the
  old round-based loop (re-prefill every round, every slot decodes the
  global ``max_new``) at 8 slots under *skewed* per-request ``max_new`` —
  the regime where round barriers waste the most decode steps;
* tokens/s across slot counts (the compiled batch dimension);
* burst vs staggered arrival (requests joining mid-stream through
  ``prefill_into`` — no round barrier to wait for);
* chunked prefill + prefix KV reuse under staggered arrival: whole-prompt
  vs fused ``decode_prefill`` admission (burst-gap ratio + TTFT medians),
  and a shared-prefix workload served cold vs from prefix-cache hits
  (hit TTFT must undercut the cold median);
* dense vs packed vs xnor execution plans under the step-level loop;
* mesh-sharded vs single-device serving (tensor-parallel execution plans
  on a forced 2x2 ("data", "model") CPU mesh, run in a subprocess so this
  process keeps its device count) — on CPU this is a *parity* row (same
  tokens, placement overhead visible), on real multi-chip hardware it is
  the scale-out row.

All throughput numbers divide tokens *actually recorded* by wall time
(``SlotBatcher.tokens_generated``), never steps-times-batch arithmetic.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import csv_row, save_json

ARCH = "starcoder2_3b"
PROMPT_LEN = 8


def _engine(plan: str):
    from repro.configs import base as cb
    from repro.core.policy import DEFAULT_POLICY
    from repro.models import transformer as T
    from repro.serve.engine import ServeEngine, pack_params

    cfg = cb.get_config(ARCH, smoke=True)
    params = T.init_lm(cfg, jax.random.key(0))
    if plan != "dense":
        params = pack_params(params, DEFAULT_POLICY, plan,
                             key=jax.random.key(1))
    return cfg, ServeEngine(cfg, params)


def _submit_skewed(batcher, cfg, n: int, cap: int, n_long: int, short: int,
                   seed: int = 0):
    """A few cap-length requests + many short ones: the skew that starves a
    round-based loop (every slot decodes the global cap every round)."""
    rng = np.random.default_rng(seed)
    for i in range(n):
        batcher.submit(rng.integers(0, cfg.vocab_size, PROMPT_LEN),
                       cap if i < n_long else short)


def _run_step_loop(engine, batcher, cap: int, metrics=None,
                   chunk: int = 1) -> tuple[float, int, int]:
    from repro.serve.engine import stream_serve

    t0 = time.perf_counter()
    steps = stream_serve(engine, batcher, max_new_cap=cap, metrics=metrics,
                         decode_chunk=chunk)
    return time.perf_counter() - t0, steps, batcher.tokens_generated


def _run_round_loop(engine, batcher, cap: int) -> tuple[float, int, int]:
    """The legacy pre-step-engine loop: every round re-prefills all slots
    and decodes the global cap, with corrected token accounting."""
    t0 = time.perf_counter()
    rounds = 0
    while not batcher.idle:
        batcher.refill()
        result = engine.generate(jnp.asarray(batcher.prompts()), cap)
        for step_tok in np.asarray(result.tokens).T:
            batcher.record(step_tok)
        rounds += 1
    batcher.refill()
    return time.perf_counter() - t0, rounds, batcher.tokens_generated


def _fresh_batcher(cfg, slots: int, prompt_len: int = PROMPT_LEN):
    from repro.serve.batcher import SlotBatcher

    return SlotBatcher(slots, prompt_len)


def _staggered_loop(engine, cfg, slots: int, n: int, cap: int,
                    every: int) -> tuple[float, int, int]:
    """Requests arrive mid-stream (one every ``every`` steps): the hand-
    rolled loop shows the engine primitives absorbing async arrival — a
    new request joins the live batch at the next step, no round barrier."""
    batcher = _fresh_batcher(cfg, slots)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, PROMPT_LEN) for _ in range(n)]
    t0 = time.perf_counter()
    state = engine.init_decode(slots, PROMPT_LEN, cap)
    submitted = steps = 0
    while submitted < n or not batcher.idle:
        while submitted < n and steps >= submitted * every:
            batcher.submit(prompts[submitted], cap)
            submitted += 1
        for slot in batcher.refill():
            state = engine.prefill_into(state, slot, batcher.slots[slot].prompt)
        if batcher.idle:
            if submitted < n:  # queue drained but more arrivals pending
                steps += 1
                continue
            break
        tok = jnp.argmax(state.logits, axis=-1)
        batcher.record(np.asarray(tok))
        steps += 1
        if submitted == n and batcher.idle:
            break              # final emission needs no trailing decode
        state = engine.decode_step(state, tok)
    batcher.refill()
    return time.perf_counter() - t0, steps, batcher.tokens_generated


def _staggered_stream(engine, cfg, slots: int, n: int, cap: int, every: int,
                      *, prefill_chunk: int = 0, prefix_cache=None,
                      shared_prefix: int = 0, prompt_len: int = PROMPT_LEN):
    """Open-loop staggered arrival through ``stream_serve``'s ``arrivals``
    hook (one request every ``every`` iterations; ``every=0`` submits the
    whole batch up front — the burst baseline through the *same* loop
    driver), optionally with chunked prefill, a prefix cache, and a shared
    prompt prefix (the multi-tenant system-prompt workload). Returns the
    batcher for TTFT accounting."""
    from repro.serve.engine import stream_serve

    batcher = _fresh_batcher(cfg, slots, prompt_len)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, prompt_len) for _ in range(n)]
    if shared_prefix:
        for p in prompts[1:]:
            p[:shared_prefix] = prompts[0][:shared_prefix]
    sub = {"n": 0}

    def arrivals(iteration: int) -> bool:
        while sub["n"] < n and iteration >= sub["n"] * every:
            batcher.submit(prompts[sub["n"]], cap)
            sub["n"] += 1
        return sub["n"] < n

    t0 = time.perf_counter()
    steps = stream_serve(engine, batcher, max_new_cap=cap,
                         prefill_chunk=prefill_chunk,
                         prefix_cache=prefix_cache, arrivals=arrivals)
    return (time.perf_counter() - t0, steps, batcher.tokens_generated,
            batcher)


def _sharded_child(modes: list[str], n: int, cap: int, slots: int,
                   mesh_shape=(2, 2), widen: int = 1,
                   chunk: int = 1) -> dict:
    """Runs inside the forced-multi-device subprocess: serve the same
    workload through a single-device engine and a mesh-sharded engine per
    plan mode; returns tok/s for both (greedy tokens must agree).

    ``widen`` scales d_model / n_heads / d_ff by an integer factor (the
    model-size sweep: where per-device compute grows, the fixed per-step
    collective cost amortizes). Both engines stay *untraced* (the
    ``NULL_TRACER`` default — no ``tracer.fence``): a fencing tracer
    ``block_until_ready``'s every dispatch, serializing the async pipeline
    and understating exactly the sharded rows this compares. The returned
    ``manifest`` is this subprocess's own ``run_manifest`` — it, not the
    parent, sees the forced device count and mesh shape."""
    import dataclasses

    from benchmarks.common import run_manifest
    from repro.configs import base as cb
    from repro.core.policy import DEFAULT_POLICY
    from repro.engine import compile_plan
    from repro.models import transformer as T
    from repro.serve.engine import ServeEngine

    mesh = jax.make_mesh(tuple(mesh_shape), ("data", "model"))
    cfg = cb.get_config(ARCH, smoke=True)
    if widen != 1:
        cfg = dataclasses.replace(cfg, d_model=cfg.d_model * widen,
                                  n_heads=cfg.n_heads * widen,
                                  d_ff=cfg.d_ff * widen)
    params = T.init_lm(cfg, jax.random.key(0))
    out = {"manifest": run_manifest(mesh_shape=list(mesh_shape),
                                    widen=widen, decode_chunk=chunk)}
    for mode in modes:
        plan = compile_plan(params, DEFAULT_POLICY, mode, warn=False,
                            mesh=mesh)
        packed = plan.pack(params, key=jax.random.key(1))
        engines = {"single": ServeEngine(cfg, packed),
                   "sharded": ServeEngine(cfg, packed, mesh=mesh, plan=plan)}
        tokens = {}
        for name, eng in engines.items():
            b = _fresh_batcher(cfg, slots)          # warmup/compile
            _submit_skewed(b, cfg, slots, cap, slots, 0)
            _run_step_loop(eng, b, cap, chunk=chunk)
            b = _fresh_batcher(cfg, slots)
            _submit_skewed(b, cfg, n, cap, n, 0)
            dt, steps, toks = _run_step_loop(eng, b, cap, chunk=chunk)
            out[f"{mode}_{name}"] = {"s": dt, "tokens": toks,
                                     "tok_s": toks / dt}
            tokens[name] = {r.uid: list(r.generated) for r in b.completed}
        out[f"{mode}_identical"] = tokens["single"] == tokens["sharded"]
    return out


def _sharded_compare(modes: list[str], n: int, cap: int, slots: int, *,
                     devices: int = 4, mesh_shape=(2, 2), widen: int = 1,
                     chunk: int = 1) -> dict | None:
    """Sharded-vs-single comparison, in a subprocess with ``devices``
    forced host devices (device count is fixed at backend init, so the
    parent process cannot grow one). Returns None if the child fails (e.g.
    no subprocess support on the platform) — the suite keeps going."""
    code = (f"import benchmarks.serve_bench as sb, json; "
            f"print('RESULT ' + json.dumps(sb._sharded_child("
            f"{modes!r}, {n}, {cap}, {slots}, {tuple(mesh_shape)!r}, "
            f"{widen}, {chunk})))")
    env = dict(os.environ,
               XLA_FLAGS=f"--xla_force_host_platform_device_count={devices}",
               JAX_PLATFORMS="cpu")
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), os.pardir, "src"),
         os.path.join(os.path.dirname(__file__), os.pardir),
         env.get("PYTHONPATH", "")])
    try:
        proc = subprocess.run([sys.executable, "-c", code], env=env,
                              capture_output=True, text=True, timeout=540)
    except (OSError, subprocess.TimeoutExpired):
        return None
    for line in reversed(proc.stdout.splitlines()):
        if line.startswith("RESULT "):
            return json.loads(line[len("RESULT "):])
    print(f"sharded-compare child failed:\n{proc.stderr[-1500:]}",
          file=sys.stderr)
    return None


def main(fast: bool = False):
    slots = 8
    cap = 16 if fast else 32
    short = 2
    n_req = 12 if fast else 24
    n_long = 2 if fast else 4

    record = {}
    rows = []

    # -- step vs round under skewed max_new (the headline comparison) -----
    cfg, engine = _engine("det")
    for loop, runner in (("step", _run_step_loop), ("round", _run_round_loop)):
        b = _fresh_batcher(cfg, slots)       # warmup: compile both paths
        _submit_skewed(b, cfg, slots, cap, 1, short)
        runner(engine, b, cap)
        b = _fresh_batcher(cfg, slots)
        _submit_skewed(b, cfg, n_req, cap, n_long, short)
        if loop == "step":
            # the step loop reports itself through the metrics registry;
            # the artifact keeps the full latency distribution, not just
            # the throughput scalar
            from repro.obs.metrics import MetricsRegistry
            metrics = MetricsRegistry()
            dt, steps, toks = _run_step_loop(engine, b, cap, metrics)
        else:
            metrics = None
            dt, steps, toks = runner(engine, b, cap)
        record[f"{loop}_skewed"] = {"s": dt, "steps": steps, "tokens": toks,
                                    "tok_s": toks / dt}
        if metrics is not None:
            record[f"{loop}_skewed"]["step_latency"] = metrics.histogram(
                "serve_step_seconds").summary()
            record[f"{loop}_skewed"]["ttft"] = metrics.histogram(
                "serve_ttft_seconds").summary()
        rows.append(csv_row(
            f"serve/{loop}_slots{slots}_skewed", dt / max(steps, 1) * 1e6,
            f"tok/s={toks / dt:.1f} tokens={toks}"))
    ratio = record["step_skewed"]["tok_s"] / record["round_skewed"]["tok_s"]
    record["step_over_round"] = ratio
    rows.append(csv_row("serve/step_over_round_skewed", 0.0,
                        f"ratio={ratio:.2f}x (>=1 expected: no round barrier)"))

    # -- slot-count sweep (uniform max_new, step loop) --------------------
    sweep_cap = 8
    for s in ((2, 8) if fast else (2, 4, 8)):
        b = _fresh_batcher(cfg, s)
        _submit_skewed(b, cfg, s, sweep_cap, s, 0)   # warmup this n_slots
        _run_step_loop(engine, b, sweep_cap)
        b = _fresh_batcher(cfg, s)
        _submit_skewed(b, cfg, 2 * s, sweep_cap, 2 * s, 0)
        dt, steps, toks = _run_step_loop(engine, b, sweep_cap)
        record[f"step_slots{s}"] = {"s": dt, "tokens": toks,
                                    "tok_s": toks / dt}
        rows.append(csv_row(f"serve/step_slots{s}_uniform",
                            dt / max(steps, 1) * 1e6,
                            f"tok/s={toks / dt:.1f}"))

    # -- arrival patterns: burst vs staggered (step loop, 4 slots) --------
    arr_slots, arr_n, arr_cap = 4, 8, 8
    b = _fresh_batcher(cfg, arr_slots)               # warmup this n_slots
    _submit_skewed(b, cfg, arr_slots, arr_cap, arr_slots, 0)
    _run_step_loop(engine, b, arr_cap)
    b = _fresh_batcher(cfg, arr_slots)
    _submit_skewed(b, cfg, arr_n, arr_cap, arr_n, 0)
    dt, steps, toks = _run_step_loop(engine, b, arr_cap)
    rows.append(csv_row("serve/arrival_burst", dt / max(steps, 1) * 1e6,
                        f"tok/s={toks / dt:.1f}"))
    record["arrival_burst"] = {"s": dt, "tokens": toks, "tok_s": toks / dt}
    dt, steps, toks = _staggered_loop(engine, cfg, arr_slots, arr_n, arr_cap,
                                      every=2)
    rows.append(csv_row("serve/arrival_staggered", dt / max(steps, 1) * 1e6,
                        f"tok/s={toks / dt:.1f}"))
    record["arrival_staggered"] = {"s": dt, "tokens": toks, "tok_s": toks / dt}

    # -- chunked prefill + prefix KV reuse (staggered arrival) ------------
    # Staggered arrival is where whole-prompt admission hurts: every
    # arriving prompt is a separate prefill dispatch while the live slots
    # wait. Chunked prefill folds admission INTO the decode step (the
    # fused decode_prefill program — one dispatch advances all live slots
    # and one prompt chunk), closing the burst-vs-staggered gap; a prefix
    # cache on a shared-prefix workload then removes the prefill work
    # itself, pulling hit TTFT below the cold median.
    from repro.serve import PrefixCache

    def _ttft_ms(b):
        return float(np.median([r.ttft for r in b.completed]) * 1e3)

    # This section runs on its own geometry: a 16x-longer prompt (the
    # regime the ROADMAP item is about — prefill work comparable to many
    # decode steps; at PROMPT_LEN=8 a whole-prompt prefill costs barely
    # more than one decode step and there is nothing for chunking to
    # hide) and a 32-token cap so admission cost is amortized over a real
    # decode stream. Gap methodology: shared-core CPU drift between runs
    # is +/-15%, larger than the effects measured here, so each row's
    # burst_gap is the MEDIAN over paired samples — every staggered run
    # is immediately preceded by a burst run through the SAME
    # stream_serve driver (``every=0`` = submit everything up front) at
    # the SAME geometry, and the ratio is taken within the pair, where
    # drift cancels. Two chunk sizes: chunk == prompt admits each prompt
    # in ONE fused decode+prefill dispatch; chunk == prompt/4 exercises
    # true multi-chunk admission (and partial prefix snapshots). On this
    # serial-CPU smoke host the fused program's chunk compute cannot
    # overlap decode compute (the compiled fused HLO is op-for-op the sum
    # of decode_step + prefill_chunk), so plain chunked rows carry the
    # admitted slot's masked iterations as visible overhead — the row
    # that closes the burst gap outright is prefix_warm below, where the
    # chunked machinery plus prefix reuse removes the prefill work
    # instead of hiding it. On parallel accelerators, where decode is
    # memory-bound and chunk FLOPs ride along free, the plain chunked
    # rows are the ones expected to close the gap.
    ch_prompt, ch_chunk, ch_cap = 16 * PROMPT_LEN, 4 * PROMPT_LEN, 32
    ch_n, ch_every, ch_pairs = 12, 2, 5

    def _chunk_stream(every: int, **kw):
        return _staggered_stream(engine, cfg, arr_slots, ch_n, ch_cap,
                                 every, prompt_len=ch_prompt, **kw)

    def _paired(pairs: int, **kw):
        """Median-gap estimate: (burst, staggered) sample pairs, ratio
        taken within each pair. Returns the median pair (by gap)."""
        samples = []
        for _ in range(pairs):
            bdt, _bs, btoks, _bb = _chunk_stream(0)
            dt, steps, toks, b = _chunk_stream(ch_every, **kw)
            samples.append(((btoks / bdt) / (toks / dt), dt, steps, toks, b))
        samples.sort(key=lambda s: s[0])
        return samples[len(samples) // 2]

    _chunk_stream(0)                                     # warmup/compile
    chunked = {"prompt_len": ch_prompt, "chunk": ch_chunk, "cap": ch_cap,
               "n": ch_n, "every": ch_every, "pairs": ch_pairs}
    for tag, kw in (("staggered_whole", {}),
                    ("staggered_chunked", {"prefill_chunk": ch_prompt}),
                    ("staggered_chunked_multi",
                     {"prefill_chunk": ch_chunk})):
        _chunk_stream(ch_every, **kw)                    # warmup/compile
        gap, dt, steps, toks, b = _paired(ch_pairs, **kw)
        chunked[tag] = {"s": dt, "tokens": toks, "tok_s": toks / dt,
                        "ttft_ms": _ttft_ms(b), "burst_gap": gap}
        rows.append(csv_row(
            f"serve/{tag}", dt / max(steps, 1) * 1e6,
            f"tok/s={toks / dt:.1f} burst_gap={gap:.2f}x "
            f"ttft_ms={_ttft_ms(b):.1f}"))

    # shared-prefix workload: pass 1 populates the cache (cold, a single
    # unpaired stream), later passes admit every prompt from a
    # full-prompt prefix hit (warm, paired like the rows above — the
    # cache stays warm so the pair loop re-serves it)
    pc = PrefixCache()
    dt, steps, toks, b = _chunk_stream(ch_every, prefill_chunk=ch_chunk,
                                       prefix_cache=pc,
                                       shared_prefix=ch_prompt)
    chunked["prefix_cold"] = {"s": dt, "tok_s": toks / dt,
                              "ttft_ms": _ttft_ms(b)}
    gap, dt, steps, toks, b = _paired(3, prefill_chunk=ch_chunk,
                                      prefix_cache=pc,
                                      shared_prefix=ch_prompt)
    chunked["prefix_warm"] = {"s": dt, "tok_s": toks / dt, "burst_gap": gap,
                              "ttft_ms": _ttft_ms(b), **pc.stats()}
    warm_ttft = chunked["prefix_warm"]["ttft_ms"]
    cold_ttft = chunked["prefix_cold"]["ttft_ms"]
    rows.append(csv_row(
        "serve/staggered_prefix_warm", dt / max(steps, 1) * 1e6,
        f"tok/s={toks / dt:.1f} burst_gap={gap:.2f}x "
        f"ttft_ms={warm_ttft:.1f} (cold {cold_ttft:.1f}) hits={pc.hits} "
        f"skipped={pc.tokens_skipped}tok"))
    record["chunked_prefill"] = chunked

    # -- execution plans under the step loop ------------------------------
    plan_n, plan_cap = (8, 8) if fast else (16, 16)
    for plan in ("dense", "det", "xnor"):
        cfg_p, eng_p = (cfg, engine) if plan == "det" else _engine(plan)
        b = _fresh_batcher(cfg_p, slots)
        _submit_skewed(b, cfg_p, slots, plan_cap, slots, 0)
        _run_step_loop(eng_p, b, plan_cap)
        b = _fresh_batcher(cfg_p, slots)
        _submit_skewed(b, cfg_p, plan_n, plan_cap, plan_n, 0)
        dt, steps, toks = _run_step_loop(eng_p, b, plan_cap)
        record[f"plan_{plan}"] = {"s": dt, "tokens": toks, "tok_s": toks / dt}
        rows.append(csv_row(f"serve/plan_{plan}_slots{slots}",
                            dt / max(steps, 1) * 1e6,
                            f"tok/s={toks / dt:.1f}"))

    # -- mesh-sharded vs single-device (tensor-parallel plans) ------------
    # Two sharded grids, each row a forced-device-count subprocess serving
    # the identical workload through a single-device and a mesh-sharded
    # engine (multi-step decode loop, decode_chunk=4):
    #   * device-scaling curve: 1 / 2 / 4 devices at the base smoke width;
    #   * model-size sweep: 4-device mesh at widen x {d_model, n_heads,
    #     d_ff} — the per-step collective cost is fixed and activation-
    #     sized, so the ratio improves as per-device compute grows.
    # On a shared-core CPU host these are parity rows (every "device" is a
    # timeslice of the same cores, so sharded pays the full collective +
    # partitioning overhead with zero added FLOP throughput); on real
    # multi-chip hardware the same rows are the scale-out claim.
    sh_modes = ["det"] if fast else ["det", "xnor"]
    sh_n, sh_cap, sh_slots = (6, 8, 2) if fast else (8, 16, 4)
    sh_chunk = 4

    def _row(tag, r, mode):
        single = r[f"{mode}_single"]["tok_s"]
        tp = r[f"{mode}_sharded"]["tok_s"]
        rows.append(csv_row(
            f"serve/{tag}_{mode}", 0.0,
            f"single={single:.1f} sharded={tp:.1f} tok/s "
            f"ratio={tp / single:.2f}x identical={r[f'{mode}_identical']}"))
        return tp / single

    ratios = {m: {} for m in sh_modes}
    scaling = {}
    curve = ([(4, (2, 2))] if fast
             else [(1, (1, 1)), (2, (1, 2)), (4, (2, 2))])
    for ndev, shape in curve:
        r = _sharded_compare(sh_modes, sh_n, sh_cap, sh_slots,
                             devices=ndev, mesh_shape=shape, chunk=sh_chunk)
        if r is None:
            continue
        scaling[f"devices{ndev}"] = r
        for mode in sh_modes:
            ratio = _row(f"sharded_devices{ndev}", r, mode)
            if ndev == 4:
                ratios[mode]["widen1"] = ratio
    record["sharded_scaling"] = scaling

    sweep = {}
    for widen in ((2,) if fast else (2, 4)):
        r = _sharded_compare(sh_modes, sh_n, sh_cap, sh_slots, devices=4,
                             mesh_shape=(2, 2), widen=widen, chunk=sh_chunk)
        if r is None:
            continue
        sweep[f"widen{widen}"] = r
        for mode in sh_modes:
            ratios[mode][f"widen{widen}"] = _row(
                f"sharded_widen{widen}", r, mode)
    record["sharded_widen"] = sweep

    # ratio envelope + gate: the best sharded/single ratio per mode rides
    # in the artifact's run_manifest (the envelope CI archives), and a
    # GENEROUS floor turns a catastrophic collective regression (e.g. the
    # decode step re-growing weight-sized gathers) into a red build without
    # flaking on shared-core CI parity physics.
    best = {m: max(v.values()) for m, v in ratios.items() if v}
    record["sharded_ratio"] = ratios
    # promoted from the run_manifest into the results proper: the best
    # sharded/single ratio per mode is the envelope number the README's
    # soft floor (det >= ~0.7, xnor >= ~0.35 on shared-core CPU; hard
    # gate 0.25) tracks across PRs
    record["sharded_ratio_best"] = best
    for mode, r in sorted(best.items()):
        rows.append(csv_row(f"serve/sharded_best_ratio_{mode}", 0.0,
                            f"best_ratio={r:.2f}x (gate: >= 0.25)"))

    save_json("serve_bench", record,
              mesh_shape=[2, 2] if scaling or sweep else None,
              sharded_ratio_best=best or None)
    if best and max(best.values()) < 0.25:
        raise RuntimeError(
            f"sharded/single tok/s best ratio {best} fell below the 0.25 "
            f"floor — the decode step has likely re-grown weight-sized "
            f"collectives (run benchmarks.check_collectives for the diff)")
    return rows


if __name__ == "__main__":
    print("name,us_per_call,derived")
    for line in main():
        print(line)
