"""Benchmark entry point: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows. Heavy suites honour
``--fast`` (used by tests) to shrink step counts.

  PYTHONPATH=src python -m benchmarks.run [--fast] [--only table1,...]
"""
from __future__ import annotations

import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--only", default="")
    args = ap.parse_args()

    from benchmarks import (ensemble_bench, fig23_curves, kernel_bench,
                            plan_bench, roofline_report, serve_bench, table1,
                            xnor_bench, xnor_conv_bench)
    suites = {
        "table1": table1.main,
        "fig23": fig23_curves.main,
        "kernels": kernel_bench.main,
        "roofline": roofline_report.main,
        "xnor": xnor_bench.main,
        "xnor_conv": xnor_conv_bench.main,
        "plans": plan_bench.main,
        "serve": serve_bench.main,
        "ensemble": ensemble_bench.main,
    }
    selected = (args.only.split(",") if args.only else list(suites))
    print("name,us_per_call,derived")
    failed = []
    for name in selected:
        try:
            for line in suites[name](fast=args.fast):
                print(line)
        except Exception:
            failed.append(name)
            traceback.print_exc()
    if failed:
        print(f"FAILED suites: {failed}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
