"""Paper Figs. 2-3 analogue: validation-accuracy-vs-epoch curves for
{no-reg, deterministic, stochastic} on MNIST-FC (Fig. 2) and VGG/CIFAR
(Fig. 3), on the synthetic stand-in datasets.

Claims checked (paper §IV):
  * all three regimes converge to similar validation accuracy;
  * regularized (binarized) networks need more epochs to converge;
  * det and stoch curves track each other closely.
Outputs per-epoch accuracies to results/fig2_mnist.json / fig3_cifar.json
and an ASCII sparkline summary.
"""
from __future__ import annotations

import jax

from repro.core import binarize as B
from repro.core.policy import NONE_POLICY
from repro.data import synthetic as syn
from repro.launch.train import make_paper_policy
from repro.models import mnist_fc, vgg
from repro.optim import schedules
from repro.optim.sgd import sgd_momentum
from repro.train import steps as ST

from benchmarks.common import csv_row, save_json


def run_curves(model_name: str, epochs: int, steps_per_epoch: int,
               batch: int = 64, lr: float = 1e-2):
    curves = {}
    policy = make_paper_policy(3)
    for mode in ("none", "det", "stoch"):
        if model_name == "mnist_fc":
            tree = mnist_fc.init(jax.random.key(0), hidden=(256, 256))
            apply_fn = mnist_fc.apply
            spec = syn.SyntheticSpec("mnist", n_train=steps_per_epoch * batch,
                                     batch_size=batch)
            flat = True
        else:
            tree = vgg.init(jax.random.key(0), width_mult=0.25)
            apply_fn = vgg.apply
            spec = syn.SyntheticSpec("cifar", n_train=steps_per_epoch * batch,
                                     batch_size=batch)
            flat = False
        opt = sgd_momentum(schedules.paper_eq4(lr, steps_per_epoch),
                           momentum=0.9)
        step = jax.jit(ST.make_train_step(
            ST.make_classifier_loss(apply_fn), opt, mode,
            policy if mode != "none" else NONE_POLICY, has_model_state=True))
        state = ST.init_train_state(tree["params"], opt,
                                    model_state=tree["state"])
        eval_fn = ST.make_eval_fn(apply_fn)
        accs = []
        for e in range(epochs):
            for i in range(steps_per_epoch):
                x, y = syn.train_batch(spec, e * steps_per_epoch + i)
                xin = x.reshape(x.shape[0], -1) if flat else x
                state, _ = step(state, {"x": xin, "y": y})
            params = state["params"]
            ms = state["model_state"]
            if mode != "none":
                params = B.binarize_tree(params, "det", policy)
                if mode == "stoch":
                    cal = []
                    for j in range(5):
                        xc, _ = syn.train_batch(spec, 10_000 + j)
                        cal.append(xc.reshape(xc.shape[0], -1) if flat else xc)
                    ms = ST.recalibrate_bn(apply_fn, params, ms, cal)
            x, y = syn.eval_batch(spec)
            xin = x.reshape(x.shape[0], -1) if flat else x
            _, acc = eval_fn(params, ms, xin, y)
            accs.append(float(acc))
        curves[mode] = accs
    return curves


def _spark(vals):
    blocks = "▁▂▃▄▅▆▇█"
    lo, hi = min(vals), max(vals)
    rng = (hi - lo) or 1.0
    return "".join(blocks[int((v - lo) / rng * 7)] for v in vals)


def main(fast: bool = False) -> list[str]:
    lines = []
    mnist = run_curves("mnist_fc", epochs=4 if fast else 8,
                       steps_per_epoch=15 if fast else 30)
    save_json("fig2_mnist", mnist)
    cifar = run_curves("vgg16_cifar10", epochs=3 if fast else 6,
                       steps_per_epoch=8 if fast else 20, batch=16)
    save_json("fig3_cifar", cifar)
    for name, curves in (("fig2_mnist", mnist), ("fig3_cifar", cifar)):
        for mode, accs in curves.items():
            lines.append(csv_row(f"{name}/{mode}/final_acc", accs[-1] * 1e6,
                                 _spark(accs)))
    return lines


if __name__ == "__main__":
    print("\n".join(main()))
