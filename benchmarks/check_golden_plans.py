"""Golden execution-plan manifests: compile vs committed, fail loudly.

The per-layer dispatch boundary (which backend serves which layer of the
paper nets) is a correctness-critical artifact: a silent shift — e.g. a
policy regex change pushing VGG conv block 1 onto the binary-activation
path — changes served numerics without failing any kernel test. CI
compiles the plans for the paper models under det and xnor modes and diffs
them against the manifests committed in ``benchmarks/golden_plans/``.

  PYTHONPATH=src python -m benchmarks.check_golden_plans          # check
  PYTHONPATH=src python -m benchmarks.check_golden_plans --write  # regen

Regenerate (and commit) the goldens only when a dispatch change is
intentional; the diff printed on mismatch is the review artifact.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

GOLDEN_DIR = os.path.join(os.path.dirname(__file__), "golden_plans")


def compiled_plans() -> dict:
    """name -> plan JSON dict for every golden-checked (arch, mode) cell."""
    from benchmarks.plan_bench import MODES, paper_model_trees
    from repro.engine import compile_plan

    out = {}
    for arch, (params, policy) in paper_model_trees().items():
        for mode in MODES:
            plan = compile_plan(params, policy, mode, warn=False)
            out[f"{arch}_{mode}"] = plan.to_json()
    return out


def _diff(name: str, want: dict, got: dict) -> list[str]:
    lines = []
    wl = {r["path"]: r for r in want.get("layers", ())}
    gl = {r["path"]: r for r in got.get("layers", ())}
    for path in sorted(set(wl) | set(gl)):
        w, g = wl.get(path), gl.get(path)
        if w == g:
            continue
        if w is None:
            lines.append(f"  {name}: NEW layer {path} -> {g['backend']}")
        elif g is None:
            lines.append(f"  {name}: MISSING layer {path} "
                         f"(was {w['backend']})")
        else:
            for key in sorted(set(w) | set(g)):
                if w.get(key) != g.get(key):
                    lines.append(f"  {name}: {path}.{key}: "
                                 f"{w.get(key)!r} -> {g.get(key)!r}")
    for key in ("version", "mode", "with_scale"):
        if want.get(key) != got.get(key):
            lines.append(f"  {name}: {key}: {want.get(key)!r} -> "
                         f"{got.get(key)!r}")
    return lines


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--write", action="store_true",
                    help="(re)write the golden manifests instead of checking")
    args = ap.parse_args(argv)

    plans = compiled_plans()
    if args.write:
        os.makedirs(GOLDEN_DIR, exist_ok=True)
        for name, d in plans.items():
            path = os.path.join(GOLDEN_DIR, f"{name}.json")
            with open(path, "w") as f:
                json.dump(d, f, indent=1, sort_keys=True)
                f.write("\n")
            print(f"wrote {path}")
        return 0

    failures: list[str] = []
    for name, got in plans.items():
        path = os.path.join(GOLDEN_DIR, f"{name}.json")
        if not os.path.exists(path):
            failures.append(f"  {name}: golden manifest missing ({path})")
            continue
        with open(path) as f:
            want = json.load(f)
        failures.extend(_diff(name, want, got))
    if failures:
        print("golden plan mismatch — dispatch boundary changed. If "
              "intentional, regen with --write and commit:", file=sys.stderr)
        print("\n".join(failures), file=sys.stderr)
        return 1
    print(f"golden plans OK ({len(plans)} manifests)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
