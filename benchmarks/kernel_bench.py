"""Kernel-level microbenchmarks.

On this CPU container, Pallas interpret-mode timings are NOT indicative of
TPU performance — what IS structural and platform-independent is the
bytes-moved accounting (the paper's actual mechanism). We therefore report:
  * measured CPU wall time of the jnp reference paths (labeled cpu-ref;
    useful only for relative dense-vs-binary comparisons),
  * weight bytes dense vs packed (the 16x HBM-traffic claim),
  * the roofline-projected TPU time for each path at decode shapes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import packing as P
from repro.core import roofline as R
from repro.kernels import ops, ref
from repro.xnor import ops as xops
from repro.xnor import packing as xpack

from benchmarks.common import csv_row, save_json, timed


def main(fast: bool = False) -> list[str]:
    lines = []
    records = []
    shapes = [(8, 4096, 4096), (128, 4096, 4096)]
    if not fast:
        shapes.append((128, 8192, 8192))
    for m, k, n in shapes:
        x = jax.random.normal(jax.random.key(0), (m, k), jnp.float32)
        w = jax.random.normal(jax.random.key(1), (k, n), jnp.float32)
        wp = ops.binarize_and_pack(w)
        wb16 = w.astype(jnp.bfloat16)

        dense_fn = jax.jit(lambda x, w: x.astype(jnp.bfloat16) @ w)
        bin_fn = jax.jit(lambda x, wp: ref.binary_matmul_ref(x, wp))
        t_dense = timed(dense_fn, x, wb16, iters=3)
        t_bin = timed(bin_fn, x, wp, iters=3)

        dense_bytes = k * n * 2 + m * k * 2 + m * n * 4
        packed_bytes = P.packed_nbytes((k, n)) + m * k * 2 + m * n * 4
        # TPU roofline projection: decode shapes are weight-bytes bound
        tpu_dense_s = max(dense_bytes / R.HBM_BW,
                          2 * m * k * n / R.PEAK_FLOPS_BF16)
        tpu_packed_s = max(packed_bytes / R.HBM_BW,
                           2 * m * k * n / R.PEAK_FLOPS_BF16)
        rec = {
            "shape": [m, k, n],
            "cpu_ref_dense_s": t_dense, "cpu_ref_binary_s": t_bin,
            "weight_bytes_dense_bf16": k * n * 2,
            "weight_bytes_packed": P.packed_nbytes((k, n)),
            "tpu_roofline_dense_s": tpu_dense_s,
            "tpu_roofline_packed_s": tpu_packed_s,
            "tpu_projected_speedup": tpu_dense_s / tpu_packed_s,
        }
        records.append(rec)
        lines.append(csv_row(
            f"kernel/binary_matmul/{m}x{k}x{n}/tpu_projected",
            tpu_packed_s * 1e6,
            f"dense={tpu_dense_s*1e6:.1f}us;speedup={rec['tpu_projected_speedup']:.2f}x"))
        lines.append(csv_row(
            f"kernel/binary_matmul/{m}x{k}x{n}/weight_compression",
            rec["weight_bytes_packed"],
            f"{rec['weight_bytes_dense_bf16']/rec['weight_bytes_packed']:.1f}x"))

    # XNOR-popcount (fully-binary) path: dense vs packed-weight vs xnor.
    # The packed-weight path still moves full-width activations; xnor moves
    # 1-bit activations — the bytes-moved columns are the structural claim.
    from benchmarks.xnor_bench import xnor_cpu_ref as xnor_cpu

    for m, k, n in shapes:
        x = jax.random.normal(jax.random.key(4), (m, k), jnp.float32)
        wp = ops.binarize_and_pack(
            jax.random.normal(jax.random.key(5), (k, n), jnp.float32))
        t_xnor = timed(jax.jit(
            lambda x, wp, k=k: xnor_cpu(x, wp, k)), x, wp, iters=3)

        w_bytes = P.packed_nbytes((k, n))
        act_dense = xpack.activation_nbytes((m, k), 2)          # bf16
        act_xnor = xpack.packed_activation_nbytes((m, k))       # 1-bit
        packed_path_bytes = w_bytes + act_dense + m * n * 4
        xnor_path_bytes = w_bytes + act_xnor + m * n * 4
        tpu_packed_s = max(packed_path_bytes / R.HBM_BW,
                           2 * m * k * n / R.PEAK_FLOPS_BF16)
        # xnor does no MXU flops; bound it by bytes + VPU int ops
        tpu_xnor_s = max(xnor_path_bytes / R.HBM_BW,
                         2 * m * (k // 32) * n / R.PEAK_FLOPS_BF16)
        rec = {
            "shape": [m, k, n],
            "cpu_ref_xnor_s": t_xnor,
            "activation_bytes_dense_bf16": act_dense,
            "activation_bytes_xnor": act_xnor,
            "activation_compression": act_dense / act_xnor,
            "total_bytes_packed_weight_path": packed_path_bytes,
            "total_bytes_xnor_path": xnor_path_bytes,
            "tpu_roofline_packed_s": tpu_packed_s,
            "tpu_roofline_xnor_s": tpu_xnor_s,
            "tpu_projected_speedup_vs_packed": tpu_packed_s / tpu_xnor_s,
        }
        records.append(rec)
        lines.append(csv_row(
            f"kernel/xnor_matmul/{m}x{k}x{n}/activation_compression",
            act_xnor, f"{act_dense/act_xnor:.1f}x_fewer_activation_bytes"))
        lines.append(csv_row(
            f"kernel/xnor_matmul/{m}x{k}x{n}/tpu_projected",
            tpu_xnor_s * 1e6,
            f"packed={tpu_packed_s*1e6:.1f}us;"
            f"speedup={rec['tpu_projected_speedup_vs_packed']:.2f}x"))

    # XNOR conv (binary im2col popcount conv): one VGG-shaped layer per
    # speed tier; the dedicated xnor_conv suite covers the full stack and
    # owns the shared bytes/roofline math.
    from benchmarks.xnor_conv_bench import layer_roofline, roofline_csv_rows
    from repro.xnor import conv as xconv

    conv_shapes = [(8, 16, 16, 128, 128)]
    if not fast:
        conv_shapes.append((8, 8, 8, 256, 256))
    for b, h, w, c, n in conv_shapes:
        x = jax.random.normal(jax.random.key(7), (b, h, w, c), jnp.float32)
        wp = xconv.pack_conv_kernel(
            jax.random.normal(jax.random.key(8), (3, 3, c, n), jnp.float32))
        t_conv = timed(jax.jit(lambda x, wp, c=c: xconv.xnor_conv2d(
            x, wp, ksize=(3, 3), c_in=c, use_pallas=False)), x, wp, iters=3)
        rec = {**layer_roofline(b, h, w, c, n),
               "cpu_ref_xnor_conv_s": t_conv}
        records.append(rec)
        lines += roofline_csv_rows(f"kernel/xnor_conv/{b}x{h}x{w}x{c}->{n}",
                                   rec)

    # fused sign->pack throughput (CPU reference; structural check only)
    xa = jax.random.normal(jax.random.key(6), (128, 4096))
    t_sp = timed(jax.jit(lambda x: xops.sign_and_pack(x)), xa, iters=3)
    lines.append(csv_row("kernel/sign_pack/128x4096", t_sp * 1e6, "cpu-ref"))

    # fused binarize+pack throughput (CPU reference; structural check only)
    w = jax.random.normal(jax.random.key(2), (4096, 4096))
    t_det = timed(jax.jit(lambda w: ops.binarize_and_pack(w)), w, iters=3)
    key = jax.random.key(3)
    t_stoch = timed(jax.jit(
        lambda w, k: ops.binarize_and_pack(w, k, stochastic=True)), w, key,
        iters=3)
    lines.append(csv_row("kernel/binarize_pack/det/4096x4096", t_det * 1e6,
                         "cpu-ref"))
    lines.append(csv_row("kernel/binarize_pack/stoch/4096x4096",
                         t_stoch * 1e6, "cpu-ref"))
    save_json("kernel_bench", records)
    return lines


if __name__ == "__main__":
    print("\n".join(main()))
