"""Shared benchmark utilities."""
from __future__ import annotations

import datetime
import json
import os
import platform
import subprocess
import time

import jax

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results")


def run_manifest(**extra) -> dict:
    """Provenance stamp for a benchmark artifact: without the git SHA, jax
    version and device inventory a committed number is unfalsifiable — you
    can't tell whether a regression is a code change or a different machine.
    ``extra`` lets a suite add run-specific fields (e.g. serve_bench records
    the mesh shape its sharded subprocess forced)."""
    try:
        sha = subprocess.run(
            ["git", "rev-parse", "HEAD"], cwd=os.path.dirname(__file__),
            capture_output=True, text=True, timeout=10).stdout.strip() or None
    except Exception:
        sha = None
    devices = jax.devices()
    manifest = {
        "git_sha": sha,
        "jax_version": jax.__version__,
        "backend": devices[0].platform if devices else None,
        "device_count": jax.device_count(),
        "device_kinds": sorted({d.device_kind for d in devices}),
        "mesh_shape": None,
        "python": platform.python_version(),
        "platform": platform.platform(),
        "wall_clock_utc": datetime.datetime.now(datetime.timezone.utc)
                          .isoformat(timespec="seconds"),
    }
    manifest.update(extra)
    return manifest


def timed(fn, *args, warmup: int = 1, iters: int = 5) -> float:
    """Median wall seconds per call (post-warmup, blocked on results)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def save_json(name: str, record, **manifest_extra) -> str:
    """Writes ``{"run_manifest": ..., "results": record}`` — every suite
    artifact carries its provenance under the same envelope regardless of
    whether the suite's own record is a list or a dict."""
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, f"{name}.json")
    payload = {"run_manifest": run_manifest(**manifest_extra),
               "results": record}
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
    return path


def csv_row(name: str, us_per_call: float, derived: str = "") -> str:
    return f"{name},{us_per_call:.1f},{derived}"
