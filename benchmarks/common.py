"""Shared benchmark utilities."""
from __future__ import annotations

import json
import os
import time

import jax

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results")


def timed(fn, *args, warmup: int = 1, iters: int = 5) -> float:
    """Median wall seconds per call (post-warmup, blocked on results)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def save_json(name: str, record) -> str:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, f"{name}.json")
    with open(path, "w") as f:
        json.dump(record, f, indent=1)
    return path


def csv_row(name: str, us_per_call: float, derived: str = "") -> str:
    return f"{name},{us_per_call:.1f},{derived}"
