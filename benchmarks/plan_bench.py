"""Execution-plan report for the paper models (suite name: ``plans``).

Compiles the per-layer dispatch plan (``repro.engine.compile_plan``) for
the full-size paper nets under ``det`` and ``xnor`` serving modes and
reports, per layer, the assigned backend and the HBM bytes it moves vs the
dense baseline — plus the plan-wide totals and roofline-projected times.
All arithmetic comes from the shared ``repro.engine.costs`` model, so these
numbers, the xnor benches and the serve-time ``--plan-report`` agree by
construction. Parameter trees are built with ``jax.eval_shape`` (shapes
only, no weight allocation), so the suite is near-free.
"""
from __future__ import annotations

import jax

from repro.engine import compile_plan, plan_report
from repro.launch.train import make_paper_policy

from benchmarks.common import csv_row, save_json

MODES = ("det", "stoch", "xnor")


def paper_model_trees() -> dict:
    """arch -> (abstract params tree, policy), full paper-scale shapes."""
    from repro.configs import mnist_fc as MC
    from repro.configs import vgg16_cifar10 as VC
    from repro.models import mnist_fc, vgg

    fc = jax.eval_shape(
        lambda: mnist_fc.init(jax.random.key(0), hidden=MC.HIDDEN))
    cnn = jax.eval_shape(
        lambda: vgg.init(jax.random.key(0), width_mult=VC.WIDTH_MULT))
    return {
        "mnist_fc": (fc["params"], make_paper_policy(len(MC.HIDDEN) + 1)),
        "vgg16_cifar10": (cnn["params"], make_paper_policy(3)),
    }


def main(fast: bool = False) -> list[str]:
    lines: list[str] = []
    records = []
    batch = 8
    for arch, (params, policy) in paper_model_trees().items():
        for mode in MODES:
            plan = compile_plan(params, policy, mode, warn=False)
            rows = plan_report(plan, batch=batch)
            dense_b = sum(r["weight_bytes_dense"] for r in rows)
            plan_b = sum(r["weight_bytes"] for r in rows)
            by_backend: dict[str, int] = {}
            for r in rows:
                by_backend[r["backend"]] = by_backend.get(r["backend"], 0) + 1
            records.append({"arch": arch, "mode": mode, "batch": batch,
                            "weight_bytes_dense": dense_b,
                            "weight_bytes_plan": plan_b,
                            "layers_by_backend": by_backend, "rows": rows})
            lines.append(csv_row(
                f"plans/{arch}/{mode}/weight_bytes", plan_b,
                f"dense={dense_b};reduction={dense_b / max(plan_b, 1):.1f}x;"
                + ";".join(f"{k}={v}" for k, v in sorted(by_backend.items()))))
            if not fast:
                for r in rows:
                    lines.append(csv_row(
                        f"plans/{arch}/{mode}/{r['path']}",
                        r["weight_bytes"],
                        f"backend={r['backend']};reason={r['reason']}"))
    save_json("plan_report", records)
    return lines


if __name__ == "__main__":
    print("\n".join(main()))
