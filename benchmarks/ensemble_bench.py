"""Stochastic-ensemble serving curves (suite name: ``ensemble``).

Three question the paper's Eq.-2/3 stochastic nets raise for serving, all
answered from the ``repro.stoch`` subsystem:

* **bytes vs K** (full paper-scale shapes, ``jax.eval_shape`` — no weight
  allocation): K bitpacked replicas of every stochastic layer against one
  bf16 copy of the whole model. 1-bit packing is a 16x reduction, and the
  input/classifier/bn leaves are shared (never replicated), so the packed
  replica set stays under the dense baseline for every K <= 16 — the
  scaling-by-replication headroom FINN-style datapath widening exploits.
* **accuracy / agreement vs K** (smoke-size materialized nets, synthetic
  data): ensemble-mean classification accuracy, replica vote agreement and
  mean logit variance as K grows — the uncertainty signal flattens toward
  its asymptote by K ~ 8.
* **tok/s vs K** (smoke token arch through ``stream_serve``): the
  throughput cost of holding K replica caches resident in the step-level
  continuous-batching loop.

Writes ``benchmarks/results/ensemble_bench.json``.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.engine import compile_plan, plan_report
from repro.launch.train import make_paper_policy

from benchmarks.common import csv_row, save_json
from benchmarks.plan_bench import paper_model_trees

K_GRID = (1, 2, 4, 8, 16)


# ---------------------------------------------------------------------------
# bytes vs K (full-size, shape-only)
# ---------------------------------------------------------------------------

def byte_curves() -> list[dict]:
    """Per arch: K * (one replica's stochastic-leaf packed bytes) vs one
    dense bf16 copy of the *whole* model, plus the true resident total
    (shared leaves stored once). All arithmetic from the shared
    ``repro.engine.costs`` model via ``plan_report``."""
    records = []
    for arch, (params, policy) in paper_model_trees().items():
        plan = compile_plan(params, policy, "stoch", warn=False)
        rows = plan_report(plan, batch=8, full=True)
        stoch = {a.path for a in plan.stochastic_rows()}
        dense_total = sum(r["weight_bytes_dense"] for r in rows)
        stoch_packed = sum(r["weight_bytes"] for r in rows
                           if r["path"] in stoch)
        shared = sum(r["weight_bytes"] for r in rows
                     if r["path"] not in stoch)
        curve = []
        for k in K_GRID:
            rep = k * stoch_packed
            curve.append({
                "k": k,
                "packed_replica_bytes": rep,
                "total_with_shared": shared + rep,
                "dense_bf16_bytes": dense_total,
                "vs_dense": rep / dense_total,
                "under_dense_bf16": bool(rep < dense_total),
            })
        records.append({"arch": arch, "mode": "stoch",
                        "stoch_layer_packed_bytes": stoch_packed,
                        "shared_bytes": shared,
                        "dense_bf16_bytes": dense_total,
                        "curve": curve})
    return records


# ---------------------------------------------------------------------------
# accuracy / agreement vs K (smoke-size, materialized)
# ---------------------------------------------------------------------------

def _smoke_classifier(arch: str, seed: int):
    from repro.models import mnist_fc, vgg

    if arch == "mnist_fc":
        from repro.configs import mnist_fc as C
        tree = mnist_fc.init(jax.random.key(seed), hidden=C.SMOKE_HIDDEN)
        return (tree, mnist_fc.apply, len(tree["params"]["layers"]), "mnist")
    from repro.configs import vgg16_cifar10 as C
    tree = vgg.init(jax.random.key(seed), width_mult=C.SMOKE_WIDTH_MULT)
    return tree, vgg.apply, len(tree["params"]["fc"]), "cifar"


def classifier_curves(fast: bool) -> list[dict]:
    from repro.data import synthetic as syn
    from repro.stoch import ensemble_forward, sample_replicas

    ks = (1, 2, 4) if fast else K_GRID
    batch, n_batches = (16, 1) if fast else (32, 2)
    records = []
    for arch in ("mnist_fc", "vgg16_cifar10"):
        tree, apply_fn, n_fc, kind = _smoke_classifier(arch, seed=0)
        params, mstate = tree["params"], tree["state"]
        plan = compile_plan(params, make_paper_policy(n_fc), "stoch",
                            warn=False)
        spec = syn.SyntheticSpec(kind, n_train=batch * n_batches,
                                 batch_size=batch, seed=0)
        curve = []
        for k in ks:
            rs = sample_replicas(params, plan, jax.random.key(1), k)

            @jax.jit
            def fwd(x, rs=rs):
                return ensemble_forward(
                    rs, lambda t: apply_fn(t, mstate, x, training=False,
                                           binary_act=False)[0])

            accs, agrs, vrs = [], [], []
            for step in range(n_batches):
                x, y = syn.train_batch(spec, step)
                if arch == "mnist_fc":
                    x = x.reshape(x.shape[0], -1)
                es = fwd(x)
                pred = np.asarray(np.argmax(np.asarray(es.mean_logits), -1))
                accs.append(float((pred == np.asarray(y)).mean()))
                agrs.append(float(np.asarray(es.agreement).mean()))
                vrs.append(float(np.asarray(es.variance).mean()))
            curve.append({"k": k, "accuracy": float(np.mean(accs)),
                          "vote_agreement": float(np.mean(agrs)),
                          "logit_variance": float(np.mean(vrs))})
        records.append({"arch": arch, "images": batch * n_batches,
                        "smoke": True, "curve": curve})
    return records


# ---------------------------------------------------------------------------
# tok/s vs K (smoke token arch, streaming loop)
# ---------------------------------------------------------------------------

def token_curves(fast: bool) -> dict:
    from repro.configs import base as cb
    from repro.core.policy import DEFAULT_POLICY
    from repro.models import transformer as T
    from repro.serve.batcher import SlotBatcher
    from repro.serve.engine import ServeEngine, stream_serve
    from repro.stoch import sample_replicas

    arch = "starcoder2_3b"
    cfg = cb.get_config(arch, smoke=True)
    params = T.init_lm(cfg, jax.random.key(0))
    plan = compile_plan(params, DEFAULT_POLICY, "stoch", warn=False)
    ks = (1, 2) if fast else (1, 2, 4, 8)
    n_req, slots, plen, mnew = (2, 2, 8, 4) if fast else (6, 2, 8, 8)
    rng = np.random.default_rng(0)
    curve = []
    for k in ks:
        rs = sample_replicas(params, plan, jax.random.key(1), k)
        engine = ServeEngine(cfg, None, ensemble=rs)
        batcher = SlotBatcher(slots, plen)
        for _ in range(n_req):
            batcher.submit(rng.integers(0, cfg.vocab_size, plen), mnew)
        t0 = time.perf_counter()
        stream_serve(engine, batcher)
        dt = time.perf_counter() - t0
        toks = batcher.tokens_generated
        curve.append({"k": k, "tokens": toks, "seconds": dt,
                      "tok_per_s": toks / dt})
    return {"arch": arch, "smoke": True, "requests": n_req,
            "max_new": mnew, "curve": curve}


def main(fast: bool = False) -> list[str]:
    lines: list[str] = []
    bytes_rec = byte_curves()
    for rec in bytes_rec:
        for pt in rec["curve"]:
            lines.append(csv_row(
                f"ensemble/{rec['arch']}/bytes/k{pt['k']}",
                pt["packed_replica_bytes"],
                f"dense_bf16={pt['dense_bf16_bytes']};"
                f"ratio={pt['vs_dense']:.3f};"
                f"under_dense={pt['under_dense_bf16']}"))
    cls_rec = classifier_curves(fast)
    for rec in cls_rec:
        for pt in rec["curve"]:
            lines.append(csv_row(
                f"ensemble/{rec['arch']}/quality/k{pt['k']}",
                pt["vote_agreement"] * 1e3,
                f"accuracy={pt['accuracy']:.3f};"
                f"agreement={pt['vote_agreement']:.3f};"
                f"variance={pt['logit_variance']:.4f}"))
    tok_rec = token_curves(fast)
    for pt in tok_rec["curve"]:
        lines.append(csv_row(
            f"ensemble/{tok_rec['arch']}/tok_s/k{pt['k']}",
            pt["seconds"] * 1e6 / max(pt["tokens"], 1),
            f"tok_per_s={pt['tok_per_s']:.1f}"))
    save_json("ensemble_bench", {"bytes": bytes_rec,
                                 "classifier": cls_rec,
                                 "token": tok_rec})
    return lines


if __name__ == "__main__":
    print("\n".join(main()))
