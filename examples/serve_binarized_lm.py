"""End-to-end serving driver: batched requests through the slot batcher
against a binarized, bitpacked starcoder2-family model (smoke size), the
TPU analogue of the paper's inference-time experiment.

  PYTHONPATH=src python examples/serve_binarized_lm.py
"""
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.configs import base as cb
from repro.core.policy import DEFAULT_POLICY
from repro.models import transformer as T
from repro.serve.batcher import SlotBatcher
from repro.serve.engine import ServeEngine, pack_params, packed_param_bytes


def serve(params, cfg, tag, requests=8, slots=4, prompt_len=16, max_new=8):
    engine = ServeEngine(cfg, params)
    batcher = SlotBatcher(slots, prompt_len)
    rng = np.random.default_rng(0)
    for _ in range(requests):
        batcher.submit(rng.integers(0, cfg.vocab_size, prompt_len), max_new)
    t0 = time.perf_counter()
    while not batcher.idle:
        batcher.refill()
        out = engine.generate(jax.numpy.asarray(batcher.prompts()), max_new)
        for step_tok in np.asarray(out.tokens).T:
            batcher.record(step_tok)
    batcher.refill()
    dt = time.perf_counter() - t0
    print(f"{tag:>14s}: {len(batcher.completed)} requests, "
          f"{dt:.2f}s total, {dt/requests*1e3:.0f} ms/req")
    return dt


def main():
    cfg = cb.get_config("starcoder2_3b", smoke=True)
    params = T.init_lm(cfg, jax.random.key(0))

    serve(params, cfg, "dense f32")

    packed = pack_params(params, DEFAULT_POLICY, "det")
    dense_b, packed_b = packed_param_bytes(packed)
    print(f"packed projections: {dense_b/1e6:.1f}MB -> {packed_b/1e6:.1f}MB "
          f"({dense_b/packed_b:.1f}x fewer weight bytes => the HBM-bound "
          f"decode roofline term drops by the same factor on TPU)")
    serve(packed, cfg, "packed binary")


if __name__ == "__main__":
    main()
