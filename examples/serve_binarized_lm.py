"""End-to-end serving driver: step-level continuously batched requests
against a binarized, bitpacked starcoder2-family model (smoke size), the
TPU analogue of the paper's inference-time experiment. Requests stream
through a persistent slot-addressed KV cache — a finished request's slot is
re-prefilled from the queue on the next decode step.

  PYTHONPATH=src python examples/serve_binarized_lm.py
"""
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.configs import base as cb
from repro.core.policy import DEFAULT_POLICY
from repro.models import transformer as T
from repro.serve.batcher import SlotBatcher
from repro.serve.engine import (ServeEngine, pack_params, packed_param_bytes,
                                stream_serve)


def serve(params, cfg, tag, requests=8, slots=4, prompt_len=16, max_new=8):
    engine = ServeEngine(cfg, params)
    batcher = SlotBatcher(slots, prompt_len)
    rng = np.random.default_rng(0)
    for i in range(requests):
        # mixed per-request budgets: short requests free their slot for the
        # queue mid-stream (per-step refill, no round barrier)
        batcher.submit(rng.integers(0, cfg.vocab_size, prompt_len),
                       max_new if i % 2 == 0 else max(1, max_new // 4))
    t0 = time.perf_counter()
    steps = stream_serve(engine, batcher, max_new_cap=max_new)
    dt = time.perf_counter() - t0
    toks = batcher.tokens_generated
    print(f"{tag:>14s}: {len(batcher.completed)} requests, {toks} tokens in "
          f"{steps} steps, {dt:.2f}s total ({toks/dt:.0f} tok/s)")
    return dt


def main():
    cfg = cb.get_config("starcoder2_3b", smoke=True)
    params = T.init_lm(cfg, jax.random.key(0))

    serve(params, cfg, "dense f32")

    packed = pack_params(params, DEFAULT_POLICY, "det")
    dense_b, packed_b = packed_param_bytes(packed)
    print(f"packed projections: {dense_b/1e6:.1f}MB -> {packed_b/1e6:.1f}MB "
          f"({dense_b/packed_b:.1f}x fewer weight bytes => the HBM-bound "
          f"decode roofline term drops by the same factor on TPU)")
    serve(packed, cfg, "packed binary")


if __name__ == "__main__":
    main()
