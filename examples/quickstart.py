"""Quickstart: train a stochastically-binarized network (the paper's novel
regime) on synthetic MNIST, evaluate its deterministic-sign inference
network, and bitpack it for serving.

  PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

from repro.core import binarize as B
from repro.core.policy import BinarizePolicy
from repro.data import synthetic as syn
from repro.models import mnist_fc
from repro.optim import schedules
from repro.optim.sgd import sgd_momentum
from repro.serve.engine import pack_params, packed_param_bytes
from repro.train import steps as ST


def main():
    # 1. model + policy (BNN convention: first/last layers stay FP)
    tree = mnist_fc.init(jax.random.key(0), hidden=(256, 256))
    policy = BinarizePolicy(include=(r".*kernel$",),
                            exclude=(r"layers/0/kernel", r"layers/2/kernel"))

    # 2. Alg. 1 train step: binarize -> fwd/bwd -> update -> clip
    opt = sgd_momentum(schedules.paper_eq4(1e-2, steps_per_epoch=50),
                       momentum=0.9)
    step = jax.jit(ST.make_train_step(
        ST.make_classifier_loss(mnist_fc.apply), opt, "stoch", policy,
        has_model_state=True))
    state = ST.init_train_state(tree["params"], opt,
                                model_state=tree["state"])

    spec = syn.SyntheticSpec("mnist", n_train=3200, batch_size=64)
    for i in range(300):
        x, y = syn.train_batch(spec, i)
        state, m = step(state, {"x": x.reshape(64, -1), "y": y})
        if i % 50 == 0:
            print(f"step {i:4d}  loss {float(m['loss']):.3f}  "
                  f"acc {float(m['accuracy']):.3f}")

    # 3. inference network: deterministic sign of the masters + BN recal
    params_inf = B.binarize_tree(state["params"], "det", policy)
    cal = [syn.train_batch(spec, 10_000 + j)[0].reshape(64, -1)
           for j in range(20)]
    ms = ST.recalibrate_bn(mnist_fc.apply, params_inf, state["model_state"],
                           cal)
    x, y = syn.eval_batch(spec)
    loss, acc = ST.make_eval_fn(mnist_fc.apply)(params_inf, ms,
                                                x.reshape(-1, 784), y)
    print(f"\nvalidation: loss {float(loss):.3f}  accuracy {float(acc):.3f}")

    # 4. pack for serving: 1 bit/weight for the binarized projections
    packed = pack_params(state["params"], policy, "det")
    dense_b, packed_b = packed_param_bytes(packed)
    print(f"serving weights: {dense_b/1e6:.2f}MB dense bf16 -> "
          f"{packed_b/1e6:.2f}MB packed ({dense_b/packed_b:.1f}x)")


if __name__ == "__main__":
    main()
