"""Stochastic-ensemble uncertainty triage at example scale.

Trains the paper's FC MNIST net with stochastic binarization (Eq. 2/3),
draws a K-replica packed ensemble (``repro.stoch.sample_replicas``), and
uses the replica vote agreement to split a test stream into *confident*
and *ambiguous* inputs — the ambiguous bucket is where the ensemble
actually earns its bytes: accuracy on the confident bucket is far higher
than on the abstained one, so routing low-agreement inputs to a fallback
(bigger model, human) trades a small abstention rate for most of the
error mass.

  PYTHONPATH=src python examples/ensemble_uncertainty.py [--k 8]
      [--threshold 0.6]

The ambiguous inputs are *manufactured*: half the eval stream is blended
pairs of two classes (x = 0.5*a + 0.5*b), the classic
genuinely-ambiguous-input construction — a well-calibrated ensemble
should disagree on exactly those.

One honest knob: long BNN training polarizes master weights toward the
±1 clip boundaries (BinaryConnect's reported weight histograms), which is
what makes test-time Eq.-3 sampling informative — P(+1) = (w+1)/2 is
near 0/1 for most weights and genuinely uncertain for the rest. This
smoke-scale synthetic run stops at |w| ~ 0.05, where every sample is a
coin flip, so we apply a per-layer gain (clip(g*w, -1, 1), sign
preserved, g set so mean |w| lands near 0.8) before sampling to emulate
the polarized regime.
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.policy import BinarizePolicy
from repro.data import synthetic as syn
from repro.engine import compile_plan
from repro.models import mnist_fc
from repro.optim import schedules
from repro.optim.sgd import sgd_momentum
from repro.stoch import ensemble_forward, sample_replicas
from repro.train import steps as ST

POLICY = BinarizePolicy(include=(r".*kernel$",),
                        exclude=(r"layers/0/kernel", r"layers/2/kernel"))
EPOCHS, SPE, BATCH = 8, 25, 64
HIDDEN = (256, 256)


def polarize(params):
    """Per-layer gain on the stochastic kernels: clip(g*w, -1, 1) with g
    chosen so mean |w| lands near 0.8 — signs unchanged, so the det
    network is identical; only the Eq.-3 sampling sharpens (see module
    docstring)."""
    for i in range(1, len(params["layers"]) - 1):
        w = params["layers"][i]["kernel"]
        g = 0.8 / jnp.mean(jnp.abs(w))
        params["layers"][i]["kernel"] = jnp.clip(g * w, -1.0, 1.0)
    return params


def train():
    tree = mnist_fc.init(jax.random.key(0), hidden=HIDDEN)
    opt = sgd_momentum(schedules.paper_eq4(2e-2, SPE), momentum=0.9)
    step = jax.jit(ST.make_train_step(
        ST.make_classifier_loss(mnist_fc.apply), opt, "stoch", POLICY,
        has_model_state=True))
    state = ST.init_train_state(tree["params"], opt,
                                model_state=tree["state"])
    spec = syn.SyntheticSpec("mnist", n_train=SPE * BATCH, batch_size=BATCH)
    for e in range(EPOCHS):
        for i in range(SPE):
            x, y = syn.train_batch(spec, e * SPE + i)
            state, _ = step(state, {"x": x.reshape(BATCH, -1), "y": y})
    return state["params"], state["model_state"], spec


def eval_stream(spec, n=256):
    """Half clean inputs, half 50/50 two-class blends (label = first
    class; a blend is *correct* under either constituent's label, so we
    score it against both)."""
    xs, ys, ys2, blended = [], [], [], []
    for j in range(n // BATCH):
        xa, ya = syn.train_batch(spec, 50_000 + j)
        xb, yb = syn.train_batch(spec, 60_000 + j)
        half = BATCH // 2
        xs.append(np.concatenate([xa[:half], 0.5 * xa[half:] + 0.5 * xb[half:]]))
        ys.append(np.concatenate([ya[:half], ya[half:]]))
        ys2.append(np.concatenate([ya[:half], yb[half:]]))
        blended.append(np.concatenate([np.zeros(half, bool),
                                       np.ones(half, bool)]))
    return (np.concatenate(xs).reshape(n, -1), np.concatenate(ys),
            np.concatenate(ys2), np.concatenate(blended))


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--k", type=int, default=8)
    ap.add_argument("--threshold", type=float, default=0.95,
                    help="abstain when vote agreement drops below this")
    args = ap.parse_args()

    print(f"training stoch-binarized MNIST FC ({EPOCHS}x{SPE} steps)...")
    params, mstate, spec = train()
    params = polarize(params)
    plan = compile_plan(params, POLICY, "stoch", warn=False)
    rs = sample_replicas(params, plan, jax.random.key(1), args.k)
    # training ran on master weights; the BN running stats must be
    # recalibrated under the *binarized* forward (same recipe as
    # binarize_comparison.py), here against the replica-0 packed tree
    cal = [syn.train_batch(spec, 99_000 + j)[0].reshape(BATCH, -1)
           for j in range(10)]
    mstate = ST.recalibrate_bn(mnist_fc.apply, rs.base, mstate, cal)

    fwd = jax.jit(lambda x: ensemble_forward(
        rs, lambda t: mnist_fc.apply(t, mstate, x, training=False)[0]))
    x, y, y2, blended = eval_stream(spec)
    es = fwd(jnp.asarray(x))
    pred = np.asarray(jnp.argmax(es.mean_logits, -1))
    agr = np.asarray(es.agreement)
    correct = (pred == y) | (pred == y2)   # blends score against both labels
    confident = agr >= args.threshold

    print(f"\nK={args.k} replicas, abstain threshold {args.threshold}")
    print(f"  {'bucket':<12}{'n':>6}{'accuracy':>10}{'mean agr':>10}"
          f"{'% blended':>11}")
    for name, m in [("confident", confident), ("abstained", ~confident)]:
        if m.sum() == 0:
            print(f"  {name:<12}{0:>6}")
            continue
        print(f"  {name:<12}{int(m.sum()):>6}{correct[m].mean():>10.3f}"
              f"{agr[m].mean():>10.3f}{100 * blended[m].mean():>10.1f}%")
    cov = confident.mean()
    print(f"\n  coverage {100 * cov:.1f}%  |  accuracy on answered "
          f"{correct[confident].mean():.3f} vs overall {correct.mean():.3f}")
    caught = blended[~confident].sum() / max(blended.sum(), 1)
    print(f"  {100 * caught:.1f}% of the manufactured-ambiguous inputs "
          f"landed in the abstain bucket")


if __name__ == "__main__":
    main()
