"""Fault tolerance end-to-end: train a binarized LM, inject two crashes,
watch auto-recovery reproduce the uninterrupted trajectory, then do an
elastic "restart on fewer devices" reshard of the final checkpoint.

  PYTHONPATH=src python examples/fault_tolerant_training.py
"""
import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

from repro.configs import base as cb
from repro.core.policy import DEFAULT_POLICY
from repro.data import synthetic as syn
from repro.distributed.sharding import params_pspecs
from repro.ft.elastic import adjust_microbatching, make_elastic_mesh, reshard
from repro.ft.failures import FailureInjector
from repro.models import transformer as T
from repro.optim import schedules
from repro.optim.sgd import sgd_momentum
from repro.train import steps as ST
from repro.train.trainer import Trainer, TrainerConfig


def main():
    cfg = cb.get_config("starcoder2_3b", smoke=True)
    params = T.init_lm(cfg, jax.random.key(0))
    opt = sgd_momentum(schedules.constant(5e-3))
    step = ST.make_train_step(ST.make_lm_loss(cfg), opt, "det",
                              DEFAULT_POLICY)
    state = ST.init_train_state(params, opt)
    spec = syn.SyntheticSpec("lm", n_train=1 << 20, batch_size=8,
                             seq_len=64, vocab_size=cfg.vocab_size)

    with tempfile.TemporaryDirectory() as d:
        trainer = Trainer(
            TrainerConfig(total_steps=60, checkpoint_dir=d,
                          checkpoint_every=20, log_every=10,
                          async_checkpoint=True),
            step, lambda i: {"tokens": syn.lm_tokens(spec, i)}, state,
            failure_injector=FailureInjector((25, 47)))
        history = trainer.run()
        print(f"trained 60 steps with 2 injected crashes; "
              f"recoveries={trainer.recoveries}")
        for h in history[-3:]:
            print(f"  step {h['step']:3d}  loss {h['loss']:.4f}")

        # elastic restart: reshard the final params onto whatever devices
        # survive (here: the 1-device CPU "cluster")
        mesh = make_elastic_mesh(model_parallel=1)
        specs = params_pspecs(trainer.state["params"], fsdp=False)
        resharded = reshard(jax.device_get(trainer.state["params"]), specs,
                            mesh)
        mb = adjust_microbatching(global_batch=256, old_devices=256,
                                  new_devices=mesh.devices.size)
        print(f"elastic re-mesh onto {mesh.devices.size} device(s): "
              f"params resharded, grad-accum x{mb} keeps the global batch")


if __name__ == "__main__":
    main()
