"""Paper Figs. 2-3 at example scale: det vs stoch vs no-regularizer learning
curves on synthetic MNIST, printed as an ASCII chart.

  PYTHONPATH=src python examples/binarize_comparison.py

``--binarize xnor`` (the same flag launch.serve takes) additionally serves
the det-trained net through the fully-binary engine — pack_params swaps
hidden projections for XnorLinear leaves — and reports the packed eval
accuracy next to the dense-binarized one.
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

from repro.core import binarize as B
from repro.core.policy import BinarizePolicy, NONE_POLICY
from repro.data import synthetic as syn
from repro.models import mnist_fc
from repro.optim import schedules
from repro.optim.sgd import sgd_momentum
from repro.train import steps as ST

POLICY = BinarizePolicy(include=(r".*kernel$",),
                        exclude=(r"layers/0/kernel", r"layers/2/kernel"))
EPOCHS, SPE = 8, 25


def curve(mode):
    tree = mnist_fc.init(jax.random.key(0), hidden=(128, 128))
    opt = sgd_momentum(schedules.paper_eq4(2e-2, SPE), momentum=0.9)
    step = jax.jit(ST.make_train_step(
        ST.make_classifier_loss(mnist_fc.apply), opt, mode,
        POLICY if mode != "none" else NONE_POLICY, has_model_state=True))
    state = ST.init_train_state(tree["params"], opt, model_state=tree["state"])
    spec = syn.SyntheticSpec("mnist", n_train=SPE * 64, batch_size=64)
    eval_fn = ST.make_eval_fn(mnist_fc.apply)
    accs = []
    for e in range(EPOCHS):
        for i in range(SPE):
            x, y = syn.train_batch(spec, e * SPE + i)
            state, _ = step(state, {"x": x.reshape(64, -1), "y": y})
        params, ms = state["params"], state["model_state"]
        if mode != "none":
            params = B.binarize_tree(params, "det", POLICY)
            if mode == "stoch":
                cal = [syn.train_batch(spec, 99_000 + j)[0].reshape(64, -1)
                       for j in range(10)]
                ms = ST.recalibrate_bn(mnist_fc.apply, params, ms, cal)
        x, y = syn.eval_batch(spec)
        _, acc = eval_fn(params, ms, x.reshape(-1, 784), y)
        accs.append(float(acc))
    return accs, (state["params"], state["model_state"], spec)


def xnor_eval(params, model_state, spec):
    """Serve the trained net fully binary: XnorLinear hidden projections
    (binary weights AND activations), as launch.serve --binarize xnor.

    Training ran with ReLU activations, so the BN running stats are
    recalibrated under the sign-activation forward first (same recipe as
    det-evaluating a stoch-trained net)."""
    from repro.engine import compile_plan, format_plan_table, plan_report
    from repro.train.steps import accuracy

    plan = compile_plan(params, POLICY, "xnor")
    print("\nexecution plan (per-layer dispatch):")
    print(format_plan_table(plan_report(plan, batch=64)))
    packed = plan.pack(params)
    bact_apply = lambda p, s, x, training: mnist_fc.apply(  # noqa: E731
        p, s, x, training=training, binary_act=True)
    cal = [syn.train_batch(spec, 98_000 + j)[0].reshape(-1, 784)
           for j in range(10)]
    model_state = ST.recalibrate_bn(bact_apply, packed, model_state, cal)
    fwd = jax.jit(lambda p, s, x: bact_apply(p, s, x, training=False)[0])
    x, y = syn.eval_batch(spec)
    return float(accuracy(fwd(packed, model_state, x.reshape(-1, 784)), y))


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--binarize", default="", choices=["", "xnor"],
                    help="'xnor': also eval the det-trained net on the "
                         "fully-binary XNOR-popcount engine")
    args = ap.parse_args()

    results, trained = {}, {}
    for m in ("none", "det", "stoch"):
        results[m], trained[m] = curve(m)
    print("\nvalidation accuracy per epoch")
    print("epoch :", "  ".join(f"{e:5d}" for e in range(EPOCHS)))
    for mode, accs in results.items():
        print(f"{mode:6s}:", "  ".join(f"{a:5.3f}" for a in accs))
    # paper's claim: binarized curves converge close to the baseline,
    # needing somewhat more epochs
    print("\nfinal-accuracy deltas vs no-regularizer "
          "(paper: -0.94% det / -0.37% stoch on MNIST):")
    for mode in ("det", "stoch"):
        d = results[mode][-1] - results["none"][-1]
        print(f"  {mode}: {d:+.4f}")
    if args.binarize == "xnor":
        acc = xnor_eval(*trained["det"])
        print(f"\nxnor-served det net (binary weights+activations): "
              f"acc {acc:.3f} ({acc - results['det'][-1]:+.4f} vs dense "
              f"binarized eval, 16x fewer activation bytes on hidden layers)")


if __name__ == "__main__":
    main()
